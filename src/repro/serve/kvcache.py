"""SFP-compressed KV cache (beyond-paper application of the containers).

Decode is memory-bandwidth-bound by the KV cache read — exactly the regime
the paper targets at the DRAM interface. The cache stores SFP8 payloads
(1 sign + 4 delta-exp + 3 mantissa per value, one shared base exponent per
128 lanes — kernels/sfp_pack layout) and decompresses on read; each decode
step packs only the new token's K/V row. Cache bytes drop ~2x vs bf16 at
<= 3 mantissa bits of precision, matching where Quantum Mantissa lands
(paper Fig 4).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LOCAL
from repro.kernels import ops
from repro.models import attention


class PackedKV(NamedTuple):
    k_payload: jax.Array  # (B, L, D) uint8|uint16, D = KH * head_dim
    k_bases: jax.Array    # (B, L, D // 128) uint8
    v_payload: jax.Array
    v_bases: jax.Array


def _dims(cfg: ArchConfig, kind: str, max_len: int):
    D = cfg.n_kv_heads * cfg.head_dim_
    assert D % 128 == 0, (D, "KV feature dim must align to 128 lanes")
    L = min(max_len, cfg.window) if kind == LOCAL else max_len
    return D, L


def packed_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: str = "sfp8") -> PackedKV:
    D, L = _dims(cfg, kind, max_len)
    pdt = jnp.uint8 if container == "sfp8" else jnp.uint16
    return PackedKV(
        k_payload=jnp.zeros((batch, L, D), pdt),
        k_bases=jnp.zeros((batch, L, D // 128), jnp.uint8),
        v_payload=jnp.zeros((batch, L, D), pdt),
        v_bases=jnp.zeros((batch, L, D // 128), jnp.uint8),
    )


def packed_cache_spec(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: str = "sfp8") -> PackedKV:
    D, L = _dims(cfg, kind, max_len)
    pdt = jnp.uint8 if container == "sfp8" else jnp.uint16
    return PackedKV(
        k_payload=jax.ShapeDtypeStruct((batch, L, D), pdt),
        k_bases=jax.ShapeDtypeStruct((batch, L, D // 128), jnp.uint8),
        v_payload=jax.ShapeDtypeStruct((batch, L, D), pdt),
        v_bases=jax.ShapeDtypeStruct((batch, L, D // 128), jnp.uint8),
    )


def packed_cache_axes() -> PackedKV:
    return PackedKV(
        k_payload=("batch", "cache_seq", None),
        k_bases=("batch", "cache_seq", None),
        v_payload=("batch", "cache_seq", None),
        v_bases=("batch", "cache_seq", None),
    )


def attention_decode_packed(params, h_tok: jax.Array, cache: PackedKV,
                            pos: jax.Array, cfg: ArchConfig, *, kind: str,
                            container: str = "sfp8"
                            ) -> Tuple[jax.Array, PackedKV]:
    """One-token decode over the compressed cache."""
    B = h_tok.shape[0]
    hd, H, KH = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    D = KH * hd
    L = cache.k_payload.shape[1]
    dtype = h_tok.dtype

    q, k_new, v_new = attention._project_qkv(
        params, h_tok, cfg, jnp.full((1,), pos, jnp.int32))
    slot = attention.decode_slot_index(pos, L, kind)

    # Pack only the new token's row and splice it in.
    def splice(payload, bases, new):
        p_new = ops.sfp_compress_nd(new.reshape(B, 1, D).astype(dtype),
                                    container)
        payload = jax.lax.dynamic_update_slice_in_dim(
            payload, p_new.payload, slot, axis=1)
        bases = jax.lax.dynamic_update_slice_in_dim(
            bases, p_new.bases, slot, axis=1)
        return payload, bases

    k_payload, k_bases = splice(cache.k_payload, cache.k_bases, k_new)
    v_payload, v_bases = splice(cache.v_payload, cache.v_bases, v_new)

    # Decompress-on-read (fused into the attention contraction on TPU).
    k_c = ops.sfp_decompress_nd(ops.Packed(k_payload, k_bases), dtype,
                                container).reshape(B, L, KH, hd)
    v_c = ops.sfp_decompress_nd(ops.Packed(v_payload, v_bases), dtype,
                                container).reshape(B, L, KH, hd)
    o = attention.decode_attend(q, k_c, v_c, pos, cfg, kind)
    out = o.reshape(B, 1, H * hd) @ params["wo"]
    return out, PackedKV(k_payload, k_bases, v_payload, v_bases)


def pack_prefill_cache(cache_kv: attention.KVCache,
                       container: str = "sfp8") -> PackedKV:
    """Compress a prefill-produced bf16 cache in one shot."""
    B, L, KH, hd = cache_kv.k.shape
    k = ops.sfp_compress_nd(cache_kv.k.reshape(B, L, KH * hd), container)
    v = ops.sfp_compress_nd(cache_kv.v.reshape(B, L, KH * hd), container)
    return PackedKV(k_payload=k.payload, k_bases=k.bases,
                    v_payload=v.payload, v_bases=v.bases)
