"""Host-side input pipeline: device placement + background prefetch.

Batches are produced on the host (data/synthetic.py or any iterator of
numpy dicts), placed with the training step's batch shardings, and
prefetched on a background thread so host data generation overlaps device
compute — the standard single-controller JAX input pattern. At multi-host
scale each host would feed its local shard (jax.make_array_from_.
process_allgather pattern); here the single process owns all (host)
devices so placement is one device_put.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np


def place(batch: Dict[str, np.ndarray], shardings: Optional[Dict[str, Any]]
          ) -> Dict[str, jax.Array]:
    if shardings is None:
        return {k: jax.device_put(v) for k, v in batch.items()}
    return {k: jax.device_put(v, shardings.get(k)) for k, v in batch.items()}


def prefetch(it: Iterator[Dict[str, np.ndarray]],
             shardings: Optional[Dict[str, Any]] = None,
             depth: int = 2) -> Iterator[Dict[str, jax.Array]]:
    """Background-thread prefetch of ``depth`` placed batches."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        try:
            for b in it:
                if stop.is_set():
                    return
                q.put(place(b, shardings))
        except Exception as e:  # pragma: no cover
            q.put(e)
        finally:
            q.put(None)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is None:
                return
            if isinstance(item, Exception):
                raise item
            yield item
    finally:
        stop.set()
