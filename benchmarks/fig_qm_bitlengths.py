"""Fig 2/3/4: Quantum Mantissa bitlength trajectories + accuracy parity.

LM variant (per-period bitlengths over training) + CNN variant; reports
how quickly bits collapse, the final per-layer spread, and loss parity
against the unquantized baseline.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run():
    qm = common.lm_run("qm")
    base = common.lm_run("none")
    act = np.asarray([t["act"] for t in qm["qm_traj"]])   # (steps, periods)
    w = np.asarray([t["w"] for t in qm["qm_traj"]])
    out = {
        "steps_to_half": int(np.argmax(act.mean(1) < 3.5))
        if (act.mean(1) < 3.5).any() else -1,
        "final_act_mean": float(act[-1].mean()),
        "final_act_min": float(act[-1].min()),
        "final_act_max": float(act[-1].max()),
        "final_w_mean": float(w[-1].mean()),
        "xent_qm": float(np.mean([h["xent"] for h in qm["history"][-10:]])),
        "xent_base": float(np.mean([h["xent"]
                                    for h in base["history"][-10:]])),
        "act_traj_mean": act.mean(1).tolist()[::5],
    }
    out["xent_delta"] = out["xent_qm"] - out["xent_base"]
    return out


def main():
    r = run()
    print(f"QM bits: act {r['final_act_mean']:.2f} "
          f"[{r['final_act_min']:.2f}..{r['final_act_max']:.2f}], "
          f"w {r['final_w_mean']:.2f}; reached <3.5b at step "
          f"{r['steps_to_half']}")
    print(f"loss parity: qm {r['xent_qm']:.3f} vs base {r['xent_base']:.3f} "
          f"(delta {r['xent_delta']:+.3f})")
    print("mean-act-bits trajectory (every 5 steps):",
          [f"{x:.1f}" for x in r["act_traj_mean"]])
    return r


if __name__ == "__main__":
    main()
