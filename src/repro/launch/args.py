"""Shared argparse types for the launchers.

Container and policy names are validated at parse time through the same
registry parsers the lint layer uses (``codecs.validate_name`` /
``policies.validate_name``), so a typo like ``--kv-container spf8``
fails in the usage message — with the registry's did-you-mean — instead
of deep inside model construction or, worse, at trace time.
"""
from __future__ import annotations

import argparse


def container_name(value: str) -> str:
    """argparse ``type=`` for container-codec flags."""
    from repro import codecs
    try:
        codecs.validate_name(value)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))
    return value


def policy_name(value: str) -> str:
    """argparse ``type=`` for precision-policy flags ('+'-composition ok)."""
    from repro import policies
    try:
        policies.validate_name(value)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))
    return value
