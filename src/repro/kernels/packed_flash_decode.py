"""Pallas TPU kernel: fused decompress-attend flash decode.

One decode step directly over the SFP-packed KV cache — the paper's
"decompressor at the memory interface" realized at the consumer instead of
simulated: each grid step DMAs one packed KV block (payload words + the
per-128-lane shared base exponents) from HBM into VMEM, expands it inline
with the same bit logic as ``sfp_pack._unpack_kernel`` (PackFields
geometry), and feeds the online-softmax accumulator of
``flash_attention.py``. Dense geometries (``fields.dense``) store the
payload as byte-aligned bit planes (kernels/bitplane_pack.py) — the
in-kernel decompressor first re-expands the planes into payload words, so
the HBM read shrinks to the true 1 + E + K bits per value. The bf16 cache never materializes in HBM, so the
decode step's dominant read shrinks by the container ratio (~2x for sfp8)
instead of paying packed-read + bf16-write + bf16-read like the
unpack-then-attend fallback.

GQA is native to the grid: the query block for one batch row carries all
(KH, rep) head groups, so every q head of a kv-head group attends the same
unpacked block — K/V are never repeated, in HBM or VMEM.

Grid is (batch, kv_blocks) with the kv index innermost; VMEM scratch
carries the running (max, denominator, numerator) across kv blocks. Ring
slot validity (local sliding-window caches) is computed in-kernel from the
decode position (scalar, or one per batch row — continuous-batching
slots) via ``ref.decode_kv_mask``.

``paged_flash_decode`` is the continuous-batching variant: KV blocks live
in a request-agnostic pool and each row's logical blocks are gathered
through its block table *inside the grid* — the table is a scalar-prefetch
operand consumed by the BlockSpec index_maps, so each (row, block) step
DMAs its physical block straight from the HBM pool. Same recurrence, same
bit machine, same masks.

Oracles: ``ref.packed_flash_decode`` / ``ref.paged_flash_decode``
(unpack-then-attend with the same block recurrence) — bit-exact in
interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import containers
from repro.kernels import ref as kref
from repro.kernels.flash_attention import NEG_INF, _vmem_scratch

DEFAULT_BLOCK_L = 128


def vmem_estimate(*, fields: kref.PackFields, H: int, KH: int, hd: int,
                  block_l: int = DEFAULT_BLOCK_L, dtype=jnp.bfloat16) -> int:
    """Static per-grid-step VMEM footprint model, in bytes.

    Counts what the grid actually keeps resident: the double-buffered
    in/out block windows (×2 for pipelining), the persistent f32
    online-softmax scratch, and the dominant decode-body temporaries (the
    expanded f32 K/V tiles, the int32 payload words mid-expansion, and the
    f32 score/probability tile). Elementwise chains the Mosaic compiler
    fuses are not charged — this is a budget model for the static
    contract check (``repro.analysis.vmem``), not an allocator.

    The paged variant has the same window shapes (its block table and
    positions are scalar-prefetch operands living in SMEM), so one model
    covers both entry points.
    """
    D = KH * hd
    G = D // kref.GROUP
    Dp = fields.nd_payload_cols(D)
    rep = H // KH
    isz = jnp.dtype(dtype).itemsize
    psz = 1 if fields.dense else jnp.dtype(fields.payload_dtype).itemsize
    blocks = 2 * (
        4                                    # pos (1, 1) int32
        + KH * rep * hd * isz                # q block
        + 2 * block_l * Dp * psz             # k/v payload blocks
        + 2 * block_l * G                    # k/v base blocks (uint8)
        + KH * rep * hd * isz                # out block
    )
    scratch = 4 * (2 * KH * rep + KH * rep * hd)
    temps = (2 * block_l * D * 4             # expanded f32 k, v tiles
             + block_l * D * 4               # payload words as int32
             + 2 * KH * rep * block_l * 4)   # s, p score tiles
    return blocks + scratch + temps


def _decode_kernel(pos_ref, q_ref, kp_ref, kb_ref, vp_ref, vb_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_l: int, L: int, KH: int,
                   hd: int, window: Optional[int], softcap: Optional[float],
                   scale: float, fields: kref.PackFields, spec,
                   prefix_planes: Optional[int] = None):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0, 0]

    # Softmax-fused expansion: only this grid step's block_l-slot tile is
    # decompressed (ref.unpack_tile — the one inline-decompressor body both
    # decode kernels share), right before it feeds the recurrence. In the
    # draft (prefix_planes) read mode the plane slice happens in VMEM after
    # the full-block DMA; per-plane BlockSpec indexing that also shrinks
    # the HBM transfer is a Mosaic port (ROADMAP: TPU sublanes).
    k = kref.unpack_tile(kp_ref[0], kb_ref[0], fields, spec, rows=block_l,
                         KH=KH, hd=hd,
                         prefix_planes=prefix_planes)  # (block_l, KH, hd)
    v = kref.unpack_tile(vp_ref[0], vb_ref[0], fields, spec, rows=block_l,
                         KH=KH, hd=hd, prefix_planes=prefix_planes)
    q = q_ref[0].astype(jnp.float32)            # (KH, rep, hd)

    s = jnp.einsum("hgd,lhd->hgl", q, k) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    slots = ki * block_l + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, block_l), 2)
    valid = kref.decode_kv_mask(pos, L, window, slots=slots)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("hgl,lhd->hgd", p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fields", "window", "softcap",
                                             "block_l", "interpret",
                                             "prefix_planes"))
def packed_flash_decode(q: jax.Array, k_payload: jax.Array,
                        k_bases: jax.Array, v_payload: jax.Array,
                        v_bases: jax.Array, pos: jax.Array, *,
                        fields: kref.PackFields,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_l: int = DEFAULT_BLOCK_L,
                        interpret: Optional[bool] = None,
                        prefix_planes: Optional[int] = None) -> jax.Array:
    """One-token attention over an SFP-packed (B, L, KH*hd) KV cache.

    q: (B, 1, H, hd); payload (B, L, fields.nd_payload_cols(D)) — 8/16-bit
    words, or uint8 bit planes for dense geometries — and bases
    (B, L, D // 128) uint8 in the rank-preserving ``sfp_pack_nd`` /
    ``bitplane_pack_nd`` layout (D = KH * hd, D % 128 == 0). ``pos`` is
    the absolute decode position — a scalar, or (B,) for
    continuous-batching slots each at their own position; ``window`` not
    None means an L-slot ring buffer (local attention). ``prefix_planes``
    is the speculative *draft* read mode: only the leading P' payload bits
    of the same packed cache are expanded, decoded as the truncated
    geometry (``ref.prefix_fields``). Returns (B, 1, H, hd) in q's dtype.
    """
    interpret = kref.default_interpret(interpret)
    B, one, H, hd = q.shape
    assert one == 1, q.shape
    L, G = k_bases.shape[1], k_bases.shape[2]
    D = G * kref.GROUP
    KH = D // hd
    assert KH * hd == D, (D, hd)
    assert k_payload.shape[2] == fields.nd_payload_cols(D), (
        k_payload.shape, fields)
    rep = H // KH
    assert rep * KH == H, (H, KH)
    Dp = k_payload.shape[2]
    spec = containers.spec_for(jnp.dtype(q.dtype))

    # Never pad the cache arrays: padding would copy the whole packed cache
    # in HBM every step — the exact traffic this kernel exists to avoid.
    # Shrink the block to a divisor of L instead (L is the cache allocation;
    # size max_len to a block_l multiple for peak block efficiency).
    block_l = min(block_l, L)
    while L % block_l:
        block_l -= 1
    grid = (B, L // block_l)

    qg = q.reshape(B, KH, rep, hd)  # q head h shares kv head h // rep
    pos2 = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (B, 1))
    scale = 1.0 / (hd ** 0.5)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_l=block_l, L=L, KH=KH,
                          hd=hd, window=window, softcap=softcap, scale=scale,
                          fields=fields, spec=spec,
                          prefix_planes=prefix_planes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),          # per-row pos
            pl.BlockSpec((1, KH, rep, hd), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_l, Dp), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_l, G), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_l, Dp), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_l, G), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, KH, rep, hd), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, rep, hd), q.dtype),
        scratch_shapes=[
            _vmem_scratch((KH, rep, 1)),
            _vmem_scratch((KH, rep, 1)),
            _vmem_scratch((KH, rep, hd)),
        ],
        interpret=interpret,
    )(pos2, qg, k_payload, k_bases, v_payload, v_bases)
    return out.reshape(B, 1, H, hd)


def _paged_kernel(tab_ref, pos_ref, q_ref, kp_ref, kb_ref, vp_ref, vb_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, block_l: int, nb: int,
                  KH: int, hd: int, softcap: Optional[float], scale: float,
                  fields: kref.PackFields, spec,
                  prefix_planes: Optional[int] = None):
    """One (batch row, logical KV block) step over the paged pool.

    The DMA gather already happened: the grid spec's index_map routed this
    step's physical block (``tab_ref[b, j]``) into kp/kb/vp/vb, so the body
    is the contiguous decode kernel's on logical slots — the recurrence,
    masking and bit machine are shared, which is what makes paged decode
    bit-exact against the contiguous kernel over the same logical cache.
    """
    b = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    L = nb * block_l

    # Same softmax-fused per-tile expansion as the contiguous kernel — one
    # shared decompressor body (ref.unpack_tile) for both grids.
    k = kref.unpack_tile(kp_ref[0], kb_ref[0], fields, spec, rows=block_l,
                         KH=KH, hd=hd, prefix_planes=prefix_planes)
    v = kref.unpack_tile(vp_ref[0], vb_ref[0], fields, spec, rows=block_l,
                         KH=KH, hd=hd, prefix_planes=prefix_planes)
    q = q_ref[0].astype(jnp.float32)

    s = jnp.einsum("hgd,lhd->hgl", q, k) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # Masking is on *logical* slots: logical blocks past the row's
    # allocation point at the reserved trash block, and their slots exceed
    # pos — an exact no-op in the recurrence (p == 0, alpha == 1).
    slots = ki * block_l + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, block_l), 2)
    valid = kref.decode_kv_mask(pos, L, None, slots=slots)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("hgl,lhd->hgd", p, v)
    m_scr[...] = m_new

    @pl.when(ki == nb - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fields", "softcap",
                                             "interpret", "prefix_planes"))
def paged_flash_decode(q: jax.Array, k_payload: jax.Array,
                       k_bases: jax.Array, v_payload: jax.Array,
                       v_bases: jax.Array, tables: jax.Array,
                       pos: jax.Array, *, fields: kref.PackFields,
                       softcap: Optional[float] = None,
                       interpret: Optional[bool] = None,
                       prefix_planes: Optional[int] = None) -> jax.Array:
    """One-token attention over a *paged* SFP-packed KV block pool.

    The serving engine's continuous-batching decode step: pool parts are
    (P_blocks, block_l, D) payload / (P_blocks, block_l, D // 128) bases
    shared by every request; ``tables`` (B, nb) int32 maps each batch
    row's logical KV blocks to physical pool blocks, and ``pos`` (B,) is
    each row's absolute decode position. The block table is a scalar-
    prefetch operand, so the *gather happens inside the kernel grid*: each
    (b, j) step's index_map DMAs physical block ``tables[b, j]`` straight
    from the HBM pool into VMEM — no contiguous per-request cache ever
    materializes. Logical blocks past a row's allocation must point at a
    valid (trash) physical block; position masking makes them exact
    no-ops. Global attention only (local ring buffers are window-bounded
    and stay per-slot contiguous). Returns (B, 1, H, hd) in q's dtype.

    Oracle: ``ref.paged_flash_decode`` — bit-exact in interpret mode.
    """
    from jax.experimental.pallas import tpu as pltpu

    interpret = kref.default_interpret(interpret)

    B, one, H, hd = q.shape
    assert one == 1, q.shape
    n_phys, block_l, Dp = k_payload.shape
    G = k_bases.shape[2]
    D = G * kref.GROUP
    KH = D // hd
    assert KH * hd == D, (D, hd)
    assert Dp == fields.nd_payload_cols(D), (k_payload.shape, fields)
    rep = H // KH
    assert rep * KH == H, (H, KH)
    nb = tables.shape[1]
    spec = containers.spec_for(jnp.dtype(q.dtype))

    qg = q.reshape(B, KH, rep, hd)
    pos1 = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    tables = tables.astype(jnp.int32)
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (tables, pos) — available to index_maps
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, KH, rep, hd),
                         lambda b, j, tab, pos: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_l, Dp),
                         lambda b, j, tab, pos: (tab[b, j], 0, 0)),
            pl.BlockSpec((1, block_l, G),
                         lambda b, j, tab, pos: (tab[b, j], 0, 0)),
            pl.BlockSpec((1, block_l, Dp),
                         lambda b, j, tab, pos: (tab[b, j], 0, 0)),
            pl.BlockSpec((1, block_l, G),
                         lambda b, j, tab, pos: (tab[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KH, rep, hd),
                               lambda b, j, tab, pos: (b, 0, 0, 0)),
        scratch_shapes=[
            _vmem_scratch((KH, rep, 1)),
            _vmem_scratch((KH, rep, 1)),
            _vmem_scratch((KH, rep, hd)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, block_l=block_l, nb=nb, KH=KH,
                          hd=hd, softcap=softcap, scale=scale, fields=fields,
                          spec=spec, prefix_planes=prefix_planes),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, rep, hd), q.dtype),
        interpret=interpret,
    )(tables, pos1, qg, k_payload, k_bases, v_payload, v_bases)
    return out.reshape(B, 1, H, hd)
