"""gemma2-2b [dense] — alternating local/global attention, logit softcaps.

[arXiv:2408.00118; hf] 26L, d_model=2304, 8H (GQA kv=4), d_ff=9216,
vocab=256000.
"""
from repro.configs.base import ArchConfig, GLOBAL, LOCAL, register

GEMMA2_2B = register(ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256_000,
    period=(LOCAL, GLOBAL),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    emb_scale=True,
    source="arXiv:2408.00118 (Gemma 2); assignment spec",
))
