"""Pallas TPU kernel: mantissa truncation Q(M, n) (paper eq. 5).

The quantizer datapath of the paper's compressor (§V-A) as a VPU kernel:
bitcast -> mask the low (m - n) mantissa bits -> bitcast back, tiled over
(block_rows, 128) VMEM blocks. ``n`` arrives as a scalar (traced per step —
Quantum Mantissa / BitChop update it each batch), carried in SMEM.

Validated against repro.kernels.ref.mantissa_truncate in interpret mode
(CPU) across shape/dtype sweeps; on TPU the same kernel lowers natively.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import containers
from repro.kernels.ref import default_interpret

LANES = 128
DEFAULT_BLOCK_ROWS = 256


def _quant_kernel(n_ref, x_ref, o_ref, *, spec: containers.FloatSpec):
    x = x_ref[...]
    n = jnp.clip(n_ref[0, 0], 0, spec.man_bits)
    u = jax.lax.bitcast_convert_type(x, spec.int_dtype)
    drop = (spec.man_bits - n).astype(spec.int_dtype)
    one = jnp.asarray(1, spec.int_dtype)
    low = jnp.left_shift(one, drop) - one
    keep = jnp.asarray(spec.man_mask, spec.int_dtype) ^ low
    mask = jnp.asarray(
        ~spec.man_mask & ((1 << spec.total_bits) - 1), spec.int_dtype) | keep
    o_ref[...] = jax.lax.bitcast_convert_type(u & mask, spec.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def mantissa_quantize(x: jax.Array, n: jax.Array, *,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Truncate mantissas of ``x`` to ``n`` bits (scalar int32, traced ok)."""
    interpret = default_interpret(interpret)
    spec = containers.spec_for(x)
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % (block_rows * LANES)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    x2 = flat.reshape(-1, LANES)
    rows = x2.shape[0]
    grid = (rows // block_rows,)

    out = pl.pallas_call(
        functools.partial(_quant_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # scalar n
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(jnp.asarray(n, jnp.int32).reshape(1, 1), x2)

    out = out.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape)
