"""SPMD tests run in a subprocess (needs 8 host devices; the main test
process must keep the default single-device view for everything else)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

WORKER = Path(__file__).parent / "worker.py"


def _run(name, timeout=420):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(WORKER), name],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert f"PASS {name}" in r.stdout


@pytest.mark.parametrize("name", ["sharded_embed", "pipeline",
                                  "grad_compress", "elastic"])
def test_spmd_fast(name):
    _run(name)


@pytest.mark.slow
def test_spmd_sharded_train_step_matches_single_device():
    _run("sharded_vs_single", timeout=560)
