"""LR schedules. Step-decay boundaries are exposed so BitChop can hold full
precision around LR changes (paper §IV-B: "Full precision is used during LR
changes")."""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Schedule:
    kind: str = "cosine"            # 'cosine' | 'step' | 'constant'
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    boundaries: Tuple[int, ...] = ()  # step-decay drop points (x0.1)
    min_lr_frac: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(self.warmup_steps, 1), 1.0)
        if self.kind == "constant":
            lr = jnp.asarray(self.base_lr, jnp.float32)
        elif self.kind == "step":
            lr = jnp.asarray(self.base_lr, jnp.float32)
            for b in self.boundaries:
                lr = jnp.where(step >= b, lr * 0.1, lr)
        else:  # cosine
            frac = jnp.clip((s - self.warmup_steps)
                            / max(self.total_steps - self.warmup_steps, 1),
                            0.0, 1.0)
            cos = 0.5 * (1 + jnp.cos(math.pi * frac))
            lr = self.base_lr * (self.min_lr_frac + (1 - self.min_lr_frac) * cos)
        return lr * warm

    def lr_changed(self, step: jax.Array) -> jax.Array:
        """True at step-decay boundaries (drives BitChop's precision hold)."""
        if not self.boundaries:
            return jnp.zeros((), bool)
        b = jnp.asarray(self.boundaries, jnp.int32)
        return jnp.any(step == b)
