"""BitChop: history-based network-wide mantissa bitlength control.

Paper §IV-B. Observes the per-batch training loss, maintains an exponential
moving average (eq. 8) and a noise threshold epsilon (EMA of |L - Mavg|),
and once per period (N = 1 batch) decides to shrink / keep / grow the
single network-wide mantissa bitlength (eq. 9):

    n <- n - 1   if Mavg > L + eps     (loss clearly improving)
    n <- n       if |Mavg - L| <= eps
    n <- n + 1   if Mavg < L - eps     (loss clearly regressing)

The controller is a pure function over a small state pytree so it can live
on-device inside a jitted train step (the paper implements it as a tiny
hardware block fed by a loss register — the software analogue is a fused
scalar update). Full precision is forced for a window after learning-rate
changes (the paper: "Full precision is used during LR changes").
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BitChopConfig:
    alpha: float = 0.1            # loss EMA decay (eq. 8)
    eps_alpha: float = 0.1        # EMA decay for the |L - Mavg| noise proxy
    eps_scale: float = 1.0        # epsilon = eps_scale * err_ema
    max_bits: int = 7             # container mantissa bits (7 bf16, 23 fp32)
    min_bits: int = 0
    period: int = 1               # batches per decision period (paper: N=1)
    warmup_steps: int = 8         # observe-only steps before first decision
    lr_change_hold: int = 100     # full-precision steps after an LR change


class BitChopState(NamedTuple):
    mavg: jax.Array        # fp32 scalar, EMA of loss
    err_ema: jax.Array     # fp32 scalar, EMA of |L - mavg|
    n: jax.Array           # int32 scalar, current mantissa bitlength
    step: jax.Array        # int32 scalar
    hold_until: jax.Array  # int32 scalar; full precision while step < hold_until


def init(cfg: BitChopConfig) -> BitChopState:
    return BitChopState(
        mavg=jnp.asarray(0.0, jnp.float32),
        err_ema=jnp.asarray(0.0, jnp.float32),
        n=jnp.asarray(cfg.max_bits, jnp.int32),
        step=jnp.asarray(0, jnp.int32),
        hold_until=jnp.asarray(0, jnp.int32),
    )


def update(state: BitChopState, loss, cfg: BitChopConfig,
           lr_changed=False) -> BitChopState:
    """One observe/decide step (eq. 8 + 9). Safe to call inside jit."""
    loss = jnp.asarray(loss, jnp.float32)
    first = state.step == 0
    mavg0 = jnp.where(first, loss, state.mavg)
    err = jnp.abs(loss - mavg0)
    err_ema = jnp.where(
        first, err, state.err_ema + cfg.eps_alpha * (err - state.err_ema)
    )
    # eq. (8): Mavg <- Mavg + alpha * (L - Mavg)
    mavg = mavg0 + cfg.alpha * (loss - mavg0)

    eps = cfg.eps_scale * err_ema
    decide = (
        (state.step >= cfg.warmup_steps)
        & (state.step >= state.hold_until)
        & ((state.step % cfg.period) == 0)
    )
    # eq. (9)
    shrink = mavg0 > loss + eps
    grow = mavg0 < loss - eps
    delta = jnp.where(shrink, -1, jnp.where(grow, 1, 0)).astype(jnp.int32)
    n = jnp.where(decide, state.n + delta, state.n)
    n = jnp.clip(n, cfg.min_bits, cfg.max_bits)

    lr_changed = jnp.asarray(lr_changed, bool)
    hold_until = jnp.where(
        lr_changed, state.step + cfg.lr_change_hold, state.hold_until
    ).astype(jnp.int32)
    # During the hold window run at full container precision.
    n = jnp.where(state.step < hold_until, cfg.max_bits, n)

    return BitChopState(
        mavg=mavg,
        err_ema=err_ema,
        n=n.astype(jnp.int32),
        step=state.step + 1,
        hold_until=hold_until,
    )


def effective_bits(state: BitChopState, cfg: BitChopConfig) -> jax.Array:
    """Bitlength to apply this step (full precision inside hold windows)."""
    return jnp.where(state.step < state.hold_until, cfg.max_bits, state.n)
