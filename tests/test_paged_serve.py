"""Paged serving engine: block pool invariants, scheduler mechanics
(admission gating, preemption, slot recycling, streaming), paged-vs-
contiguous token equivalence under continuous batching, and policy-aware
container resolution from checkpoint metadata."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, configs
from repro.configs.base import reduced
from repro.kernels import ops
from repro.models.model import DecoderModel
from repro.serve import engine, kvcache, precision
from repro.serve.pool import TRASH_BLOCK, BlockPool, blocks_for
from repro.serve.scheduler import Request, Scheduler


def _model(name, container, **over):
    cfg = dataclasses.replace(reduced(configs.get(name)), dtype="float32",
                              **over)
    return cfg, DecoderModel(cfg, kv_container=container)


def _prompts(rng, cfg, sizes):
    return [rng.randint(0, cfg.vocab, size=s).astype(np.int32)
            for s in sizes]


# ---------------------------------------------------------------------------
# Block pool
# ---------------------------------------------------------------------------


def test_pool_alloc_free_trash_invariants():
    pool = BlockPool(num_blocks=4, max_slots=2, max_logical=3, block_l=16)
    assert blocks_for(0, 16) == 0 and blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1 and blocks_for(17, 16) == 2
    assert pool.free_blocks == 4
    assert pool.alloc_upto(0, 33)  # 3 blocks
    assert pool.used_blocks == 3
    assert TRASH_BLOCK not in pool.tables[0, :3]
    assert pool.tables[0, 2] != TRASH_BLOCK
    assert not pool.alloc_upto(1, 17)   # needs 2, only 1 free
    assert pool.free_blocks == 1        # failed alloc takes nothing
    assert pool.alloc_upto(1, 16)
    assert pool.free_blocks == 0
    assert pool.free_slot(0) == 3
    assert pool.free_blocks == 3
    assert (pool.tables[0] == TRASH_BLOCK).all()
    # growing an existing allocation is idempotent below the watermark
    assert pool.alloc_upto(1, 8) and pool.used_blocks == 1
    with pytest.raises(ValueError):
        pool.alloc_upto(1, 16 * 3 + 1)  # > max_logical


def test_pool_hardening_rejects_misuse():
    """The allocator raises on double free, out-of-range slots, and
    quarantine of blocks a slot does not own — aliasing bugs surface at
    the call site instead of corrupting another request's blocks."""
    pool = BlockPool(num_blocks=4, max_slots=2, max_logical=3, block_l=16)
    with pytest.raises(ValueError, match="slot 2 out of range"):
        pool.alloc_upto(2, 16)
    with pytest.raises(ValueError, match="slot -1 out of range"):
        pool.free_slot(-1)
    with pytest.raises(ValueError, match="n_tokens"):
        pool.alloc_upto(0, -5)
    with pytest.raises(KeyError, match="double free"):
        pool.free_slot(0)               # never allocated
    assert pool.alloc_upto(0, 20)       # 2 blocks
    pool.verify_invariants()
    with pytest.raises(ValueError, match="not owned"):
        pool.free_slot(0, quarantine=(99,))
    with pytest.raises(ValueError, match="trash block"):
        pool.free_slot(0, quarantine=(TRASH_BLOCK,))
    owned = pool.owned_ids()
    assert pool.free_slot(0, quarantine=owned[:1]) == 1
    with pytest.raises(KeyError, match="double free"):
        pool.free_slot(0)
    pool.verify_invariants()
    # quarantined blocks are neither free nor owned until rehabilitated
    assert pool.free_blocks == 3 and pool.quarantined_blocks == owned[:1]
    with pytest.raises(ValueError, match="not quarantined"):
        pool.rehabilitate(owned[1])
    with pytest.raises(ValueError, match="never pooled"):
        pool.rehabilitate(TRASH_BLOCK)
    pool.rehabilitate(owned[0])
    assert pool.free_blocks == 4
    pool.verify_invariants()


def test_pool_admission_gate_keeps_decode_headroom():
    pool = BlockPool(num_blocks=3, max_slots=2, max_logical=4, block_l=16)
    assert pool.can_admit(47)       # prompt + first token fit 3 blocks
    assert not pool.can_admit(48)   # block-aligned prompt needs a 4th
    pool.alloc_upto(0, 17)          # 2 blocks used, 1 free
    assert pool.can_admit(15) and not pool.can_admit(16)
    # Full residency must be reachable: a one-block pool admits a request
    # whose prompt + first token fit one block (B=1 bench regression).
    tiny = BlockPool(num_blocks=1, max_slots=1, max_logical=1, block_l=128)
    assert tiny.can_admit(120) and not tiny.can_admit(128)


# ---------------------------------------------------------------------------
# Scheduler-driven generation == per-request engine.generate
# ---------------------------------------------------------------------------


def test_scheduler_matches_generate_staggered():
    """>= 8 requests with mixed prompt/output lengths and staggered
    arrivals, decoded as a continuous batch over the sfp8 pool, must emit
    exactly the tokens per-request generate emits at the same budget
    (fused interpret kernels on both sides — bit-exact packed paths)."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    sizes = [5, 9, 5, 12, 9, 5, 7, 9]
    news = [4, 3, 5, 2, 4, 3, 2, 3]
    reqs = [Request(uid=i, prompt=p, max_new=n, arrival=0.3 * i)
            for i, (p, n) in enumerate(zip(_prompts(rng, cfg, sizes), news))]
    ops.force_backend("interpret")
    try:
        eng = engine.PagedEngine(model, params, max_slots=3, max_len=128)
        sched = Scheduler(eng)
        clock = {"t": 0.0}

        def now():
            clock["t"] += 0.25
            return clock["t"]

        out = sched.run(reqs, now_fn=now)
        assert sched.stats.preemptions == 0  # full-residency pool
        assert sched.stats.admitted == len(reqs)
        for r in reqs:
            want = engine.generate(model, params,
                                   jnp.asarray(r.prompt)[None],
                                   max_new=r.max_new, max_len=eng.max_len)
            np.testing.assert_array_equal(out[r.uid],
                                          np.asarray(want.tokens[0]))
    finally:
        ops.force_backend(None)
    # Slots were recycled: more requests than slots, all finished.
    assert len(out) == len(reqs) > eng.max_slots


def test_scheduler_matches_generate_gqa4():
    """GQA 4 (one kv head shared by four q heads) through the whole
    engine: grouped q heads share gathered pool blocks in the paged
    kernel; tokens must equal per-request generate."""
    cfg, model = _model("mistral-large-123b", "sfp16", n_kv_heads=1,
                        head_dim=128)
    assert cfg.n_heads // cfg.n_kv_heads == 4
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    reqs = [Request(uid=i, prompt=p, max_new=3)
            for i, p in enumerate(_prompts(rng, cfg, [5, 8]))]
    ops.force_backend("interpret")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        out = Scheduler(eng).run(reqs)
        for r in reqs:
            want = engine.generate(model, params,
                                   jnp.asarray(r.prompt)[None],
                                   max_new=r.max_new, max_len=eng.max_len)
            np.testing.assert_array_equal(out[r.uid],
                                          np.asarray(want.tokens[0]))
    finally:
        ops.force_backend(None)


@pytest.mark.slow
def test_scheduler_matches_generate_ring_wrap_and_block_crossing():
    """gemma3 (5x local + global): decode past the sliding window wraps
    the per-slot packed rings, and one long prompt crosses the 128-row
    pool block boundary mid-decode — tokens must still equal generate."""
    cfg, model = _model("gemma3-12b", "sfp16", window=16)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    reqs = [
        Request(uid=0, prompt=_prompts(rng, cfg, [8])[0], max_new=20),
        Request(uid=1, prompt=_prompts(rng, cfg, [126])[0], max_new=5),
        Request(uid=2, prompt=_prompts(rng, cfg, [5])[0], max_new=3),
    ]
    ops.force_backend("interpret")
    try:
        eng = engine.PagedEngine(model, params, max_slots=3, max_len=160)
        out = Scheduler(eng).run(reqs)
        for r in reqs:
            want = engine.generate(model, params,
                                   jnp.asarray(r.prompt)[None],
                                   max_new=r.max_new, max_len=eng.max_len)
            np.testing.assert_array_equal(out[r.uid],
                                          np.asarray(want.tokens[0]))
    finally:
        ops.force_backend(None)


# ---------------------------------------------------------------------------
# Scheduler mechanics (ref backend: fast, no bit-exactness needed)
# ---------------------------------------------------------------------------


def _run_ref(model, params, reqs, **eng_kw):
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, **eng_kw)
        sched = Scheduler(eng)
        out = sched.run(reqs)
    finally:
        ops.force_backend(None)
    return eng, sched, out


def test_scheduler_slot_recycling_and_streaming():
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    stream = []
    reqs = [Request(uid=i, prompt=p, max_new=3,
                    on_token=lambda uid, tok, done:
                    stream.append((uid, tok, done)))
            for i, p in enumerate(_prompts(rng, cfg, [4] * 5))]
    eng, sched, out = _run_ref(model, params, reqs, max_slots=2,
                               max_len=128)
    assert sorted(out) == [0, 1, 2, 3, 4]
    assert all(len(v) == 3 for v in out.values())
    assert sched.stats.finished == 5 and sched.stats.admitted == 5
    # Streaming: per uid, tokens arrive in order and exactly the last
    # carries done=True; the stream equals the final results.
    per = {}
    for uid, tok, done in stream:
        per.setdefault(uid, []).append((tok, done))
    for uid, toks in per.items():
        assert [t for t, _ in toks] == out[uid].tolist()
        assert [d for _, d in toks] == [False, False, True]
    # Pool fully drained after the run — everything recycled.
    assert eng.pool.used_blocks == 0


def test_scheduler_admission_gated_on_free_blocks():
    """With a pool that fits one request's blocks (plus headroom), the
    second request must queue until the first finishes."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    reqs = [Request(uid=i, prompt=p, max_new=2)
            for i, p in enumerate(_prompts(rng, cfg, [4, 4]))]
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128,
                                 num_blocks=1)
        sched = Scheduler(eng)
        for r in reqs:
            sched.submit(r)
        first = sched.step()
        # Only request 0 admitted: it holds the pool's single block, so
        # request 1 queues despite a free slot.
        assert {uid for uid, _, _ in first} == {0}
        assert sched.stats.admitted == 1 and len(sched.pending) == 1
        out = sched.run()
    finally:
        ops.force_backend(None)
    assert all(len(out[i]) == 2 for i in (0, 1))
    assert sched.stats.preemptions == 0


def test_scheduler_preempts_youngest_and_recovers():
    """Two long requests crossing a block boundary with a 3-block pool:
    the younger is evicted (recompute), re-admitted after the older
    drains, and still emits its full budget — with every token recorded
    across the preemption."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(4)
    reqs = [Request(uid=i, prompt=p, max_new=6)
            for i, p in enumerate(_prompts(rng, cfg, [126, 126]))]
    eng, sched, out = _run_ref(model, params, reqs, max_slots=2,
                               max_len=256, num_blocks=3)
    assert sched.stats.preemptions >= 1
    assert all(len(out[i]) == 6 for i in (0, 1))
    assert eng.pool.used_blocks == 0


def test_single_oversized_request_raises():
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(5)
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=256,
                                 num_blocks=1)
        # Prompt + first token can never fit the pool: rejected up front.
        req = Request(uid=0, prompt=_prompts(rng, cfg, [129])[0], max_new=2)
        with pytest.raises(RuntimeError, match="cannot ever admit"):
            Scheduler(eng).run([req])
        # Admissible but outgrows the pool mid-decode with nobody left to
        # preempt: raises at the growth point instead of spinning.
        req2 = Request(uid=1, prompt=_prompts(rng, cfg, [126])[0],
                       max_new=8)
        with pytest.raises(RuntimeError, match="cannot hold"):
            Scheduler(eng).run([req2])
    finally:
        ops.force_backend(None)


def test_submit_validates_requests_up_front():
    """Malformed requests raise at submit() with the offending field
    named — never deep inside prefill with a shape error."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128,
                                 num_blocks=1)
        sched = Scheduler(eng)
        good = np.arange(4, dtype=np.int32)
        with pytest.raises(ValueError, match="prompt"):
            sched.submit(Request(uid=0, prompt=good[None], max_new=2))
        with pytest.raises(ValueError, match="prompt"):
            sched.submit(Request(uid=0, prompt=good[:0], max_new=2))
        with pytest.raises(ValueError, match="max_new"):
            sched.submit(Request(uid=0, prompt=good, max_new=0))
        # a prompt the pool can never hold is refused at submit, not
        # after it reaches the head of the queue
        big = np.arange(129, dtype=np.int32)
        with pytest.raises(RuntimeError, match="cannot ever admit"):
            sched.submit(Request(uid=0, prompt=big, max_new=2))
        assert not sched.pending  # nothing malformed was enqueued
        sched.submit(Request(uid=1, prompt=good, max_new=2))
        assert len(sched.pending) == 1
    finally:
        ops.force_backend(None)


def test_paged_engine_rejects_raw_and_unfuseable_codecs():
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    params = DecoderModel(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_container"):
        engine.PagedEngine(DecoderModel(cfg), params)
    with pytest.raises(ValueError, match="fixed-width"):
        engine.PagedEngine(DecoderModel(cfg, kv_container="gecko8"), params)


def test_generate_memoizes_compiled_functions():
    """Repeated generate() calls with the same budget must reuse the
    compiled prefill and decode-loop callables (no per-call re-jit)."""
    cfg, model = _model("mistral-large-123b", None)
    model = DecoderModel(cfg)  # raw cache is fine for this
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.arange(6, dtype=np.int32))[None]
    r1 = engine.generate(model, params, prompt, max_new=3)
    cache = model.__dict__[engine._CACHE_ATTR]
    keys1 = set(cache)
    fns1 = dict(cache)
    r2 = engine.generate(model, params, prompt, max_new=3)
    assert set(cache) == keys1
    for k in keys1:
        assert cache[k] is fns1[k]
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  np.asarray(r2.tokens))
    # the memo must not immortalize the model: it lives on the instance
    # (an ordinary garbage cycle), not in any module-level registry.
    import gc
    import weakref
    ref = weakref.ref(model)
    del model, cache, fns1, r1, r2
    gc.collect()
    assert ref() is None


# ---------------------------------------------------------------------------
# Decode bursts
# ---------------------------------------------------------------------------


def _burst_stream_run(model, params, reqs, burst, stream=None,
                      speculate=None, draft_planes=None, **eng_kw):
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, **eng_kw)
        sched = Scheduler(
            eng, on_token=None if stream is None else
            (lambda uid, tok, done: stream.append((uid, tok, done))))
        out = sched.run(reqs, burst=burst, speculate=speculate,
                        draft_planes=draft_planes)
    finally:
        ops.force_backend(None)
    return eng, sched, out


def test_burst_token_streams_identical_to_single_step():
    """K-token bursts are a pacing change, not a semantic one: per-uid
    token streams (values, order, done flags) must equal burst=1 exactly,
    including requests that hit their budget mid-burst."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(8)
    # max_new 2/5/9 against burst=4: finishes land mid-burst, at a burst
    # boundary, and across two bursts.
    sizes, news = [4, 6, 5], [2, 5, 9]

    def reqs():
        rng2 = np.random.RandomState(8)
        return [Request(uid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(
                    zip(_prompts(rng2, cfg, sizes), news))]

    stream1, streamK = [], []
    _, s1, out1 = _burst_stream_run(model, params, reqs(), 1, stream1,
                                    max_slots=3, max_len=128)
    engK, sK, outK = _burst_stream_run(model, params, reqs(), 4, streamK,
                                       max_slots=3, max_len=128)
    assert set(out1) == set(outK)
    for uid in out1:
        np.testing.assert_array_equal(out1[uid], outK[uid])

    def per_uid(stream):
        per = {}
        for uid, tok, done in stream:
            per.setdefault(uid, []).append((tok, done))
        return per

    assert per_uid(stream1) == per_uid(streamK)
    # Same number of jitted decode steps in total — bursts only chunk
    # them (max remaining budget of 9 after the admission token -> 8
    # decode rounds either way) — and the engine agrees with the
    # scheduler's accounting.
    assert s1.stats.decode_steps == sK.stats.decode_steps == 8
    assert engK.decode_steps == sK.stats.decode_steps
    assert sK.stats.emitted_tokens == sum(news)


def test_burst_clamps_to_budget_and_capacity():
    """A burst never outruns max_len (hard) or the largest remaining
    token budget (efficiency): with max_new=3 everywhere, burst=32 must
    execute exactly the 2 decode steps burst=1 would."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(9)
    reqs = [Request(uid=i, prompt=p, max_new=3)
            for i, p in enumerate(_prompts(rng, cfg, [4, 7]))]
    eng, sched, out = _burst_stream_run(model, params, reqs, 32,
                                        max_slots=2, max_len=128)
    assert all(len(out[i]) == 3 for i in (0, 1))
    assert sched.stats.decode_steps == 2
    assert eng.decode_steps == 2
    # near the max_len wall the hard clamp takes over: a prompt of 126
    # in a 128-budget engine leaves exactly 2 positions.
    rng = np.random.RandomState(9)
    req = [Request(uid=0, prompt=_prompts(rng, cfg, [126])[0], max_new=8)]
    eng2, sched2, out2 = _burst_stream_run(model, params, req, 32,
                                           max_slots=1, max_len=128)
    assert len(out2[0]) == 2  # admission token + 2 steps, capped by len
    assert sched2.stats.decode_steps == 2


def test_burst_defers_admission_and_preemption_to_boundaries():
    """Preemption happens only while setting up a burst (never inside
    one), and a slot freed mid-burst is refilled at the next boundary —
    bursts still drain everything with streams equal to burst=1."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))

    def reqs():
        rng = np.random.RandomState(10)
        return [Request(uid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(
                    zip(_prompts(rng, cfg, [126, 126, 4]), [6, 6, 4]))]

    # 3-block pool, two block-crossing requests: the younger is evicted
    # at a burst boundary and recovers, exactly as with burst=1.
    _, s1, out1 = _burst_stream_run(model, params, reqs(), 1,
                                    max_slots=2, max_len=256, num_blocks=3)
    _, sK, outK = _burst_stream_run(model, params, reqs(), 4,
                                    max_slots=2, max_len=256, num_blocks=3)
    assert sK.stats.preemptions >= 1
    assert set(out1) == set(outK)
    for uid in out1:
        np.testing.assert_array_equal(out1[uid], outK[uid])


def test_burst_finished_slot_recycled_at_next_boundary():
    """A request finishing mid-burst frees its slot during the burst's
    replay; the very next step's admission must reuse that slot (no idle
    step in between) — and the recycled streams equal burst=1."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))

    def reqs():
        rng = np.random.RandomState(12)
        return [Request(uid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(
                    zip(_prompts(rng, cfg, [4, 4, 4]), [2, 9, 3]))]

    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        slot_of = {}
        sched = Scheduler(eng)
        sched.on_token = lambda uid, tok, done: slot_of.setdefault(
            uid, next(st.slot for st in sched.running.values()
                      if st.req.uid == uid))
        for r in reqs():
            sched.submit(r)
        steps = []
        while not sched.idle:
            steps.append(sched.step(burst=4))
        _, s1, out1 = _burst_stream_run(model, params, reqs(), 1,
                                        max_slots=2, max_len=128)
    finally:
        ops.force_backend(None)
    # uid 0 (max_new=2) finishes inside the first 4-token burst...
    done_step = {u: i for i, em in enumerate(steps)
                 for u, _, d in em if d}
    first_step = {}
    for i, em in enumerate(steps):
        for u, _, _ in em:
            first_step.setdefault(u, i)
    assert done_step[0] == 0
    # ...and uid 2 takes its slot at the very next burst boundary
    assert first_step[2] == 1
    assert slot_of[2] == slot_of[0]
    for u in out1:
        np.testing.assert_array_equal(sched.finished[u], out1[u])

    # preemption during a burst composes with the recycling: with a
    # 3-block pool the younger crosser is evicted mid-run at a burst
    # boundary while the short request recycles the finisher's slot —
    # everything still drains token-identical to burst=1.
    def reqs2():
        rng = np.random.RandomState(13)
        return [Request(uid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(
                    zip(_prompts(rng, cfg, [126, 126, 4]), [6, 6, 3]))]

    _, sA, outA = _burst_stream_run(model, params, reqs2(), 1,
                                    max_slots=2, max_len=256, num_blocks=3)
    _, sB, outB = _burst_stream_run(model, params, reqs2(), 4,
                                    max_slots=2, max_len=256, num_blocks=3)
    assert sB.stats.preemptions >= 1
    assert sB.stats.admitted > sB.stats.finished == 3  # readmissions
    for uid in outA:
        np.testing.assert_array_equal(outA[uid], outB[uid])


def test_burst_matches_generate_interpret():
    """Bit-exact end to end: burst-decoded tokens over the fused
    interpret kernels equal per-request contiguous generate."""
    cfg, model = _model("mistral-large-123b", "sfp-m2e4")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(11)
    reqs = [Request(uid=i, prompt=p, max_new=n)
            for i, (p, n) in enumerate(
                zip(_prompts(rng, cfg, [5, 9]), [4, 6]))]
    ops.force_backend("interpret")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        out = Scheduler(eng).run(reqs, burst=3)
        for r in reqs:
            want = engine.generate(model, params,
                                   jnp.asarray(r.prompt)[None],
                                   max_new=r.max_new, max_len=eng.max_len)
            np.testing.assert_array_equal(out[r.uid],
                                          np.asarray(want.tokens[0]))
    finally:
        ops.force_backend(None)


# ---------------------------------------------------------------------------
# Self-speculative decoding
# ---------------------------------------------------------------------------


def test_speculate_token_streams_identical_to_single_step():
    """Greedy self-speculation is a pacing change, not a semantic one:
    the full-width verify corrects every draft divergence, so per-uid
    streams (values, order, done flags) must equal burst=1 exactly — and
    drafting reads the *same* pool blocks, so peak pool usage must equal
    a burst run of the same horizon (zero additional pool bytes)."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    sizes, news = [4, 6, 5], [2, 5, 9]

    def reqs():
        rng = np.random.RandomState(8)
        return [Request(uid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(
                    zip(_prompts(rng, cfg, sizes), news))]

    stream1, streamS = [], []
    _, s1, out1 = _burst_stream_run(model, params, reqs(), 1, stream1,
                                    max_slots=3, max_len=128)
    engB, _, _ = _burst_stream_run(model, params, reqs(), 4,
                                   max_slots=3, max_len=128)
    engS, sS, outS = _burst_stream_run(model, params, reqs(), 1, streamS,
                                       speculate=4,
                                       max_slots=3, max_len=128)
    assert set(out1) == set(outS)
    for uid in out1:
        np.testing.assert_array_equal(out1[uid], outS[uid])

    def per_uid(stream):
        per = {}
        for uid, tok, done in stream:
            per.setdefault(uid, []).append((tok, done))
        return per

    assert per_uid(stream1) == per_uid(streamS)
    # Draft + verify touch only blocks a K-burst would also own: the
    # same-horizon burst run is the pool-bytes ceiling.
    assert engS.pool.stats().peak_used == engB.pool.stats().peak_used
    # The speculative run drafted something and the verify accepted a
    # nonzero prefix somewhere (greedy drafts at 7 of 8 payload bits
    # agree with full width most steps).
    assert sS.stats.spec_rounds >= 1 and sS.stats.drafted > 0
    assert sS.stats.draft_accepted > 0


def test_speculate_acceptance_bookkeeping():
    """Counters and per-request terminal records stay consistent:
    accepted + rejected == drafted globally, per-uid drafted/accepted
    sum to the scheduler totals, and the engine's model-step accounting
    charges K draft + K verify steps per round."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(21)
    reqs = [Request(uid=i, prompt=p, max_new=n)
            for i, (p, n) in enumerate(
                zip(_prompts(rng, cfg, [4, 7]), [6, 9]))]
    eng, sched, out = _burst_stream_run(model, params, reqs, 1,
                                        speculate=3,
                                        max_slots=2, max_len=128)
    s = sched.stats
    assert s.spec_rounds >= 1
    assert s.draft_accepted + s.draft_rejected == s.drafted > 0
    res = [sched.results[r.uid] for r in reqs]
    assert all(r.status == "ok" for r in res)
    assert sum(r.drafted for r in res) == s.drafted
    assert sum(r.draft_accepted for r in res) == s.draft_accepted
    assert all(0 <= r.draft_accepted <= r.drafted for r in res)
    # one spec round = K draft + K verify jitted model steps (K may be
    # clamped below 3 near the budget wall, but always pairs up)
    assert eng.decode_steps == s.decode_steps
    assert s.decode_steps % 2 == 0
    assert s.decode_steps <= 6 * s.spec_rounds
    assert s.emitted_tokens == sum(len(v) for v in out.values())


def test_speculate_dense_geometry_and_draft_depth():
    """Dense bit-plane pools speculate too, across the legal draft-depth
    range: the minimum prefix (dexp_bits + 2) and the widest
    (payload - 1) both stream token-identical to burst=1."""
    cfg, model = _model("mistral-large-123b", "sfp-m3e5")
    params = model.init(jax.random.PRNGKey(0))

    def reqs():
        rng = np.random.RandomState(5)
        return [Request(uid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(
                    zip(_prompts(rng, cfg, [5, 8]), [5, 7]))]

    _, _, out1 = _burst_stream_run(model, params, reqs(), 1,
                                   max_slots=2, max_len=128)
    fields = codecs.get("sfp-m3e5").pack_fields(cfg.compute_dtype)
    lo, hi = fields.dexp_bits + 2, fields.payload_bits - 1
    assert lo <= hi
    for dp in {lo, hi}:
        _, sched, outS = _burst_stream_run(model, params, reqs(), 1,
                                           speculate=2, draft_planes=dp,
                                           max_slots=2, max_len=128)
        for uid in out1:
            np.testing.assert_array_equal(out1[uid], outS[uid])
        assert sched.stats.drafted > 0


def test_speculate_validates_inputs():
    """Bad speculation knobs fail loudly at the host boundary: a
    non-positive K, a draft depth outside the container's legal prefix
    range, and speculation over a raw (uncontainered) cache all raise."""
    cfg, model = _model("mistral-large-123b", "sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    reqs = [Request(uid=0, prompt=_prompts(rng, cfg, [4])[0], max_new=2)]
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128)
        with pytest.raises(ValueError):
            Scheduler(eng).run(reqs, speculate=0)
        fields = codecs.get("sfp8").pack_fields(cfg.compute_dtype)
        for bad in (fields.dexp_bits + 1, fields.payload_bits + 1):
            with pytest.raises(ValueError):
                eng.validate_draft_planes(bad)
    finally:
        ops.force_backend(None)


# ---------------------------------------------------------------------------
# Policy-aware precision
# ---------------------------------------------------------------------------


def test_container_for_decision_mapping():
    # Learned decisions now deploy as *dense* bit-plane geometries: the
    # payload is exactly 1 + dexp + man bits (an 8-bit budget like m3e4
    # keeps the fixed-lane word layout as the fast path).
    assert precision.container_for_decision(3.0, 4.0) == "sfp-m3e4"
    assert precision.container_for_decision(2.3, 3.7) == "sfp-m3e4"
    assert precision.container_for_decision(7.0, 5.0) == "sfp-m7e5"
    # exponent clamps into the delta field range
    assert precision.container_for_decision(3.0, 8.0) == "sfp-m3e7"
    assert precision.container_for_decision(1.0, 1.0) == "sfp-m1e2"
    f8 = codecs.get("sfp-m3e4").pack_fields(jnp.bfloat16)
    assert f8.payload_bits == 8 and not f8.dense  # fast path survives
    f7 = codecs.get("sfp-m2e4").pack_fields(jnp.bfloat16)
    assert (f7.payload_bits, f7.dense) == (7, True)


def test_parametric_sfp_codec_resolves_and_roundtrips():
    codec = codecs.get("sfp8-m3e4")  # sfp8 by another name
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 128), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(codec.roundtrip(x), np.float32),
        np.asarray(codecs.get("sfp8").roundtrip(x), np.float32))
    # learned geometry narrower than sfp16's default
    c2 = codecs.get("sfp16-m5e3")
    f = c2.pack_fields(jnp.float32)
    assert (f.man_keep, f.dexp_bits, f.payload_bits) == (5, 3, 16)
    y = c2.roundtrip(x)
    assert np.isfinite(np.asarray(y)).all()
    with pytest.raises(KeyError):
        codecs.get("sfp12-m3e4")  # only 8/16-bit payload words exist


def test_container_from_checkpoint_decision_stamp(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": np.zeros((2, 2), np.float32)}
    mgr.save(1, state, extra={"policy": "qm+qe", "container": "sfp8",
                              "decision": {"man_bits": 4.2,
                                           "exp_bits": 5.6}})
    name = precision.container_from_checkpoint(str(tmp_path))
    assert name == "sfp-m5e6"
    # the derived container is servable end-to-end: a dense 12-bit payload
    f = codecs.get(name).pack_fields(jnp.float32)
    assert f.payload_bits == 12 and f.man_keep == 5 and f.dexp_bits == 6
    assert f.dense

    # legacy checkpoints without a decision fall back to the run container
    mgr2 = CheckpointManager(str(tmp_path / "legacy"))
    mgr2.save(1, state, extra={"policy": "qm", "container": "sfp16"})
    assert precision.container_from_checkpoint(
        str(tmp_path / "legacy")) == "sfp16"
    mgr3 = CheckpointManager(str(tmp_path / "bare"))
    mgr3.save(1, state)
    assert (precision.container_from_checkpoint(str(tmp_path / "bare"))
            == codecs.DEFAULT_CONTAINER)
    with pytest.raises(FileNotFoundError):
        precision.container_from_checkpoint(str(tmp_path / "empty"))


def test_paged_engine_serves_policy_derived_container():
    """End to end: a pool built from a policy-derived parametric geometry
    generates tokens identical to contiguous generate with that codec."""
    cfg, model = _model("mistral-large-123b",
                        precision.container_for_decision(6.0, 5.0))
    assert model.kv_container == "sfp-m6e5"  # dense 12-bit payload
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(6)
    reqs = [Request(uid=i, prompt=p, max_new=3)
            for i, p in enumerate(_prompts(rng, cfg, [5, 7]))]
    ops.force_backend("interpret")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        out = Scheduler(eng).run(reqs)
        for r in reqs:
            want = engine.generate(model, params,
                                   jnp.asarray(r.prompt)[None],
                                   max_new=r.max_new, max_len=eng.max_len)
            np.testing.assert_array_equal(out[r.uid],
                                          np.asarray(want.tokens[0]))
    finally:
        ops.force_backend(None)
