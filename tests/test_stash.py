"""sfp_scan: the compressed-stash scan must be gradient-exact vs a plain
differentiable scan when the codec is identity, and numerically close with
real containers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.core import stash


def _layer(carry, x):
    h, extras = carry
    h2 = jnp.tanh(h @ x["w"]) + h
    extras = extras + jnp.sum(x["w"]) * 0.0
    return (h2, extras), {"mean": jnp.mean(h2)}


def _setup(P=3, d=16, B=4):
    k = jax.random.PRNGKey(0)
    h0 = jax.random.normal(jax.random.fold_in(k, 1), (B, d))
    ws = jax.random.normal(jax.random.fold_in(k, 2), (P, d, d)) * 0.3
    return h0, {"w": ws}


def test_identity_codec_matches_direct_scan():
    h0, xs = _setup()

    def via_sfp(h0, xs):
        (h, e), aux = stash.plain_scan(_layer, (h0, jnp.zeros(())), xs)
        return jnp.sum(h ** 2)

    def direct(h0, xs):
        def body(h, x):
            return jnp.tanh(h @ x["w"]) + h, None
        h, _ = jax.lax.scan(body, h0, xs)
        return jnp.sum(h ** 2)

    v1, g1 = jax.value_and_grad(via_sfp, argnums=(0, 1))(h0, xs)
    v2, g2 = jax.value_and_grad(direct, argnums=(0, 1))(h0, xs)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1[1]["w"]), np.asarray(g2[1]["w"]),
                               rtol=1e-5)


def test_extras_carry_gradients_flow():
    h0, xs = _setup()

    def f(h0, xs):
        def layer(carry, x):
            h, extras = carry
            h2 = jnp.tanh(h @ x["w"])
            return (h2, extras + jnp.mean(x["w"] ** 2)), {}
        (h, e), _ = stash.plain_scan(layer, (h0, jnp.zeros(())), xs)
        return e  # loss purely through the extras carry

    g = jax.grad(f, argnums=1)(h0, xs)
    assert float(jnp.sum(jnp.abs(g["w"]))) > 0


def test_compressed_stash_forward_uses_quantized_values():
    h0, xs = _setup(d=128)

    codec = codecs.get("sfp8")

    def compress(h, x):
        return codec.pack(h.astype(jnp.bfloat16))

    def decompress(c, x):
        return codec.unpack(c).astype(jnp.float32)

    (h, e), _ = stash.sfp_scan(_layer, compress, decompress,
                               (h0, jnp.zeros(())), xs)
    # quantized path differs from exact but stays close
    (h_ref, _), _ = stash.plain_scan(_layer, (h0, jnp.zeros(())), xs)
    err = float(jnp.max(jnp.abs(h - h_ref)))
    scale = float(jnp.max(jnp.abs(h_ref)))
    assert 0 < err < 0.5 * scale  # coarse 3-bit containers, bounded drift


def test_compressed_stash_grads_close_to_exact():
    h0, xs = _setup(d=128)

    codec = codecs.get("sfp16")

    def compress(h, x):
        return codec.pack(h.astype(jnp.bfloat16))

    def decompress(c, x):
        return codec.unpack(c).astype(jnp.float32)

    def f(h0, xs):
        (h, e), _ = stash.sfp_scan(_layer, compress, decompress,
                                   (h0, jnp.zeros(())), xs)
        return jnp.mean(h ** 2)

    def f_ref(h0, xs):
        (h, e), _ = stash.plain_scan(_layer, (h0, jnp.zeros(())), xs)
        return jnp.mean(h ** 2)

    g = jax.grad(f, argnums=1)(h0, xs)["w"]
    gr = jax.grad(f_ref, argnums=1)(h0, xs)["w"]
    cos = float(jnp.sum(g * gr) / (jnp.linalg.norm(g) * jnp.linalg.norm(gr)))
    assert cos > 0.99


def test_stash_grad_hook_receives_cotangents():
    h0, xs = _setup()
    seen = {}

    def hook(dh, c, x):
        return {"w": jnp.ones_like(x["w"]) * jnp.mean(dh)}

    def f(h0, xs):
        (h, e), _ = stash.sfp_scan(_layer, stash.identity_compress,
                                   stash.identity_decompress,
                                   (h0, jnp.zeros(())), xs, stash_grad=hook)
        return jnp.sum(h)

    g_with = jax.grad(f, argnums=1)(h0, xs)["w"]
    g_without = jax.grad(
        lambda h0, xs: jnp.sum(stash.plain_scan(
            _layer, (h0, jnp.zeros(())), xs)[0][0]), argnums=1)(h0, xs)["w"]
    assert not np.allclose(np.asarray(g_with), np.asarray(g_without))
