"""Per-architecture smoke: a reduced same-family config runs one forward +
train step on CPU with finite outputs and the right shapes (the full
configs are exercised only via the dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, policies
from repro.configs.base import reduced
from repro.models.model import DecoderModel
from repro.optim.schedule import Schedule
from repro.train import step as step_mod

ARCHS = [c.name for c in configs.ASSIGNED]

pytestmark = pytest.mark.slow  # ~3 min of reduced-config train steps


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(configs.get(arch))
    model = DecoderModel(cfg, policies.get("qm", container="sfp8"))
    tc = step_mod.TrainConfig(
        schedule=Schedule(total_steps=10, warmup_steps=1),
        num_microbatches=2)
    state = step_mod.init_state(model, jax.random.PRNGKey(0), tc)

    B, S = 4, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if cfg.prefix_tokens:
        batch["cond_embeddings"] = jnp.zeros(
            (B, cfg.prefix_tokens, cfg.d_model), cfg.compute_dtype)

    # forward shapes
    run = model.run_state(jax.random.PRNGKey(2))
    logits, _ = model.forward(state.params, tokens, run,
                              cond_embeddings=batch.get("cond_embeddings"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one jitted train step
    train_step = jax.jit(step_mod.make_train_step(model, tc))
    state2, metrics = train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduced(configs.get(arch))
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 96)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.asarray(cfg.prefix_tokens, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_param_counts_match_configs():
    """Sanity: constructed parameter trees match ArchConfig.param_count."""
    for arch in ("gemma2-2b", "olmoe-1b-7b", "mamba2-370m"):
        cfg = configs.get(arch)
        model = DecoderModel(cfg)
        shapes = model.param_shapes()
        import math
        n = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
        approx = cfg.param_count()
        assert abs(n - approx) / approx < 0.05, (arch, n, approx)


def test_full_config_values():
    """The assigned table's exact numbers."""
    g = configs.get("gemma3-12b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab) == (48, 3840, 16, 8, 15360, 262144)
    assert g.period == ("local",) * 5 + ("global",)
    m = configs.get("mistral-large-123b")
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab) == (88, 12288, 96, 8, 28672, 32768)
    o = configs.get("olmoe-1b-7b")
    assert (o.n_experts, o.top_k) == (64, 8)
    p = configs.get("phi3.5-moe-42b-a6.6b")
    assert (p.n_experts, p.top_k) == (16, 2)
    r = configs.get("recurrentgemma-9b")
    assert r.period == ("rglru", "rglru", "local") and r.n_layers == 38
    assert len(r.remainder) == 2
    mm = configs.get("mamba2-370m")
    assert mm.ssm_state == 128 and mm.period == ("ssd",)


def test_cells_matrix():
    from repro.configs.base import cells_for
    total = sum(len(cells_for(c)) for c in configs.ASSIGNED)
    # 10 archs x 3 shapes + 2 long-context cells
    assert total == 32
