"""DEPRECATED: legacy SFP policy enum — use ``repro.policies`` instead.

This module used to own the mode-string dispatch (``MODE_QM`` if/else
ladders) that decided how stashed tensors were quantized. That surface is
now the precision-policy registry: ``repro.policies.get("qm")`` etc.,
composable (``"qm+qe"``) and extensible via ``policies.register``.

Only the ``SFPPolicy`` dataclass survives, as a thin shim: constructing
one still works, and every consumer (``DecoderModel``, ``CNN``) coerces
it through :meth:`SFPPolicy.to_policy`. New code should build registry
policies directly.
"""
from __future__ import annotations

import dataclasses
import warnings

# Legacy mode names, kept for back-compat constructors only.
MODE_NONE = "none"
MODE_QM = "qm"
MODE_BITCHOP = "bitchop"
MODE_STATIC = "static"


@dataclasses.dataclass(frozen=True)
class SFPPolicy:
    """Legacy policy spec. Use ``repro.policies.get(mode, ...)`` instead."""

    mode: str = MODE_NONE
    container: str = "sfp8"        # 'sfp8' | 'sfp16' | 'bit_exact'
    static_act_bits: int = 3
    static_weight_bits: int = 7
    quantize_weights: bool = True
    gecko_mode: str = "delta"
    gamma: float = 0.1

    @property
    def enabled(self) -> bool:
        return self.mode != MODE_NONE

    def to_policy(self):
        """Resolve through the precision-policy registry."""
        from repro import policies
        warnings.warn(
            "core.sfp.SFPPolicy is deprecated; use "
            f"repro.policies.get({self.mode!r}, ...) instead.",
            DeprecationWarning, stacklevel=2)
        return policies.get(
            self.mode, _strict=False, container=self.container,
            quantize_weights=self.quantize_weights, gamma=self.gamma,
            static_act_bits=self.static_act_bits,
            static_weight_bits=self.static_weight_bits)
