"""The static checker itself: every seeded fixture violation fires on
exactly its marked line, the real tree is clean, the jaxpr contracts
hold on the live entry points, and the launchers reject bad names at
argparse time with the registry's did-you-mean."""
import json
import pathlib

import numpy as np
import pytest

from repro.analysis import astlint, contracts, names, vmem
from repro.analysis.findings import Finding, load_baseline, split_by_baseline
from repro.analysis.runner import REPO_ROOT, main

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "analysis"
VIOLATIONS = sorted(FIXTURES.glob("viol_*.py"))


def _markers(path: pathlib.Path):
    """{(line, rule)} promised by the fixture's ``# LINT: rule`` markers."""
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if "# LINT:" in line:
            out.add((i, line.split("# LINT:")[1].strip()))
    return out


def _rel(path: pathlib.Path) -> str:
    return path.resolve().relative_to(REPO_ROOT).as_posix()


# ---------------------------------------------------------------------------
# layer 1: AST lints
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", VIOLATIONS, ids=lambda p: p.stem)
def test_seeded_violations_fire_exactly(fixture):
    assert _markers(fixture), f"{fixture.name} has no # LINT markers"
    found = astlint.lint_source(fixture.read_text(), _rel(fixture))
    assert {(f.line, f.rule) for f in found} == _markers(fixture)


def test_every_rule_has_a_fixture():
    covered = {rule for fx in VIOLATIONS for _, rule in _markers(fx)}
    assert covered == {"host-sync-in-jit", "stale-interpret-flag",
                       "force-backend-leak", "traced-truthiness",
                       "container-name", "policy-name", "float64",
                       "obs-no-hot-path-sync"}


def test_clean_fixture_is_clean():
    fx = FIXTURES / "clean_ok.py"
    assert astlint.lint_source(fx.read_text(), _rel(fx)) == []


def test_real_tree_is_lint_clean():
    assert astlint.run_lints([REPO_ROOT / "src" / "repro"], REPO_ROOT) == []


def test_did_you_mean():
    assert "did you mean 'sfp8'" in names.check_container("spf8")
    assert names.check_container("sfp-m2e4") is None
    assert "did you mean 'qm'" in names.check_policy("qm+qx")
    assert "duplicate" in names.check_policy("qm+qm")
    assert names.check_policy("qm+qe") is None


# ---------------------------------------------------------------------------
# findings / baseline mechanics
# ---------------------------------------------------------------------------


def test_waiver_key_ignores_line_numbers(tmp_path):
    f = Finding(rule="r", path="p.py", line=12, scope="fn", message="m")
    g = Finding(rule="r", path="p.py", line=99, scope="fn", message="m")
    assert f.key == g.key
    base = tmp_path / "b.json"
    base.write_text(json.dumps({"waivers": [
        {"key": f.key, "reason": "known host boundary"},
        {"key": "r:gone.py:old", "reason": "stale entry"}]}))
    waivers = load_baseline(base)
    active, waived, stale = split_by_baseline([f, g], waivers)
    assert active == [] and len(waived) == 2
    assert stale == ["r:gone.py:old"]


def test_waiver_without_reason_rejected(tmp_path):
    base = tmp_path / "b.json"
    base.write_text(json.dumps({"waivers": [{"key": "r:p.py:fn"}]}))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(base)


def test_cli_exit_codes(tmp_path):
    clean = FIXTURES / "clean_ok.py"
    bad = FIXTURES / "viol_force_backend.py"
    assert main(["--no-contracts", "--paths", str(clean)]) == 0
    assert main(["--no-contracts", "--paths", str(bad)]) == 1
    # A justified waiver turns the failure into a pass.
    key = f"force-backend-leak:{_rel(bad)}:setup_model"
    base = tmp_path / "waive.json"
    base.write_text(json.dumps({"waivers": [
        {"key": key, "reason": "fixture exercises the rule"}]}))
    assert main(["--no-contracts", "--paths", str(bad),
                 "--baseline", str(base)]) == 0


@pytest.mark.parametrize("fixture", VIOLATIONS, ids=lambda p: p.stem)
def test_cli_nonzero_on_each_fixture(fixture):
    assert main(["--no-contracts", "--paths", str(fixture)]) == 1


# ---------------------------------------------------------------------------
# layer 2: jaxpr contracts on the real entry points
# ---------------------------------------------------------------------------


def test_precision_leak_quick_geometries():
    assert contracts.check_precision_leak(contracts.QUICK_GEOMETRIES) == []


def test_buffer_geometry_quick_geometries():
    import dataclasses

    from repro import configs
    from repro.configs.base import reduced
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    assert contracts.check_buffer_geometry(contracts.QUICK_GEOMETRIES,
                                           cfg) == []


@pytest.mark.slow
def test_donation_audit():
    assert contracts.check_donation(include_train=True) == []


@pytest.mark.slow
def test_recompile_guard():
    assert contracts.check_recompile() == []


@pytest.mark.slow
def test_recompile_guard_burst_memo_across_k():
    _, _, _, eng = contracts._tiny_serving("sfp8")
    S = eng.max_slots
    toks, pos = np.zeros(S, np.int32), np.zeros(S, np.int32)
    for k in (2, 3, 2, 3):
        eng.decode_burst(toks, pos, k)
    assert set(eng._bursts) == {2, 3}
    for k, fn in eng._bursts.items():
        assert fn._cache_size() == 1, f"K={k} burst re-traced"


@pytest.mark.slow
def test_recompile_guard_scheduler_burst_path():
    from repro.serve.scheduler import Request, Scheduler
    cfg, _, _, eng = contracts._tiny_serving("sfp8")
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab, size=6).astype(np.int32),
                    max_new=5) for i in range(3)]
    sched = Scheduler(eng)
    out = sched.run(reqs, burst=2)
    assert len(out) == 3
    # The whole trace — admissions, bursts, retirements — holds exactly
    # one K=2 burst executable and one decode-step executable.
    assert set(eng._bursts) <= {2}
    for fn in eng._bursts.values():
        assert fn._cache_size() == 1
    assert eng._step._cache_size() in (0, 1)


def test_vmem_quick_geometries():
    assert vmem.check_vmem(contracts.QUICK_GEOMETRIES) == []


# ---------------------------------------------------------------------------
# launcher argparse validation (same registry parsers)
# ---------------------------------------------------------------------------


def test_serve_parser_rejects_bad_container(capsys):
    from repro.launch import serve
    ap = serve.build_parser()
    with pytest.raises(SystemExit):
        ap.parse_args(["--arch", "gemma2-2b", "--kv-container", "spf8"])
    assert "did you mean 'sfp8'" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        ap.parse_args(["--arch", "gemma2-2b", "--kv-container", "sfp8",
                       "--degraded-container", "gecko9"])
    args = ap.parse_args(["--arch", "gemma2-2b", "--kv-container", "sfp8",
                          "--degraded-container", "sfp-m1e2"])
    assert args.kv_container == "sfp8"


def test_train_parser_rejects_bad_names(capsys):
    from repro.launch import train
    ap = train.build_parser()
    with pytest.raises(SystemExit):
        ap.parse_args(["--arch", "gemma2-2b", "--policy", "qm+qx"])
    assert "did you mean 'qm'" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        ap.parse_args(["--arch", "gemma2-2b", "--container", "spf8"])
    args = ap.parse_args(["--arch", "gemma2-2b", "--policy", "qm+qe",
                          "--container", "sfp-m2e4"])
    assert args.policy == "qm+qe" and args.container == "sfp-m2e4"
