"""Precision-policy registry: resolution, composition, bit-identity with
the pre-registry QM/BitChop implementations, state round-trips, and
end-to-end training under the new policies (qe / bitwave / qm+qe)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, policies
from repro.configs.base import reduced
from repro.core import bitchop, containers as C, quantum_mantissa as qm
from repro.checkpoint.manager import CheckpointManager
from repro.data import synthetic
from repro.models.model import DecoderModel
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.train import step as step_mod

DIMS = policies.ScopeDims(n_periods=3, n_rem=2, man_bits=7, exp_bits=8)


# ---------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------


def test_registry_names_and_resolution():
    assert {"none", "static", "qm", "qe", "bitchop", "bitwave"} <= set(
        policies.names())
    for name in policies.names():
        assert policies.get(name).name == name


def test_unknown_and_duplicate_raise():
    with pytest.raises(KeyError):
        policies.get("quantum-flux")
    with pytest.raises(KeyError):
        policies.get("qm+qm")
    with pytest.raises(TypeError):
        policies.get("bitchop", gamma=0.5)  # not a bitchop knob


def test_kwargs_route_to_matching_subpolicy():
    p = policies.get("qm+bitchop", gamma=0.7, warmup_steps=3,
                     container="sfp8")
    by = {s.name: s for s in p.policies}
    assert by["qm"].gamma == 0.7 and by["bitchop"].warmup_steps == 3
    assert all(s.container == "sfp8" for s in p.policies)


def test_composite_properties_and_decision():
    p = policies.get("qm+qe")
    assert p.name == "qm+qe"
    assert p.adapts_exponent and p.has_stash_grad and p.quantizes_weights
    st = p.init_state(DIMS)
    view = p.forward_view(st.learn, p.control_view(st.ctrl, DIMS), DIMS)
    sl = jax.tree.map(lambda a: a[0], p.scan_slices(view, DIMS))
    d = p.act_decision(sl, jax.random.PRNGKey(0), DIMS)
    assert int(d.man_bits) == 7 and int(d.exp_bits) == 8  # init = full


def test_legacy_sfppolicy_shim_coerces():
    from repro.core import sfp
    with pytest.deprecated_call():
        pol = policies.coerce(sfp.SFPPolicy(mode="qm", container="sfp16"))
    assert isinstance(pol, policies.QMPolicy) and pol.container == "sfp16"
    with pytest.deprecated_call():
        assert isinstance(policies.coerce(sfp.SFPPolicy()),
                          policies.NonePolicy)
    assert isinstance(policies.coerce(None), policies.NonePolicy)
    assert isinstance(policies.coerce("bitwave"), policies.BitWavePolicy)


# ---------------------------------------------------------------------
# Bit-identity with the pre-refactor implementations
# ---------------------------------------------------------------------


def test_qm_act_decision_bit_identical_to_legacy_formula():
    """The registry QM must reproduce the pre-refactor stash decision:
    stochastic_bitlength(n, fold_in(key, 7), man_bits)."""
    pol = policies.get("qm")
    st = pol.init_state(DIMS)
    learn = {k: v - jnp.arange(v.size, dtype=jnp.float32) * 0.7
             for k, v in st.learn.items()}
    view = pol.forward_view(learn, {}, DIMS)
    slices = pol.scan_slices(view, DIMS)
    for i in range(DIMS.n_periods):
        for salt in range(5):
            key = jax.random.fold_in(jax.random.PRNGKey(3), salt)
            d = pol.act_decision(jax.tree.map(lambda a: a[i], slices),
                                 key, DIMS)
            legacy = C.stochastic_bitlength(
                learn["act"][i], jax.random.fold_in(key, 7), DIMS.man_bits)
            assert int(d.man_bits) == int(legacy)
            assert int(d.exp_bits) == DIMS.exp_bits
    # remainder scopes slice act_rem
    r = pol.rem_slice(view, 1, DIMS)
    key = jax.random.PRNGKey(9)
    d = pol.act_decision(r, key, DIMS)
    legacy = C.stochastic_bitlength(
        learn["act_rem"][1], jax.random.fold_in(key, 7), DIMS.man_bits)
    assert int(d.man_bits) == int(legacy)


def test_qm_weight_quantize_bit_identical_to_qm_quantize():
    pol = policies.get("qm")
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 16), jnp.bfloat16)
    n = jnp.asarray(3.4, jnp.float32)
    key = jax.random.PRNGKey(4)
    got = pol.quantize_weight(w, {"act": n, "w": n}, key, DIMS)
    want = qm.qm_quantize(w, n, key)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_bitchop_observe_bit_identical_to_legacy_update():
    pol = policies.get("bitchop", warmup_steps=2)
    cfg = bitchop.BitChopConfig(warmup_steps=2, max_bits=DIMS.man_bits)
    ctrl = pol.init_state(DIMS).ctrl
    legacy = bitchop.init(cfg)
    losses = [3.0, 2.5, 2.6, 2.0, 1.5, 1.6, 1.4, 1.2]
    for i, l in enumerate(losses):
        ctrl = pol.observe(ctrl, jnp.asarray(l), i == 4, DIMS)
        legacy = bitchop.update(legacy, jnp.asarray(l), cfg, lr_changed=i == 4)
    for a, b in zip(ctrl, legacy):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    got = pol.control_view(ctrl, DIMS)["act"]
    want = bitchop.effective_bits(legacy, cfg)
    assert int(got) == int(want)


def test_qm_update_learn_matches_legacy_sgd():
    pol = policies.get("qm", lr=0.1, min_bits=0.0)
    st = pol.init_state(DIMS)
    grads = jax.tree.map(
        lambda a: jnp.full_like(a, 12.3), st.learn)  # big grad -> clip
    new = pol.update_learn(st.learn, grads, DIMS)
    for k in st.learn:
        want = jnp.clip(st.learn[k] - 0.1 * grads[k], 0.0, 7.0)
        np.testing.assert_array_equal(np.asarray(new[k]), np.asarray(want))


# ---------------------------------------------------------------------
# QE / BitWave mechanics
# ---------------------------------------------------------------------


def test_qe_decision_draws_reduced_exponent():
    pol = policies.get("qe")
    learn = {"act": jnp.full((3,), 4.0, jnp.float32),
             "w": jnp.full((3,), 4.0, jnp.float32),
             "act_rem": jnp.zeros((2,)), "w_rem": jnp.zeros((2,))}
    sl = jax.tree.map(lambda a: a[0], pol.scan_slices(learn, DIMS))
    d = pol.act_decision(sl, jax.random.PRNGKey(0), DIMS)
    assert int(d.exp_bits) == 4 and int(d.man_bits) == DIMS.man_bits
    # min clamp: learned value below the floor still yields >= 2 bits
    r = pol.rem_slice(learn, 0, DIMS)
    d = pol.act_decision(r, jax.random.PRNGKey(1), DIMS)
    assert int(d.exp_bits) >= C.MIN_EXP_BITS


def test_bitwave_shrinks_both_fields_on_improving_loss():
    pol = policies.get("bitwave", warmup_steps=2)
    ctrl = pol.init_state(DIMS).ctrl
    for i in range(12):
        ctrl = pol.observe(ctrl, jnp.asarray(3.0 - 0.25 * i), False, DIMS)
    assert int(ctrl.n_man) < DIMS.man_bits
    assert int(ctrl.n_exp) < DIMS.exp_bits
    view = pol.control_view(ctrl, DIMS)
    d = pol.act_decision(view, jax.random.PRNGKey(0), DIMS)
    assert int(d.man_bits) == int(ctrl.n_man)
    assert int(d.exp_bits) == int(ctrl.n_exp)


def test_bitwave_holds_full_precision_after_lr_change():
    pol = policies.get("bitwave", warmup_steps=1, lr_change_hold=5)
    ctrl = pol.init_state(DIMS).ctrl
    for i in range(8):
        ctrl = pol.observe(ctrl, jnp.asarray(3.0 - 0.3 * i), False, DIMS)
    shrunk = (int(ctrl.n_man), int(ctrl.n_exp))
    assert shrunk < (DIMS.man_bits, DIMS.exp_bits)
    ctrl = pol.observe(ctrl, jnp.asarray(0.5), True, DIMS)  # LR change
    view = pol.control_view(ctrl, DIMS)
    assert int(view["act"]) == DIMS.man_bits
    assert int(view["act_e"]) == DIMS.exp_bits


def test_modeled_footprint_reports_exponent_savings():
    pol = policies.get("bitwave")
    st = pol.init_state(DIMS)
    ctrl = st.ctrl._replace(n_man=jnp.asarray(2, jnp.int32),
                            n_exp=jnp.asarray(4, jnp.int32))
    fp = policies.modeled_footprint(
        pol, policies.PolicyState(learn=st.learn, ctrl=ctrl), DIMS)
    assert fp["bits_per_value"] == 1 + 2 + 4
    assert fp["vs_bf16"] == pytest.approx(7 / 16)


# ---------------------------------------------------------------------
# Train-step integration (reduced config, a few steps each)
# ---------------------------------------------------------------------


def _train(policy, n_steps, arch="gemma2-2b", seed=0, **red):
    cfg = reduced(configs.get(arch), **red)
    model = DecoderModel(cfg, policy)
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=5e-3),
        schedule=Schedule(total_steps=n_steps, warmup_steps=2, base_lr=5e-3))
    step = jax.jit(step_mod.make_train_step(model, tc))
    state = step_mod.init_state(model, jax.random.PRNGKey(seed), tc)
    dcfg = synthetic.SyntheticConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=4, seed=seed)
    corpus = synthetic.MarkovCorpus(dcfg)
    hist = []
    for i in range(n_steps):
        b = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
        state, m = step(state, b)
        hist.append({k: float(np.asarray(v)) for k, v in m.items()})
    return model, state, hist


@pytest.mark.slow
def test_qe_trains_and_bits_fall():
    pol = policies.get("qe", container="bit_exact", gamma=1.0, lr=0.4)
    model, state, hist = _train(pol, 25)
    assert np.isfinite(hist[-1]["xent"])
    assert hist[-1]["qe_act_mean"] < 8.0  # penalty pushes exponent bits down
    assert float(jnp.min(state.pstate.learn["act"])) >= C.MIN_EXP_BITS


@pytest.mark.slow
def test_bitwave_trains_and_adjusts_both():
    pol = policies.get("bitwave", container="sfp8", warmup_steps=4)
    model, state, hist = _train(pol, 25)
    assert np.isfinite(hist[-1]["xent"])
    bits = [(h["bw_man_bits"], h["bw_exp_bits"]) for h in hist]
    assert min(b[0] for b in bits) < 7 or min(b[1] for b in bits) < 8


@pytest.mark.slow
def test_qm_plus_qe_composes_and_checkpoint_roundtrips(tmp_path):
    pol = policies.get("qm+qe", container="bit_exact", gamma=0.5, lr=0.3)
    model, state, hist = _train(pol, 20)
    assert np.isfinite(hist[-1]["xent"])
    # both learned fields move in one run
    assert hist[-1]["qm_act_mean"] < 7.0
    assert hist[-1]["qe_act_mean"] < 8.0

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(20, state, extra={"policy": pol.name})
    assert mgr.read_extra(20) == {"policy": pol.name}
    like = jax.tree.map(jnp.zeros_like, state)
    back = mgr.restore(20, like)
    for a, b in zip(jax.tree.leaves(state.pstate), jax.tree.leaves(back.pstate)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restoring into a different policy's state tree fails loudly
    other = step_mod.init_state(
        DecoderModel(reduced(configs.get("gemma2-2b")),
                     policies.get("bitwave")),
        jax.random.PRNGKey(0), step_mod.TrainConfig())
    with pytest.raises(ValueError, match="precision policy"):
        mgr.restore(20, other)


def test_policy_state_checkpoint_roundtrip_fast(tmp_path):
    """Controller ints + learned floats survive the generic manager."""
    pol = policies.get("qm+bitwave")
    st = pol.init_state(DIMS)
    ctrl = dict(st.ctrl)
    ctrl["bitwave"] = ctrl["bitwave"]._replace(n_exp=jnp.asarray(3, jnp.int32))
    st = policies.PolicyState(learn=st.learn, ctrl=ctrl)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, st, extra={"policy": pol.name})
    back = mgr.restore(1, jax.tree.map(jnp.zeros_like, st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(back.ctrl["bitwave"].n_exp) == 3
