"""Learned-bitlength policies: Quantum Mantissa and Quantum Exponent.

Both learn one real-valued bitlength parameter per tensor scope (per
period x {act, w}, plus remainder layers) jointly with the model: the
data gradient flows through the stochastic quantizer's custom VJP
(core.quantum_mantissa / core.quantum_exponent), a footprint-weighted
penalty (eq. 7) pushes bits down, and the policy applies a plain SGD step
clipped to the container's range. ``policies.get("qm+qe")`` composes them
to learn both fields at once — the paper's headline 4.74x configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import containers, quantum_exponent as qe, \
    quantum_mantissa as qm
from repro.policies import base

# Per-scope Bernoulli-draw salts: act draws fold 7 (the pre-registry
# constant — decisions must stay bit-identical for "qm"), QE act draws
# fold 8 so composed policies decorrelate.
QM_ACT_SALT = 7
QE_ACT_SALT = 8


@dataclasses.dataclass(frozen=True)
class _LearnedBitsPolicy(base.Policy):
    """Shared machinery: state layout, SGD update, penalty, estimators."""

    gamma: float = 0.1            # regularizer strength (eq. 7)
    init_bits: Optional[float] = None  # None -> container's full field
    lr: float = 0.01              # SGD learning rate for the bitlengths
    min_bits: float = 0.0
    # step thresholds at which gamma decays 10x (paper: epochs 0/30/60)
    gamma_decay_steps: Tuple[int, ...] = ()

    # subclass hooks ----------------------------------------------------
    def _max_bits(self, dims: base.ScopeDims) -> int:
        raise NotImplementedError

    def _min_bits(self, dims: base.ScopeDims) -> float:
        return self.min_bits

    def _truncate(self, x, n_int):
        raise NotImplementedError

    def _quantize(self, x, n, key):
        raise NotImplementedError

    # state -------------------------------------------------------------

    def init_state(self, dims: base.ScopeDims) -> base.PolicyState:
        bits = (float(self._max_bits(dims)) if self.init_bits is None
                else float(self.init_bits))
        full = lambda n: jnp.full((n,), bits, jnp.float32)
        learn = {"act": full(dims.n_periods), "w": full(dims.n_periods),
                 "act_rem": full(dims.n_rem), "w_rem": full(dims.n_rem)}
        return base.PolicyState(learn=learn, ctrl={})

    def forward_view(self, learn, cview, dims):
        return learn

    def scan_slices(self, view, dims):
        return {"act": view["act"], "w": view["w"]}

    def rem_slice(self, view, i, dims):
        return {"act": view["act_rem"][i], "w": view["w_rem"][i]}

    # quantizers ---------------------------------------------------------

    def quantize_act(self, x, pslice, key, dims):
        return self._quantize(x, pslice["act"], key)

    def quantize_weight(self, w, pslice, key, dims):
        return self._quantize(w, pslice["w"], key)

    def stash_grad(self, dh, h_q, pslice, dims):
        """Importance-weighted bitlength estimate from the realized stash.

        Hardware cannot see bits it never stored (DESIGN.md D8): compare
        the stash against re-truncation at floor(n) — the mass that a
        one-bit-tighter budget would lose — and scale by 1/frac, the
        inverse probability the extra bit was drawn.
        """
        lo = self._min_bits(dims)
        nf = jnp.clip(pslice["act"], lo, float(self._max_bits(dims)))
        floor_n = jnp.floor(nf).astype(jnp.int32)
        frac = nf - floor_n.astype(jnp.float32)
        q_lo = self._truncate(h_q, floor_n)
        diff = (h_q - q_lo).astype(jnp.float32)
        dn = jnp.sum(dh.astype(jnp.float32) * diff) / jnp.maximum(frac, 0.05)
        return {"act": dn, "w": jnp.zeros((), jnp.float32)}

    # loss & updates -----------------------------------------------------

    def gamma_at(self, step: jax.Array) -> jax.Array:
        g = jnp.asarray(self.gamma, jnp.float32)
        for s in self.gamma_decay_steps:
            g = jnp.where(step >= s, g * 0.1, g)
        return g

    def penalty(self, learn, lam, step, dims):
        top = float(self._max_bits(dims))
        gamma = self.gamma_at(step)
        return gamma * (
            jnp.sum(lam["act"] * jnp.clip(learn["act"], 0, top))
            + jnp.sum(lam["w"] * jnp.clip(learn["w"], 0, top))
            + jnp.sum(lam["act_rem"] * jnp.clip(learn["act_rem"], 0, top))
            + jnp.sum(lam["w_rem"] * jnp.clip(learn["w_rem"], 0, top)))

    def update_learn(self, learn, grads, dims):
        top = float(self._max_bits(dims))
        lo = self._min_bits(dims)
        return {k: jnp.clip(learn[k] - self.lr * grads[k], lo, top)
                for k in learn}

    # reporting ----------------------------------------------------------

    def _means(self, state, dims):
        top = float(self._max_bits(dims))
        return (jnp.mean(jnp.clip(state.learn["act"], 0, top)),
                jnp.mean(jnp.clip(state.learn["w"], 0, top)))

    def _deployed_mean(self, state, dims) -> float:
        """Deployment bits: learned fractional bitlengths round up (§IV-A4)."""
        lo = self._min_bits(dims)
        top = float(self._max_bits(dims))
        vals = [jnp.clip(state.learn[k], lo, top)
                for k in ("act", "act_rem") if state.learn[k].size]
        cat = jnp.concatenate([v.reshape(-1) for v in vals])
        return float(jnp.mean(jnp.ceil(cat)))

    def _deployed_per_period(self, state, dims):
        """Per-period deployed act bitlengths (rounded up, host floats)."""
        lo = self._min_bits(dims)
        top = float(self._max_bits(dims))
        v = jnp.ceil(jnp.clip(state.learn["act"], lo, top))
        return [float(b) for b in v]


@dataclasses.dataclass(frozen=True)
class QMPolicy(_LearnedBitsPolicy):
    """Quantum Mantissa (§IV-A): learned per-scope mantissa bitlengths."""

    name = "qm"
    has_stash_grad = True
    requires_act_bits = True

    def _max_bits(self, dims):
        return dims.man_bits

    def _truncate(self, x, n_int):
        return containers.truncate_mantissa(x, n_int)

    def _quantize(self, x, n, key):
        return qm.qm_quantize(x, n, key)

    def act_decision(self, pslice, key, dims):
        n = containers.stochastic_bitlength(
            pslice["act"], jax.random.fold_in(key, QM_ACT_SALT),
            dims.man_bits)
        return base.PrecisionDecision(
            man_bits=n, exp_bits=jnp.asarray(dims.exp_bits, jnp.int32))

    def metrics(self, state, dims):
        act, w = self._means(state, dims)
        return {"qm_act_mean": act, "qm_w_mean": w}

    def snapshot(self, state):
        return {"act": state.learn["act"], "w": state.learn["w"]}

    def decision_summary(self, state, dims):
        return {"man_bits": self._deployed_mean(state, dims),
                "exp_bits": float(dims.exp_bits)}

    def layer_decisions(self, state, dims):
        return [(b, float(dims.exp_bits))
                for b in self._deployed_per_period(state, dims)]


@dataclasses.dataclass(frozen=True)
class QEPolicy(_LearnedBitsPolicy):
    """Quantum Exponent (§IV): learned per-scope exponent bitlengths.

    The estimator mirrors qm_quantize, backed by containers.
    truncate_exponent — the reduced range flushes underflow to zero and
    saturates overflow. Defaults are gentler than QM's: the exponent field
    is smaller, and flushing a needed binade hurts more than a dropped
    mantissa bit.
    """

    gamma: float = 0.05
    min_bits: float = float(containers.MIN_EXP_BITS)

    name = "qe"
    adapts_exponent = True
    has_stash_grad = True
    requires_act_bits = True

    def _max_bits(self, dims):
        return dims.exp_bits

    def _truncate(self, x, e_int):
        return containers.truncate_exponent(x, e_int)

    def _quantize(self, x, e, key):
        return qe.qe_quantize(x, e, key)

    def act_decision(self, pslice, key, dims):
        e = containers.stochastic_bitlength(
            pslice["act"], jax.random.fold_in(key, QE_ACT_SALT),
            dims.exp_bits, min_bits=containers.MIN_EXP_BITS)
        return base.PrecisionDecision(
            man_bits=jnp.asarray(dims.man_bits, jnp.int32), exp_bits=e)

    def metrics(self, state, dims):
        act, w = self._means(state, dims)
        return {"qe_act_mean": act, "qe_w_mean": w}

    def snapshot(self, state):
        return {"act_e": state.learn["act"], "w_e": state.learn["w"]}

    def decision_summary(self, state, dims):
        return {"man_bits": float(dims.man_bits),
                "exp_bits": self._deployed_mean(state, dims)}

    def layer_decisions(self, state, dims):
        return [(float(dims.man_bits), b)
                for b in self._deployed_per_period(state, dims)]
