"""containers.truncate_exponent edge cases + the Quantum Exponent VJP.

Covers the satellite checklist: subnormal flush, inf/nan preservation,
saturation at the reduced exponent range, and a property test against a
pure-Python bit-twiddling oracle.
"""
import math
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import containers as C, quantum_exponent as QE


def _oracle(v: float, e: int) -> float:
    """Pure-Python truncate_exponent for one fp32 value."""
    e = max(C.MIN_EXP_BITS, min(e, 8))
    if math.isnan(v) or math.isinf(v):
        return v
    bits = struct.unpack("<I", struct.pack("<f", np.float32(v)))[0]
    exp = (bits >> 23) & 0xFF
    bias_e = 2 ** (e - 1) - 1
    lo, hi = 1 - bias_e, (2 ** e - 2) - bias_e
    unb = exp - 127
    if exp == 0 or unb < lo:  # zero/subnormal or underflow: flush
        return math.copysign(0.0, v)
    if unb > hi:              # overflow: clamp exponent, keep mantissa
        new = (bits & 0x807FFFFF) | ((hi + 127) << 23)
        return struct.unpack("<f", struct.pack("<I", new))[0]
    return float(np.float32(v))


def test_zero_and_subnormal_flush():
    tiny = np.float32(1e-40)  # fp32 subnormal
    x = jnp.asarray([0.0, -0.0, tiny, -tiny], jnp.float32)
    for e in (2, 4, 8):
        out = np.asarray(C.truncate_exponent(x, e))
        np.testing.assert_array_equal(out, np.zeros(4, np.float32))
        # signed zero: the sign bit survives the flush
        assert np.signbit(out[1]) and np.signbit(out[3])
        assert not np.signbit(out[0]) and not np.signbit(out[2])


def test_underflow_flushes_normals_below_range():
    # e=4: bias 7, normal range [-6, 7] -> 2^-7 flushes, 2^-6 survives
    x = jnp.asarray([2.0 ** -7, 2.0 ** -6, -(2.0 ** -7)], jnp.float32)
    out = np.asarray(C.truncate_exponent(x, 4))
    assert out[0] == 0.0 and out[2] == 0.0 and np.signbit(out[2])
    assert out[1] == 2.0 ** -6


def test_overflow_saturates_keeping_mantissa():
    # e=4: max unbiased exponent 7 -> magnitudes clamp into [128, 256)
    x = jnp.asarray([1000.0, -1000.0, 1.75 * 2.0 ** 20], jnp.float32)
    out = np.asarray(C.truncate_exponent(x, 4))
    assert out[0] == 1000.0 / 2.0 ** 2  # 1000 = 1.953*2^9 -> 1.953*2^7
    assert out[1] == -out[0]
    assert out[2] == 1.75 * 2.0 ** 7  # mantissa bits preserved
    assert (np.abs(out) < 2.0 ** 8).all()


def test_inf_nan_preserved():
    x = jnp.asarray([np.inf, -np.inf, np.nan], jnp.float32)
    out = np.asarray(C.truncate_exponent(x, 3))
    assert np.isposinf(out[0]) and np.isneginf(out[1]) and np.isnan(out[2])


def test_full_width_identity_for_normals():
    x = jnp.asarray([1.5, -3.0, 2.0 ** 127, 2.0 ** -126], jnp.float32)
    np.testing.assert_array_equal(np.asarray(C.truncate_exponent(x, 8)),
                                  np.asarray(x))


def test_idempotent_and_monotone_range():
    x = (jax.random.normal(jax.random.PRNGKey(0), (512,), jnp.float32)
         * jnp.exp2(jax.random.randint(jax.random.PRNGKey(1), (512,),
                                       -40, 40).astype(jnp.float32)))
    for e in (2, 3, 5, 8):
        q1 = C.truncate_exponent(x, e)
        q2 = C.truncate_exponent(q1, e)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        # a wider budget keeps at least every value the narrow one kept
        wide = np.asarray(C.truncate_exponent(x, min(e + 1, 8)))
        kept = np.asarray(q1) != 0
        assert (wide[kept] != 0).all()


def test_bf16_supported():
    x = jnp.asarray([1.0, 1000.0, 2.0 ** -20], jnp.bfloat16)
    out = C.truncate_exponent(x, 4)
    assert out.dtype == jnp.bfloat16
    assert float(out[2]) == 0.0  # below e=4 range


def test_property_vs_python_oracle():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(width=32, allow_nan=False),
                    min_size=1, max_size=64),
           st.integers(0, 10))
    def check(vals, e):
        x = jnp.asarray(vals, jnp.float32)
        got = np.asarray(C.truncate_exponent(x, e))
        want = np.asarray([_oracle(v, e) for v in vals], np.float32)
        np.testing.assert_array_equal(got, want)

    check()


# ---------------------------------------------------------------------
# qe_quantize: STE + expectation-derivative estimator
# ---------------------------------------------------------------------


def test_qe_quantize_matches_truncation_at_integer_e():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,), jnp.float32) * 1e4
    q = QE.qe_quantize(x, jnp.asarray(4.0), jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(C.truncate_exponent(x, 4)))


def test_qe_grad_x_is_straight_through():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(
        QE.qe_quantize(x, jnp.asarray(3.0), jax.random.PRNGKey(1))))(x)
    np.testing.assert_array_equal(np.asarray(g), np.ones(64, np.float32))


def test_qe_grad_e_is_expectation_derivative():
    # Spread exponents so T(x, floor) != T(x, floor+1): the estimator must
    # equal sum(g * (T(x, e+1) - T(x, e))) exactly.
    x = (jax.random.normal(jax.random.PRNGKey(0), (512,), jnp.float32)
         * jnp.exp2(jax.random.randint(jax.random.PRNGKey(1), (512,),
                                       -30, 30).astype(jnp.float32)))
    e = jnp.asarray(4.5, jnp.float32)
    de = jax.grad(lambda e: jnp.sum(
        QE.qe_quantize(x, e, jax.random.PRNGKey(2))), argnums=0)(e)
    want = float(jnp.sum(C.truncate_exponent(x, 5)
                         - C.truncate_exponent(x, 4)))
    assert abs(float(de) - want) < 1e-3 * max(1.0, abs(want))
    assert float(de) != 0.0


def test_qe_deterministic_rounds_up():
    x = jnp.asarray([2.0 ** -20, 1.0], jnp.float32)
    q = QE.qe_quantize_deterministic(x, jnp.asarray(4.2))
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(C.truncate_exponent(x, 5)))
