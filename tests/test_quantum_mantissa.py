import jax
import jax.numpy as jnp
import numpy as np

from repro.core import containers as C, quantum_mantissa as qm


def test_qm_quantize_values_are_truncations():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,), jnp.float32)
    q = qm.qm_quantize(x, jnp.asarray(4.5, jnp.float32), jax.random.PRNGKey(1))
    q4 = C.truncate_mantissa(x, 4)
    q5 = C.truncate_mantissa(x, 5)
    match = (np.asarray(q) == np.asarray(q4)).all() or (
        np.asarray(q) == np.asarray(q5)).all()
    assert match  # per-tensor draw: all elements share the same bitlength


def test_qm_ste_gradient_wrt_x():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(
        qm.qm_quantize(x, jnp.asarray(3.0), jax.random.PRNGKey(1)) * 2.0))(x)
    np.testing.assert_allclose(np.asarray(g), 2.0)


def test_qm_bitlength_gradient_matches_expectation_slope():
    """dL/dn must equal sum(g * (Q(x, floor+1) - Q(x, floor)))."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128,), jnp.float32) * 3
    w = jax.random.normal(jax.random.PRNGKey(3), (128,), jnp.float32)
    n = jnp.asarray(3.4, jnp.float32)

    def loss(n):
        return jnp.sum(w * qm.qm_quantize(x, n, jax.random.PRNGKey(4)))

    dn = jax.grad(loss)(n)
    expect = jnp.sum(w * (C.truncate_mantissa(x, 4) - C.truncate_mantissa(x, 3)))
    np.testing.assert_allclose(float(dn), float(expect), rtol=1e-5)


def test_qm_bitlength_gradient_zero_at_max_bits():
    x = jax.random.normal(jax.random.PRNGKey(5), (64,), jnp.float32)
    dn = jax.grad(lambda n: jnp.sum(
        qm.qm_quantize(x, n, jax.random.PRNGKey(6)) ** 2))(jnp.asarray(23.0))
    assert float(dn) == 0.0


def test_penalty_and_lambdas():
    lams = qm.footprint_lambdas({"a": 100, "b": 300})
    assert abs(lams["a"] - 0.25) < 1e-9 and abs(lams["b"] - 0.75) < 1e-9
    bits = {"a": jnp.asarray(4.0), "b": jnp.asarray(2.0)}
    pen = qm.qm_penalty(bits, lams, gamma=0.1)
    np.testing.assert_allclose(float(pen), 0.1 * (0.25 * 4 + 0.75 * 2),
                               rtol=1e-6)


def test_gamma_decay_schedule():
    cfg = qm.QMConfig(gamma=0.1, gamma_decay_steps=(10, 20))
    assert abs(float(qm.gamma_at(cfg, jnp.asarray(0))) - 0.1) < 1e-6
    assert abs(float(qm.gamma_at(cfg, jnp.asarray(15))) - 0.01) < 1e-6
    assert abs(float(qm.gamma_at(cfg, jnp.asarray(25))) - 0.001) < 1e-6


def test_qm_quantize_bf16():
    x = (jax.random.normal(jax.random.PRNGKey(7), (128,), jnp.float32)
         ).astype(jnp.bfloat16)
    q = qm.qm_quantize(x, jnp.asarray(2.0, jnp.float32), jax.random.PRNGKey(8))
    expect = C.truncate_mantissa(x, 2)
    np.testing.assert_array_equal(
        np.asarray(q).view(np.uint16), np.asarray(expect).view(np.uint16))


def test_deterministic_rounds_up():
    x = jax.random.normal(jax.random.PRNGKey(9), (32,), jnp.float32)
    q = qm.qm_quantize_deterministic(x, jnp.asarray(2.3))
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(C.truncate_mantissa(x, 3)))
