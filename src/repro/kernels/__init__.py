"""Pallas TPU kernels for Schrödinger's FP hot spots.

  mantissa_quant   - Q(M, n) truncation (paper eq. 5, the quantizer datapath)
  sfp_pack         - SFP8/SFP16 container pack/unpack (the §V compressor)
  flash_attention  - online-softmax attention (consumer of compressed KV)
  ops              - backend dispatch (pallas on TPU / jnp ref elsewhere)
  ref              - pure-jnp oracles for all of the above
"""
