"""Paged flash-decode: the block-table-gathering kernel must be bit-exact
(interpret mode) against the gather-unpack-attend oracle, agree with the
contiguous kernel on the same logical cache, and the per-row-position
extension of the contiguous kernel must match per-row scalar calls."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.kernels import ops, ref
from repro.kernels import packed_flash_decode as pfd


def _pool(key, n_phys, bl, D, container, dtype):
    """Random packed physical blocks (n_phys, bl, D)."""
    ks = jax.random.split(key, 2)
    f = codecs.fields_for(container, dtype)
    parts = []
    for k in ks:
        x = jax.random.normal(k, (n_phys * bl, D), jnp.float32).astype(dtype)
        p, b = ref.sfp_pack_nd(x, f)
        parts.append((p.reshape(n_phys, bl, D),
                      b.reshape(n_phys, bl, D // 128)))
    (kp, kb), (vp, vb) = parts
    return (kp, kb, vp, vb), f


@pytest.mark.parametrize("container,dtype", [("sfp8", jnp.bfloat16),
                                             ("sfp16", jnp.float32)])
@pytest.mark.parametrize("rep", [1, 4])  # GQA ratio H / KH
def test_paged_kernel_bit_exact_vs_oracle(container, dtype, rep):
    B, KH, hd, bl, nb, n_phys = 3, 2, 64, 16, 3, 8
    H = KH * rep
    packed, f = _pool(jax.random.PRNGKey(0), n_phys, bl, KH * hd,
                      container, dtype)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, 1, H, hd),
                          jnp.float32).astype(dtype)
    # Rows at different fill levels; row 1 has unallocated logical blocks
    # pointing at the trash block (0) — masked by position.
    tables = jnp.array([[1, 4, 2], [7, 0, 0], [5, 3, 6]], jnp.int32)
    pos = jnp.array([40, 9, 33], jnp.int32)
    got = pfd.paged_flash_decode(q, *packed, tables, pos, fields=f,
                                 softcap=30.0, interpret=True)
    oracle = jax.jit(functools.partial(ref.paged_flash_decode, fields=f,
                                       softcap=30.0))
    want = oracle(q, *packed, tables, pos)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_paged_matches_contiguous_on_same_logical_cache():
    """A block table that happens to be the identity permutation must
    reproduce the contiguous kernel bit-for-bit: paged decode is the same
    recurrence over the same logical slots."""
    B, KH, rep, hd, bl, nb = 2, 2, 2, 64, 16, 4
    H, D = KH * rep, 2 * 64
    dtype = jnp.float32
    (kp, kb, vp, vb), f = _pool(jax.random.PRNGKey(2), nb, bl, D,
                                "sfp16", dtype)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, 1, H, hd), dtype)
    pos = jnp.array([bl * nb - 1, 17], jnp.int32)
    ident = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32), (B, nb))
    got = pfd.paged_flash_decode(q, kp, kb, vp, vb, ident, pos, fields=f,
                                 interpret=True)
    want = pfd.packed_flash_decode(
        q, jnp.broadcast_to(kp.reshape(1, nb * bl, D), (B, nb * bl, D)),
        jnp.broadcast_to(kb.reshape(1, nb * bl, D // 128),
                         (B, nb * bl, D // 128)),
        jnp.broadcast_to(vp.reshape(1, nb * bl, D), (B, nb * bl, D)),
        jnp.broadcast_to(vb.reshape(1, nb * bl, D // 128),
                         (B, nb * bl, D // 128)),
        pos, fields=f, block_l=bl, interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("window", [None, 16])
def test_contiguous_kernel_vector_pos_matches_per_row(window):
    """(B,) per-row positions (continuous-batching slots) must equal B
    separate scalar-pos calls — rows are independent grid lanes."""
    B, KH, rep, hd, L = 3, 2, 2, 64, 16
    H, D = KH * rep, 2 * 64
    dtype = jnp.float32
    f = codecs.fields_for("sfp16", dtype)
    k = jax.random.normal(jax.random.PRNGKey(4), (B, L, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, L, D), dtype)
    kp, kb = ref.sfp_pack_nd(k, f)
    vp, vb = ref.sfp_pack_nd(v, f)
    q = jax.random.normal(jax.random.PRNGKey(6), (B, 1, H, hd), dtype)
    pos = jnp.array([5, 21, 15], jnp.int32)  # 21: wrapped when window=16
    got = pfd.packed_flash_decode(q, kp, kb, vp, vb, pos, fields=f,
                                  window=window, block_l=16, interpret=True)
    for b in range(B):
        one = pfd.packed_flash_decode(
            q[b:b + 1], kp[b:b + 1], kb[b:b + 1], vp[b:b + 1], vb[b:b + 1],
            jnp.asarray(int(pos[b]), jnp.int32), fields=f, window=window,
            block_l=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[b:b + 1], np.float32),
                                      np.asarray(one, np.float32))


def test_ops_paged_dispatch_ref_vs_interpret():
    """ops.paged_flash_decode: ref oracle and interpret kernel agree."""
    B, KH, hd, bl, n_phys = 2, 2, 64, 16, 6
    dtype = jnp.float32
    (kp, kb, vp, vb), f = _pool(jax.random.PRNGKey(7), n_phys, bl, KH * hd,
                                "sfp8", dtype)
    q = jax.random.normal(jax.random.PRNGKey(8), (B, 1, KH, hd), dtype)
    tables = jnp.array([[2, 5], [4, 0]], jnp.int32)
    pos = jnp.array([25, 3], jnp.int32)
    outs = {}
    for backend in ("ref", "interpret"):
        ops.force_backend(backend)
        try:
            outs[backend] = np.asarray(ops.paged_flash_decode(
                q, ops.Packed(payload=kp, bases=kb),
                ops.Packed(payload=vp, bases=vb), tables, pos, fields=f),
                np.float32)
        finally:
            ops.force_backend(None)
    np.testing.assert_array_equal(outs["ref"], outs["interpret"])


def test_trailing_trash_blocks_are_exact_noops():
    """Extra logical blocks pointing at the trash block past a row's
    position must not change the output by a single bit (the masked-block
    recurrence contributes exactly zero)."""
    B, KH, hd, bl = 1, 2, 64, 16
    dtype = jnp.float32
    (kp, kb, vp, vb), f = _pool(jax.random.PRNGKey(9), 5, bl, KH * hd,
                                "sfp16", dtype)
    q = jax.random.normal(jax.random.PRNGKey(10), (B, 1, KH, hd), dtype)
    pos = jnp.array([bl - 2], jnp.int32)
    short = jnp.array([[3]], jnp.int32)
    long = jnp.array([[3, 0, 0, 0]], jnp.int32)
    a = pfd.paged_flash_decode(q, kp, kb, vp, vb, short, pos, fields=f,
                               interpret=True)
    b = pfd.paged_flash_decode(q, kp, kb, vp, vb, long, pos, fields=f,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
