"""SFP-compressed activation stashing.

The paper's hardware sits between the compute units and DRAM: the forward
pass *encodes* activations as they are stashed off-chip; the backward pass
*decodes* them on the way back in (§V). The TPU-native equivalent is a
scan-over-layers whose saved cross-pass residuals are the packed
containers:

    sfp_scan(layer_fn, compress, decompress, (h0, extras0), xs)

  forward : for each layer i, stash c_i = compress(h_i, x_i) and compute
            h_{i+1} = layer_fn(decompress(c_i, x_i), x_i) — compute consumes
            the quantized values, exactly as in the paper (§IV-A1).
  backward: a reverse scan re-reads each c_i, decompresses, recomputes the
            layer (rematerialization) and transposes it. Only the packed
            containers (plus the tiny ``extras`` carry, e.g. accumulated
            router aux losses) live across the forward/backward gap.

This gives bit-identical forward/backward values (the backward sees exactly
what the forward computed from) and makes the stash the *only* cross-pass
residual — the paper's "transparent encode/decode" as a JAX transform.

Gradient semantics at the stash boundary: straight-through (dL/dh = dL/dh_q)
— the paper's STE (§IV-A1). The optional ``stash_grad`` hook lets Quantum
Mantissa inject bitlength gradients computed from the *realized* stash
(DESIGN.md D8: an importance-weighted estimator, since hardware cannot see
mantissa bits it never stored).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def sfp_scan(
    layer_fn: Callable[[Tuple[Any, Any], Any], Tuple[Tuple[Any, Any], Any]],
    compress: Callable[[Any, Any], Any],
    decompress: Callable[[Any, Any], Any],
    carry0: Tuple[Any, Any],
    xs: Any,
    stash_grad: Optional[Callable[[Any, Any, Any], Any]] = None,
):
    """Scan with compressed cross-pass activation stash.

    Args:
      layer_fn:  ((h, extras), x) -> ((h_new, extras_new), aux). ``extras``
                 is a small differentiable side-carry (e.g. accumulated MoE
                 aux loss); ``aux`` is metrics-only (cotangent discarded).
      compress:  (h, x) -> packed pytree (the off-chip representation).
      decompress:(packed, x) -> h_q with h's shape/dtype.
      carry0:    (h0, extras0).
      xs:        per-layer dict pytree (params slices, rng keys, bitlengths).
      stash_grad: optional (dh, packed, x) -> {top-level xs key: cotangent}
                 overlay added to the parameter cotangents (QM bitlength
                 gradients). Keys must map to float leaves of xs.

    Returns:
      ((h_final, extras_final), aux_stacked)
    """

    def fwd_body(carry, x):
        h, extras = carry
        c = compress(h, x)
        h_q = decompress(c, x)
        (h_new, extras_new), aux = layer_fn((h_q, extras), x)
        return (h_new, extras_new), (c, extras, aux)

    @jax.custom_vjp
    def run(carry0, xs):
        carry, (_, _, aux) = jax.lax.scan(fwd_body, carry0, xs)
        return carry, aux

    def run_fwd(carry0, xs):
        carry, (stash, extras_seq, aux) = jax.lax.scan(fwd_body, carry0, xs)
        # Residuals: packed stash + per-step extras (tiny) + xs (an
        # unmodified input — kept alive anyway, no copy).
        return (carry, aux), (stash, extras_seq, xs)

    def run_bwd(res, cotangents):
        stash, extras_seq, xs = res
        (g_h, g_extras), _g_aux = cotangents  # aux is metrics-only

        def bwd_body(dcarry, step):
            dh, dex = dcarry
            x, c, extras_in = step
            h_q = decompress(c, x)

            def fwd_only(hh, ee, xx):
                (h_new, e_new), _aux = layer_fn((hh, ee), xx)
                return h_new, e_new

            _, vjp = jax.vjp(fwd_only, h_q, extras_in, x)
            dh_prev, dex_prev, dx = vjp((dh, dex))
            if stash_grad is not None:
                dx = dict(dx)
                for k, v in stash_grad(dh, c, x).items():
                    dx[k] = jax.tree.map(_acc_cotangent, dx[k], v)
            return (dh_prev, dex_prev), dx

        (dh0, dex0), dxs = jax.lax.scan(
            bwd_body, (g_h, g_extras), (xs, stash, extras_seq), reverse=True)
        return (dh0, dex0), dxs

    run.defvjp(run_fwd, run_bwd)
    return run(carry0, xs)


def _acc_cotangent(a, b):
    """Add a stash_grad overlay onto a vjp cotangent leaf.

    Integer xs leaves (e.g. controller bitlengths threaded through a
    composite policy slice) carry float0 cotangents — those pass through
    untouched; only real float cotangents accumulate.
    """
    if getattr(a, "dtype", None) == jax.dtypes.float0:
        return a
    return a + jnp.asarray(b, a.dtype)


def identity_compress(h, x):
    """Baseline: stash the raw activation (plain remat-with-saved-carries)."""
    del x
    return h


def identity_decompress(c, x):
    del x
    return c


def plain_scan(layer_fn, carry0, xs):
    """Uncompressed-stash baseline with the same remat structure as sfp_scan.

    Used for the paper-faithful FP32/BF16 baselines so that SFP-vs-baseline
    comparisons isolate the container change, not the remat strategy.
    """
    return sfp_scan(layer_fn, identity_compress, identity_decompress,
                    carry0, xs)
