"""Seeded violation: pinning the kernel backend outside kernels/ops.py."""
from repro.kernels import ops


def setup_model():
    ops.force_backend("ref")  # LINT: force-backend-leak
    return None
