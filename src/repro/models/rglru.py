"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(w_r . x_t + b_r)            (recurrence gate)
    i_t = sigmoid(w_i . x_t + b_i)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence training uses an associative scan (log-space decays carried
in fp32); decode is the one-step recurrence with a (B, lru_width) state.
Gates here are diagonal (per-channel) rather than Griffin's block-diagonal
— a documented simplification (DESIGN.md §9) that preserves the memory/
compute structure the paper's technique interacts with.

Block layout: in-proj -> [x branch: causal conv(4) -> RG-LRU] * gelu(gate
branch) -> out-proj.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.mamba2 import _causal_conv

C_FACTOR = 8.0


def rglru_init(p: common.ParamFactory, cfg: ArchConfig):
    d, lw, cw = cfg.d_model, cfg.lru_width_, cfg.conv_width
    return {
        "w_x": p((d, lw), ("embed", "lru")),
        "w_gate": p((d, lw), ("embed", "lru")),
        "conv": p((cw, lw), ("conv", "lru"), scale=cw ** -0.5),
        "w_r": p((lw,), ("lru",), init="zeros", dtype=jnp.float32),
        "b_r": p((lw,), ("lru",), init="zeros", dtype=jnp.float32),
        "w_i": p((lw,), ("lru",), init="zeros", dtype=jnp.float32),
        "b_i": p((lw,), ("lru",), init="zeros", dtype=jnp.float32),
        "lam": p((lw,), ("lru",), init="ones", dtype=jnp.float32),
        "w_out": p((lw, d), ("lru", "embed")),
    }


def _gates(params, xb: jax.Array):
    """xb: (B, S, lru) conv output (fp32). Returns log_a, gated input."""
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(params["w_r"] * xf + params["b_r"])
    i = jax.nn.sigmoid(params["w_i"] * xf + params["b_i"])
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"]) * r  # <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9))
    b = beta * (i * xf)
    return log_a, b


def rglru_forward(params, h: jax.Array, cfg: ArchConfig,
                  return_cache: bool = False):
    """Full-sequence recurrent block. h: (B, S, d)."""
    B, S, d = h.shape
    xb_raw = h @ params["w_x"]
    gate = h @ params["w_gate"]
    xb, _ = _causal_conv(xb_raw, params["conv"])

    log_a, b = _gates(params, xb)  # (B, S, lw) fp32

    def combine(e1, e2):
        la1, b1 = e1
        la2, b2 = e2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    y = hseq.astype(h.dtype) * jax.nn.gelu(gate.astype(jnp.float32)
                                           ).astype(h.dtype)
    out = y @ params["w_out"]
    if return_cache:
        cache = LRUCache(conv=xb_raw[:, -(cfg.conv_width - 1):],
                         state=hseq[:, -1])
        return out, cache
    return out


class LRUCache(NamedTuple):
    conv: jax.Array   # (B, cw-1, lru)
    state: jax.Array  # (B, lru) fp32


def lru_cache_init(cfg: ArchConfig, batch: int, dtype) -> LRUCache:
    return LRUCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width_), dtype),
        state=jnp.zeros((batch, cfg.lru_width_), jnp.float32),
    )


def lru_cache_spec(cfg: ArchConfig, batch: int, dtype) -> LRUCache:
    return LRUCache(
        conv=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, cfg.lru_width_),
                                  dtype),
        state=jax.ShapeDtypeStruct((batch, cfg.lru_width_), jnp.float32),
    )


def rglru_decode(params, h_tok: jax.Array, cache: LRUCache, cfg: ArchConfig
                 ) -> Tuple[jax.Array, LRUCache]:
    B = h_tok.shape[0]
    xb = h_tok @ params["w_x"]
    gate = h_tok @ params["w_gate"]
    xb, new_conv = _causal_conv(xb, params["conv"], cache.conv)

    log_a, b = _gates(params, xb)  # (B, 1, lw)
    state = jnp.exp(log_a[:, 0]) * cache.state + b[:, 0]
    y = state[:, None, :].astype(h_tok.dtype) * jax.nn.gelu(
        gate.astype(jnp.float32)).astype(h_tok.dtype)
    return y @ params["w_out"], LRUCache(conv=new_conv, state=state)
