"""GQA attention blocks: global / sliding-window, train + decode paths.

Training / prefill use a two-level chunked online-softmax schedule (outer
scan over query chunks, inner scan over key chunks) so no S x S tensor is
ever materialized; *local* layers slice only the key band inside the
window, so their FLOPs scale with `window`, not with sequence length.
Decode attends the whole (possibly ring-buffered) cache in one einsum —
scan-over-layers bounds the transient.

On TPU the inner loop is replaced by the Pallas flash kernel via
kernels.ops.attention (prefill fast path).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.kernels import ops
from repro.models import common

NEG_INF = -1e30


def attn_init(p: common.ParamFactory, cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.head_dim_
    H, KH = cfg.n_heads, cfg.n_kv_heads
    params = {
        "wq": p((d, H * hd), ("embed", "heads")),
        "wk": p((d, KH * hd), ("embed", "heads")),
        "wv": p((d, KH * hd), ("embed", "heads")),
        "wo": p((H * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        params["q_norm"] = common.rmsnorm_init(p, hd, axis="norm")
        params["k_norm"] = common.rmsnorm_init(p, hd, axis="norm")
    return params


def _qkv_specs(cfg: ArchConfig, batch_size: int):
    """Explicit activation shardings for (q, k, v): heads over `model` when
    divisible, replicated otherwise. Without these, GSPMD resolves the
    (fused-dim sharded) reshape against downstream uses by replicating
    whole tensors — including the KV cache, once per decode step."""
    mesh = shd.active_mesh()
    if mesh is None:
        return None, None, None
    tp = shd.model_axis_size(mesh)
    b = shd.batch_axis_for(mesh, batch_size)
    target = shd.heads_target()
    hq = target if (target and cfg.n_heads % tp == 0) else None
    hkv = target if (target and cfg.n_kv_heads % tp == 0) else None
    return (b, None, hq, None), (b, None, hkv, None), b


def _project_qkv(params, h, cfg: ArchConfig, positions):
    B, S, _ = h.shape
    hd, H, KH = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    q = (h @ params["wq"]).reshape(B, S, H, hd)
    k = (h @ params["wk"]).reshape(B, S, KH, hd)
    v = (h @ params["wv"]).reshape(B, S, KH, hd)
    q_spec, kv_spec, _ = _qkv_specs(cfg, B)
    if q_spec is not None:
        q = shd.hint(q, *q_spec)
        k = shd.hint(k, *kv_spec)
        v = shd.hint(v, *kv_spec)
    if cfg.qk_norm:
        q = common.rmsnorm(params["q_norm"], q)
        k = common.rmsnorm(params["k_norm"], k)
    q = common.rope(q, positions, cfg.rope_theta)
    k = common.rope(k, positions, cfg.rope_theta)
    if q_spec is not None:
        q = shd.hint(q, *q_spec)
        k = shd.hint(k, *kv_spec)
    return q, k, v


def _chunk_attend(q, k, v, q_pos, k_pos, *, softcap, scale,
                  carry, prefix_len: int = 0):
    """One online-softmax update. q:(B,cq,H,hd) k/v:(B,ck,KH,hd).

    bf16 contractions with fp32 accumulation (preferred_element_type);
    GQA via grouped einsum — no repeated-KV materialization."""
    m, l, acc = carry
    B, cq, H, hd = q.shape
    KH = k.shape[2]
    rep = H // KH
    qg = q.reshape(B, cq, KH, rep, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = s.reshape(B, H, cq, -1)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = k_pos[None, :] <= q_pos[:, None]
    if prefix_len > 0:
        mask = mask | (k_pos[None, :] < prefix_len)
    mask = mask & (k_pos[None, :] >= 0)
    s = jnp.where(mask[None, None], s, NEG_INF)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pg = p.reshape(B, KH, rep, cq, -1).astype(v.dtype)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", pg, v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * alpha[..., None] + pv.reshape(B, H, cq, hd)
    return m_new, l_new, acc_new


def ring_pack_kv(k: jax.Array, v: jax.Array, L: int):
    """Pack full-sequence K/V (B, S, KH, hd) into an L-slot ring cache.

    Slot s receives the latest position p <= S-1 with p === s (mod L);
    unwritten slots (S < L) are left as position p = s (the decode-side
    validity mask handles them: those slots simply equal position s which
    is either the true value or zero-init garbage masked by k_pos <= pos).
    """
    S = k.shape[1]
    slots = jnp.arange(L)
    p = (S - 1) - jnp.mod(S - 1 - slots, L)
    p = jnp.clip(p, 0, S - 1)
    return jnp.take(k, p, axis=1), jnp.take(v, p, axis=1)


def attention_train(params, h: jax.Array, cfg: ArchConfig, *, kind: str,
                    positions: jax.Array, prefix_len: int = 0,
                    chunk: int = 512, return_kv: bool = False):
    """Full-sequence attention (train / prefill). h: (B, S, d)."""
    B, S, d = h.shape
    hd, H = cfg.head_dim_, cfg.n_heads
    window = cfg.window if kind == "local" else None
    q, k, v = _project_qkv(params, h, cfg, positions)

    def _finish(out):
        out = out.reshape(B, S, H * hd) @ params["wo"]
        if return_kv:
            return out, (k, v)
        return out

    if S <= 2 * chunk or (prefix_len > 0 and prefix_len > chunk):
        # Small sequences / prefix-LM: single oracle call (O(S^2) but tiny,
        # or prefix archs whose S is bounded by the training shapes).
        out = ops.attention(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_softcap, prefix_len=prefix_len)
        return _finish(out)

    # largest chunk <= `chunk` that divides S (prefix-LM totals like
    # 4096+256 are not powers of two); tiny remainders fall back to oracle.
    cq = min(chunk, S)
    while cq > 32 and S % cq != 0:
        cq -= 32
    if S % cq != 0:
        out = ops.attention(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_softcap, prefix_len=prefix_len)
        return _finish(out)
    n_q = S // cq
    scale = 1.0 / (hd ** 0.5)

    if window is not None:
        # Banded local attention: each q chunk sees only [start, start+band).
        band = min(((window + cq - 1) // cq + 1) * cq, S)

        def q_step_local(_, qi):
            q_c = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, axis=1)
            start = jnp.maximum(qi * cq + cq - band, 0)
            k_c = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            q_pos = qi * cq + jnp.arange(cq)
            k_abs = start + jnp.arange(band)
            k_pos = jnp.where(
                (k_abs[None] > q_pos[:, None] - window)
                & (k_abs[None] <= q_pos[:, None]),
                k_abs[None], -jnp.ones_like(k_abs)[None])
            m = jnp.full((B, H, cq), NEG_INF, jnp.float32)
            l = jnp.zeros((B, H, cq), jnp.float32)
            acc = jnp.zeros((B, H, cq, hd), jnp.float32)
            # collapse per-q-row masks: use per-row k_pos by masking in attend
            s_mask = k_pos >= 0
            rep = H // cfg.n_kv_heads
            qg = q_c.reshape(B, cq, cfg.n_kv_heads, rep, hd)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_c,
                           preferred_element_type=jnp.float32) * scale
            s = s.reshape(B, H, cq, -1)
            if cfg.attn_softcap is not None:
                s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
            s = jnp.where(s_mask[None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            pg = p.reshape(B, cfg.n_kv_heads, rep, cq, -1).astype(v_c.dtype)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v_c,
                           preferred_element_type=jnp.float32)
            o = o.reshape(B, cq, H, hd)
            return None, o.astype(h.dtype)

        _, outs = jax.lax.scan(jax.checkpoint(q_step_local), None,
                               jnp.arange(n_q))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    else:
        ck = min(chunk, S)
        n_k = S // ck

        def q_step(_, qi):
            q_c = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, axis=1)
            q_pos = qi * cq + jnp.arange(cq)

            @jax.checkpoint
            def k_step_inner(carry, ki):
                k_c = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=1)
                v_c = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=1)
                k_pos = ki * ck + jnp.arange(ck)
                return _chunk_attend(q_c, k_c, v_c, q_pos, k_pos,
                                     softcap=cfg.attn_softcap, scale=scale,
                                     carry=carry)

            def k_step(carry, ki):
                return k_step_inner(carry, ki), None

            m = jnp.full((B, H, cq), NEG_INF, jnp.float32)
            l = jnp.zeros((B, H, cq), jnp.float32)
            acc = jnp.zeros((B, H, cq, hd), jnp.float32)
            # causal: only key chunks up to this query chunk contribute.
            n_rel = qi + 1

            def masked_k_step(carry, ki):
                new_carry, _ = k_step(carry, ki)
                keep = ki < n_rel
                carry = jax.tree.map(
                    lambda a, b: jnp.where(keep, a, b), new_carry, carry)
                return carry, None


            (m, l, acc), _ = jax.lax.scan(masked_k_step, (m, l, acc),
                                          jnp.arange(n_k))
            o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(h.dtype)
            return None, o

        _, outs = jax.lax.scan(jax.checkpoint(q_step), None, jnp.arange(n_q))
        out = jnp.moveaxis(outs, 0, 1)  # (B, nq, H, cq, hd) -> fix below
        out = out.transpose(0, 1, 3, 2, 4).reshape(B, S, H, hd)

    return _finish(out)


# ---------------------------------------------------------------------------
# Decode path with (ring-buffered) KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, L, KH, hd) bf16 — L = S_max (global) or window (local)
    v: jax.Array


def cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
               dtype) -> KVCache:
    L = min(max_len, cfg.window) if kind == "local" else max_len
    shape = (batch, L, cfg.n_kv_heads, cfg.head_dim_)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_spec(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    L = min(max_len, cfg.window) if kind == "local" else max_len
    shape = (batch, L, cfg.n_kv_heads, cfg.head_dim_)
    return KVCache(k=jax.ShapeDtypeStruct(shape, dtype),
                   v=jax.ShapeDtypeStruct(shape, dtype))


def decode_attend(q: jax.Array, k_c: jax.Array, v_c: jax.Array,
                  pos: jax.Array, cfg: ArchConfig, kind: str) -> jax.Array:
    """Attend one query token over a (ring-buffered) cache. Returns
    (B, 1, H, hd) output (pre-wo).

    The cache stays in bf16 through the contractions
    (preferred_element_type=f32 accumulates exactly) — casting it up front
    would double the dominant HBM read of the decode step. GQA uses a
    grouped einsum instead of materializing repeated KV heads.
    """
    B = q.shape[0]
    hd, H, KH = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    L = k_c.shape[1]
    window = cfg.window if kind == "local" else None

    # Ring-slot validity shared with the packed flash-decode kernel, so the
    # fused and unpack-fallback decode paths agree on cache semantics.
    # ``pos`` may be scalar or (B,) — continuous-batching slots each sit at
    # their own decode position.
    if jnp.ndim(pos) == 0:
        valid = ops.decode_kv_mask(pos, L, window)[None]          # (1, L)
    else:
        valid = ops.decode_kv_mask(pos[:, None], L, window)       # (B, L)

    rep = H // KH
    qg = q.reshape(B, 1, KH, rep, hd)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_c,
                   preferred_element_type=jnp.float32) * scale
    if cfg.attn_softcap is not None:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(k_c.dtype), v_c,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def decode_slot_index(pos: jax.Array, L: int, kind: str) -> jax.Array:
    return jnp.mod(pos, L) if kind == "local" else pos


def attention_decode(params, h_tok: jax.Array, cache: KVCache,
                     pos: jax.Array, cfg: ArchConfig, *, kind: str,
                     ) -> Tuple[jax.Array, KVCache]:
    """One-token decode. h_tok: (B, 1, d); pos: scalar int32 (current index)."""
    B = h_tok.shape[0]
    hd, H = cfg.head_dim_, cfg.n_heads
    L = cache.k.shape[1]

    q, k_new, v_new = _project_qkv(params, h_tok, cfg,
                                   jnp.full((1,), pos, jnp.int32))
    # New-token K/V must arrive replicated over `model` (the cache shards
    # its L dim there); otherwise GSPMD reshards the whole cache per step.
    b = (shd.batch_axis_for(shd.active_mesh(), B)
         if shd.active_mesh() is not None else None)
    if shd.active_mesh() is not None:
        k_new = shd.hint(k_new, b, None, None, None)
        v_new = shd.hint(v_new, b, None, None, None)
        q = shd.hint(q, b, None, None, None)
    slot = decode_slot_index(pos, L, kind)
    k_c = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                              slot, axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                              slot, axis=1)
    o = decode_attend(q, k_c, v_c, pos, cfg, kind)
    out = o.reshape(B, 1, H * hd) @ params["wo"]
    return out, KVCache(k=k_c, v=v_c)
