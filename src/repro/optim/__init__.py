"""Optimizers and schedules (pure JAX)."""
