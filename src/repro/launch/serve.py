"""Serving launcher: batch mode, or a continuous-batching request-trace
simulator over the paged compressed-KV engine.

Batch mode (one prefill + one jitted decode loop, the PR 2 path — now
reachable with a compressed cache from the CLI):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --preset tiny \
      --batch 4 --prompt-len 32 --max-new 16 --kv-container sfp8

Trace mode simulates production traffic: Poisson request arrivals with
mixed prompt/output lengths, driven through the scheduler's admission /
continuous-batching / preemption machinery on a virtual clock:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --preset tiny \
      --trace --requests 16 --arrival-rate 2.0 --kv-container sfp8 \
      --max-slots 8 --max-len 256

Policy-aware precision (paper §IV-A4 deployment mode): point
``--policy-ckpt`` at a training run's checkpoint directory and the KV
container geometry is derived from the learned PrecisionDecision stamped
in its manifest (see serve/precision.py) — overriding --kv-container.

Fault-tolerant operation (see README "Operating the server"): deadlines
(--deadline as a TTL after arrival), a bounded queue with load shedding
(--max-pending), chaos injection (--inject-flip-p / --inject-alloc-p,
seeded), the preemption-storm guard (--storm-guard), and the
precision-downshift pressure controller (--degraded-container +
--pressure-low/--pressure-high). An arrival flood — every request landing
at once — is just --flood:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --preset tiny \
      --trace --flood --requests 32 --kv-container sfp-m3e5 --num-blocks 8 \
      --max-pending 8 --deadline 20 --degraded-container sfp-m1e2
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro import obs as obs_mod
from repro.configs.base import reduced
from repro.launch.args import container_name
from repro.models.model import DecoderModel
from repro.serve import engine, faults, precision
from repro.serve.scheduler import Request, Scheduler


def _build_model(args):
    cfg = configs.get(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    elif args.preset == "small":
        cfg = reduced(cfg, n_layers=max(2 * len(cfg.period), 4), d_model=256)
    container = args.kv_container
    if args.policy_ckpt:
        container = precision.container_from_checkpoint(args.policy_ckpt)
        print(f"policy-aware container from {args.policy_ckpt}: {container}")
    model = DecoderModel(cfg, kv_container=container)
    params = model.init(jax.random.PRNGKey(args.seed))
    return cfg, model, params, container


def run_batch(args) -> None:
    cfg, model, params, container = _build_model(args)
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    cond = (jnp.zeros((args.batch, cfg.prefix_tokens, cfg.d_model),
                      cfg.compute_dtype) if cfg.prefix_tokens else None)
    t0 = time.time()
    res = engine.generate(model, params, prompt, max_new=args.max_new,
                          cond_embeddings=cond)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={cfg.name} kv={container or 'raw'} generated {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    print("sample:", np.asarray(res.tokens[0]).tolist())


def make_trace(args, vocab: int):
    """Poisson arrivals (exponential gaps at --arrival-rate req/s) with
    prompt/output lengths drawn uniformly from the given ranges.
    ``--flood`` collapses every arrival to t=0 (a thundering herd);
    ``--deadline`` stamps each request with arrival + TTL."""
    rng = np.random.RandomState(args.seed + 2)
    lo_p, hi_p = args.prompt_len_min, args.prompt_len_max
    lo_n, hi_n = args.max_new_min, args.max_new_max
    t = 0.0
    reqs = []
    for i in range(args.requests):
        if not getattr(args, "flood", False):
            t += rng.exponential(1.0 / args.arrival_rate)
        reqs.append(Request(
            uid=i,
            prompt=rng.randint(0, vocab,
                               size=rng.randint(lo_p, hi_p + 1)
                               ).astype(np.int32),
            max_new=int(rng.randint(lo_n, hi_n + 1)),
            arrival=t,
            deadline=(t + args.deadline if getattr(args, "deadline", None)
                      else None)))
    return reqs


def run_trace(args) -> None:
    cfg, model, params, container = _build_model(args)
    if container is None:
        raise SystemExit("--trace needs a packed cache: pass --kv-container "
                         "(or --policy-ckpt)")
    eng = engine.PagedEngine(model, params, max_slots=args.max_slots,
                             max_len=args.max_len,
                             num_blocks=args.num_blocks,
                             degraded_container=args.degraded_container,
                             integrity=not args.no_integrity)
    reqs = make_trace(args, cfg.vocab)
    # Time-to-first-token in scheduler steps, per request (streaming
    # callback: fires the step each token is produced).
    ttft = {}
    pressure = None
    if args.degraded_container:
        pressure = precision.PressureController(low=args.pressure_low,
                                                high=args.pressure_high)
    obs = obs_mod.Obs(metrics_path=args.metrics_out,
                      events_path=args.events_out,
                      trace_path=args.trace_out,
                      timeline_path=args.timeline_out)
    sched = Scheduler(eng, on_token=lambda uid, tok, done:
                      ttft.setdefault(uid, sched.stats.decode_steps),
                      max_pending=args.max_pending,
                      storm_guard=args.storm_guard,
                      pressure=pressure, obs=obs)
    hook = None
    if args.inject_flip_p or args.inject_alloc_p:
        hook = faults.FaultInjector(eng, seed=args.fault_seed,
                                    p_flip=args.inject_flip_p,
                                    p_alloc_fail=args.inject_alloc_p)
    # --profile-steps N brackets jax.profiler around scheduler steps
    # [1, 1+N) — step 0 is excluded so the capture skips compile time.
    prof = {"on": False}

    def step_hook(i):
        if args.profile_steps:
            if not prof["on"] and i == 1:
                Path(args.profile_dir).mkdir(parents=True, exist_ok=True)
                jax.profiler.start_trace(args.profile_dir)
                prof["on"] = True
            elif prof["on"] and i >= 1 + args.profile_steps:
                jax.profiler.stop_trace()
                prof["on"] = False
        if hook is not None:
            hook(i)

    # Virtual clock: admission sees arrivals as wall-clock-free step time
    # (one scheduler step advances it by --step-dt), so the same trace
    # replays identically on any hardware.
    clock = {"t": 0.0}

    def now():
        clock["t"] += args.step_dt
        return clock["t"]

    t0 = time.time()
    try:
        out = sched.run(reqs, now_fn=now, burst=args.burst,
                        fault_hook=step_hook, speculate=args.speculate,
                        draft_planes=args.draft_planes)
    finally:
        if prof["on"]:
            jax.profiler.stop_trace()
    dt = time.time() - t0
    total = int(sum(len(v) for v in out.values()))
    s = sched.stats
    pool = eng.pool.stats()
    n = max(1, len(reqs))
    report = {
        "arch": cfg.name, "container": container,
        "requests": len(reqs), "emitted_tokens": total,
        "wall_s": round(dt, 2), "tok_per_s": round(total / max(dt, 1e-9), 1),
        "decode_steps": s.decode_steps,
        "mean_batch_occupancy": round(total / max(s.decode_steps, 1), 2),
        "preemptions": s.preemptions,
        "mean_ttft_steps": round(float(np.mean(list(ttft.values()))), 2)
        if ttft else None,
        # Wall-clock latency percentiles from the obs histograms
        # (bucket-resolution: log-spaced bounds, see obs/registry.py).
        "ttft_s_p50": round(sched._h_ttft.percentile(0.50), 6),
        "ttft_s_p95": round(sched._h_ttft.percentile(0.95), 6),
        "ttft_s_p99": round(sched._h_ttft.percentile(0.99), 6),
        "token_latency_s_p50": round(sched._h_tok.percentile(0.50), 6),
        "token_latency_s_p95": round(sched._h_tok.percentile(0.95), 6),
        "token_latency_s_p99": round(sched._h_tok.percentile(0.99), 6),
        "pool_blocks": pool.num_blocks, "pool_peak_used": pool.peak_used,
        "block_l": eng.block_l, "max_slots": eng.max_slots,
        "max_len": eng.max_len,
        # fault-tolerance layer
        "finished_ok": s.finished,
        "deadline_miss_pct": round(100.0 * s.deadline_misses / n, 1),
        "shed_pct": round(100.0 * s.shed / n, 1),
        "cancelled": s.cancelled, "failed": s.failed,
        "recoveries": s.recoveries, "corrupt_blocks": s.corrupt_blocks,
        "nan_guard_trips": s.nan_guard_trips,
        "alloc_failures": s.alloc_failures,
        "downshifted": s.downshifted,
        "quarantined_blocks": pool.quarantined,
        "injected_faults": hook.counts() if hook else {},
    }
    if args.speculate:
        report["speculate"] = args.speculate
        report["draft_planes"] = (args.draft_planes if args.draft_planes
                                  is not None
                                  else eng.default_draft_planes())
        report["spec_rounds"] = s.spec_rounds
        report["drafted"] = s.drafted
        report["draft_accepted"] = s.draft_accepted
        report["draft_rejected"] = s.draft_rejected
        report["acceptance_rate"] = round(
            s.draft_accepted / max(1, s.drafted), 3)
    obs.close()  # writes --metrics-out / --trace-out, closes streams
    if args.tokens_out:
        # Per-request emitted streams, for identity diffs across runs
        # (e.g. CI asserts --speculate K streams == burst=1 streams).
        Path(args.tokens_out).write_text(json.dumps(
            {int(uid): [int(t) for t in toks] for uid, toks in out.items()},
            sort_keys=True))
    print(json.dumps(report, indent=2))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small",
                                                         "full"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-container", default=None, type=container_name,
                    help="registry codec for the packed KV cache (sfp8, "
                    "sfp16, dense sfp-m2e4, ...); None = raw bf16 cache")
    ap.add_argument("--policy-ckpt", default=None,
                    help="checkpoint dir of a trained policy run; the KV "
                    "container geometry is derived from its stamped "
                    "PrecisionDecision (overrides --kv-container)")
    # batch mode
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    # trace mode (continuous batching over the paged pool)
    ap.add_argument("--trace", action="store_true",
                    help="simulate a Poisson request trace through the "
                    "paged engine + scheduler")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean request arrivals per virtual second")
    ap.add_argument("--step-dt", type=float, default=0.1,
                    help="virtual seconds one scheduler step advances")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--prompt-len-max", type=int, default=48)
    ap.add_argument("--max-new-min", type=int, default=4)
    ap.add_argument("--max-new-max", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="pool capacity in packed blocks (default: full "
                    "residency for every slot)")
    ap.add_argument("--burst", type=int, default=1,
                    help="decode tokens per scheduler step (one scan "
                    "dispatch)")
    ap.add_argument("--speculate", type=int, default=None, metavar="K",
                    help="self-speculative decoding: K draft steps at "
                    "prefix-precision reads + one full-width verify per "
                    "scheduler step (token-identical to --burst 1)")
    ap.add_argument("--draft-planes", type=int, default=None,
                    help="bit planes the draft expands per group "
                    "(default: container payload width - 1)")
    ap.add_argument("--tokens-out", default=None,
                    help="write the per-request emitted token streams "
                    "(JSON uid -> tokens) for identity diffs across runs")
    # fault tolerance / chaos
    ap.add_argument("--flood", action="store_true",
                    help="collapse every trace arrival to t=0")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request TTL in virtual seconds after arrival")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bounded admission queue: arrived requests beyond "
                    "this are explicitly shed")
    ap.add_argument("--storm-guard", action="store_true",
                    help="reserve running slots' growth blocks at "
                    "admission (no preemption thrash)")
    ap.add_argument("--no-integrity", action="store_true",
                    help="disable per-block checksum verification")
    ap.add_argument("--degraded-container", default=None,
                    type=container_name,
                    help="narrower geometry for pressure-downshifted "
                    "admissions (enables the pressure controller)")
    ap.add_argument("--pressure-low", type=float, default=0.25,
                    help="degrade when free pool bytes fall below this "
                    "fraction of capacity")
    ap.add_argument("--pressure-high", type=float, default=0.5,
                    help="restore once free bytes recover above this "
                    "fraction")
    ap.add_argument("--inject-flip-p", type=float, default=0.0,
                    help="per-step probability of a seeded bit flip in an "
                    "allocated packed block")
    ap.add_argument("--inject-alloc-p", type=float, default=0.0,
                    help="per-step probability of arming one transient "
                    "admission alloc failure")
    ap.add_argument("--fault-seed", type=int, default=0)
    # observability (repro.obs)
    ap.add_argument("--metrics-out", default=None,
                    help="write Prometheus-text metrics here at exit "
                    "(counters + TTFT/latency histograms)")
    ap.add_argument("--events-out", default=None,
                    help="structured-event JSONL (quarantine/scrub/"
                    "corruption lifecycle)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON of per-request "
                    "span chains here (opens in Perfetto)")
    ap.add_argument("--timeline-out", default=None,
                    help="stream the per-step pool geometry/occupancy/"
                    "pressure timeline (JSONL)")
    ap.add_argument("--profile-steps", type=int, default=None, metavar="N",
                    help="bracket jax.profiler.trace around N scheduler "
                    "steps (from step 1, past compile)")
    ap.add_argument("--profile-dir", default="experiments/traces/serve")
    return ap


def main():
    args = build_parser().parse_args()

    if args.trace:
        run_trace(args)
    else:
        run_batch(args)


if __name__ == "__main__":
    main()
