import jax.numpy as jnp
import numpy as np

from repro.core import bitchop


def run(losses, cfg, lr_changes=()):
    st = bitchop.init(cfg)
    ns = []
    for i, L in enumerate(losses):
        st = bitchop.update(st, L, cfg, lr_changed=i in lr_changes)
        ns.append(int(st.n))
    return st, ns


def test_improving_loss_shrinks_bits():
    cfg = bitchop.BitChopConfig(warmup_steps=2, max_bits=7)
    losses = [10.0 - 0.5 * i for i in range(16)]
    st, ns = run(losses, cfg)
    assert ns[-1] < 7


def test_regressing_loss_grows_bits():
    cfg = bitchop.BitChopConfig(warmup_steps=2, max_bits=7, min_bits=0)
    st = bitchop.init(cfg)._replace(n=jnp.asarray(2, jnp.int32))
    losses = [1.0 + 0.5 * i for i in range(16)]
    for L in losses:
        st = bitchop.update(st, L, cfg)
    assert int(st.n) > 2


def test_epsilon_threshold_gates_decisions():
    """With a huge noise threshold no decision ever fires; with a small one
    the controller moves. (Under pure iid noise the walk itself is
    unbiased — the stabilizing feedback is the loss reacting to n, which
    test_train.py::test_bitchop_mode_runs_and_adjusts covers end-to-end.)"""
    rng = np.random.RandomState(0)
    losses = list(3.0 + 0.05 * rng.randn(64))
    cfg_hi = bitchop.BitChopConfig(warmup_steps=4, max_bits=7, eps_scale=50.0)
    st_hi, ns_hi = run(losses, cfg_hi)
    assert int(st_hi.n) == 7 and set(ns_hi) == {7}
    cfg_lo = bitchop.BitChopConfig(warmup_steps=4, max_bits=7, eps_scale=0.2)
    st_lo, ns_lo = run(losses, cfg_lo)
    assert len(set(ns_lo)) > 1  # decisions actually fire


def test_clipping_bounds():
    cfg = bitchop.BitChopConfig(warmup_steps=0, max_bits=7, min_bits=1)
    losses = [10.0 - 0.4 * i for i in range(64)]
    st, ns = run(losses, cfg)
    assert min(ns) >= 1 and max(ns) <= 7


def test_lr_change_forces_full_precision_hold():
    cfg = bitchop.BitChopConfig(warmup_steps=0, max_bits=7,
                                lr_change_hold=5)
    st = bitchop.init(cfg)._replace(n=jnp.asarray(3, jnp.int32))
    st = bitchop.update(st, 2.0, cfg, lr_changed=True)
    for L in (1.9, 1.8, 1.7):
        st = bitchop.update(st, L, cfg)
        assert int(bitchop.effective_bits(st, cfg)) == 7
    for L in [1.6] * 8:
        st = bitchop.update(st, L, cfg)
    assert int(bitchop.effective_bits(st, cfg)) < 7  # hold expired


def test_eq8_ema_update():
    cfg = bitchop.BitChopConfig(alpha=0.25, warmup_steps=100)
    st = bitchop.init(cfg)
    st = bitchop.update(st, 4.0, cfg)      # first step: mavg = L
    assert abs(float(st.mavg) - 4.0) < 1e-6
    st = bitchop.update(st, 8.0, cfg)      # mavg + 0.25*(8-4) = 5
    assert abs(float(st.mavg) - 5.0) < 1e-6
