"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD forward: within chunks the recurrence is computed as a masked
quadratic attention-like product; across chunks a linear scan carries the
(H, P, N) state. Decode is the pure recurrence (constant state — no KV
cache), which is what makes long_500k tractable for this family.

Shapes follow the "minimal mamba2" formulation:
  x:  (B, S, H, P)   P = ssm_head_dim, H = d_inner / P
  dt: (B, S, H)      softplus(dt_raw + dt_bias)
  B,C:(B, S, G, N)   G = ssm_groups (broadcast to H), N = ssm_state
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common


def ssd_init(p: common.ParamFactory, cfg: ArchConfig):
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.conv_width
    return {
        "w_x": p((d, di), ("embed", "ssm_inner")),
        "w_z": p((d, di), ("embed", "ssm_inner")),
        "w_B": p((d, G * N), ("embed", "state")),
        "w_C": p((d, G * N), ("embed", "state")),
        "w_dt": p((d, H), ("embed", "heads")),
        "conv_x": p((cw, di), ("conv", "ssm_inner"), scale=cw ** -0.5),
        "conv_B": p((cw, G * N), ("conv", "state"), scale=cw ** -0.5),
        "conv_C": p((cw, G * N), ("conv", "state"), scale=cw ** -0.5),
        "A_log": p((H,), ("heads",), init="zeros", dtype=jnp.float32),
        "D": p((H,), ("heads",), init="ones", dtype=jnp.float32),
        "dt_bias": p((H,), ("heads",), init="zeros", dtype=jnp.float32),
        "norm": common.rmsnorm_init(p, di, axis="ssm_inner"),
        "w_out": p((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv along time. x: (B, S, C); w: (cw, C).

    With ``state`` (B, cw-1, C) prepends the carry (decode path) and also
    returns the updated carry.
    """
    cw = w.shape[0]
    if state is not None:
        x = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = x[:, -(cw - 1):, :]
    else:
        x = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
        new_state = None
    out = sum(
        x[:, i: i + (x.shape[1] - cw + 1), :] * w[i][None, None, :]
        for i in range(cw))
    return out, new_state


def _projections(params, h, cfg: ArchConfig, conv_state=None,
                 return_raw_tail=False):
    B, S, _ = h.shape
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x = h @ params["w_x"]
    z = h @ params["w_z"]
    Bp = h @ params["w_B"]
    Cp = h @ params["w_C"]
    dt_raw = (h @ params["w_dt"]).astype(jnp.float32)

    raw_tail = None
    if return_raw_tail:
        cw = cfg.conv_width
        raw_tail = {"x": x[:, -(cw - 1):], "B": Bp[:, -(cw - 1):],
                    "C": Cp[:, -(cw - 1):]}
    x, sx = _causal_conv(x, params["conv_x"],
                         conv_state["x"] if conv_state else None)
    Bp, sB = _causal_conv(Bp, params["conv_B"],
                          conv_state["B"] if conv_state else None)
    Cp, sC = _causal_conv(Cp, params["conv_C"],
                          conv_state["C"] if conv_state else None)
    x = jax.nn.silu(x.astype(jnp.float32)).astype(h.dtype)
    Bp = jax.nn.silu(Bp.astype(jnp.float32)).astype(h.dtype)
    Cp = jax.nn.silu(Cp.astype(jnp.float32)).astype(h.dtype)

    x = x.reshape(B, S, H, P)
    Bp = Bp.reshape(B, S, G, N)
    Cp = Cp.reshape(B, S, G, N)
    rep = H // G
    if rep > 1:
        Bp = jnp.repeat(Bp, rep, axis=2)
        Cp = jnp.repeat(Cp, rep, axis=2)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])  # (H,) negative
    new_conv = {"x": sx, "B": sB, "C": sC} if conv_state is not None else None
    return x, z, Bp, Cp, dt, A, (new_conv if conv_state is not None
                                 else raw_tail)


def ssd_forward(params, h: jax.Array, cfg: ArchConfig,
                return_cache: bool = False):
    """Chunked SSD over a full sequence. h: (B, S, d).

    Sequences that do not divide the chunk size are zero-padded; padded
    positions get dt = 0 (decay 1, update 0) so the carried state is
    untouched — prefill state handoff stays exact for any length.
    """
    B, S, d = h.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cs = min(cfg.ssm_chunk, S)

    x, z, Bp, Cp, dt, A, raw_tail = _projections(
        params, h, cfg, return_raw_tail=return_cache)

    S_orig = S
    pad = (-S) % cs
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bp = jnp.pad(Bp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cp = jnp.pad(Cp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0: state frozen
        S = S + pad
    nc = S // cs

    # reshape into chunks
    xc = x.reshape(B, nc, cs, H, P).astype(jnp.float32)
    Bc = Bp.reshape(B, nc, cs, H, N).astype(jnp.float32)
    Cc = Cp.reshape(B, nc, cs, H, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, cs, H)

    da = dtc * A[None, None, None, :]              # (B, nc, cs, H) log decay
    cum = jnp.cumsum(da, axis=2)                   # within-chunk cumulative
    total = cum[:, :, -1, :]                       # (B, nc, H)

    # --- intra-chunk (quadratic within the chunk) ---
    # L[i, j] = exp(cum_i - cum_j) for i >= j  (per B, chunk, H)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    G_ = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)            # C_i . B_j
    M = G_ * L
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # --- chunk-boundary states + inter-chunk linear scan ---
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)       # (B,nc,cs,H)
    state_c = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bc, decay_to_end * dtc, xc)

    def scan_fn(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        new = carry * jnp.exp(dec)[:, :, None, None] + st
        return new, carry  # emit state *before* this chunk

    init = jnp.zeros((B, H, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(total, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Cc * jnp.exp(cum)[..., None],
                         prev_states)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xc.reshape(B, S, H, P) * params["D"][None, None, :, None]
    y = y.reshape(B, S, H * P).astype(h.dtype)
    if pad:
        y = y[:, :S_orig]

    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)
                                                       ).astype(h.dtype))
    out = y @ params["w_out"]
    if return_cache:
        cache = SSDCache(conv_x=raw_tail["x"], conv_B=raw_tail["B"],
                         conv_C=raw_tail["C"], state=final_state)
        return out, cache
    return out


class SSDCache(NamedTuple):
    conv_x: jax.Array   # (B, cw-1, d_inner)
    conv_B: jax.Array   # (B, cw-1, G*N)
    conv_C: jax.Array   # (B, cw-1, G*N)
    state: jax.Array    # (B, H, N, P) fp32


def ssd_cache_init(cfg: ArchConfig, batch: int, dtype) -> SSDCache:
    cw = cfg.conv_width
    return SSDCache(
        conv_x=jnp.zeros((batch, cw - 1, cfg.d_inner), dtype),
        conv_B=jnp.zeros((batch, cw - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
        conv_C=jnp.zeros((batch, cw - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                         cfg.ssm_head_dim), jnp.float32),
    )


def ssd_cache_spec(cfg: ArchConfig, batch: int, dtype) -> SSDCache:
    init = ssd_cache_init(cfg, 0, dtype)  # shapes only; rebuild with batch
    cw = cfg.conv_width
    return SSDCache(
        conv_x=jax.ShapeDtypeStruct((batch, cw - 1, cfg.d_inner), dtype),
        conv_B=jax.ShapeDtypeStruct(
            (batch, cw - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
        conv_C=jax.ShapeDtypeStruct(
            (batch, cw - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
        state=jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32),
    )


def ssd_decode(params, h_tok: jax.Array, cache: SSDCache, cfg: ArchConfig
               ) -> Tuple[jax.Array, SSDCache]:
    """One-token step: h = exp(dt*A) h + dt * B x ; y = C . h + D x."""
    B = h_tok.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_state = {"x": cache.conv_x, "B": cache.conv_B, "C": cache.conv_C}
    x, z, Bp, Cp, dt, A, new_conv = _projections(params, h_tok, cfg, conv_state)

    xf = x[:, 0].astype(jnp.float32)         # (B, H, P)
    Bf = Bp[:, 0].astype(jnp.float32)        # (B, H, N)
    Cf = Cp[:, 0].astype(jnp.float32)
    dtf = dt[:, 0]                           # (B, H)

    decay = jnp.exp(dtf * A[None, :])        # (B, H)
    upd = jnp.einsum("bhn,bhp->bhnp", Bf, xf * dtf[..., None])
    state = cache.state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cf, state)
    y = y + xf * params["D"][None, :, None]
    y = y.reshape(B, 1, H * P).astype(h_tok.dtype)
    y = common.rmsnorm(params["norm"],
                       y * jax.nn.silu(z.astype(jnp.float32)).astype(h_tok.dtype))
    out = y @ params["w_out"]
    return out, SSDCache(conv_x=new_conv["x"], conv_B=new_conv["B"],
                         conv_C=new_conv["C"], state=state)
