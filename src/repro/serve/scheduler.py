"""Continuous-batching request scheduler over the paged serving engine.

vLLM-style control loop, sized down to this repo's engine: a FIFO request
queue, admission gated on free packed blocks (the pool measures capacity
in *compressed* bytes, so a tighter container admits more concurrent
requests), prefill/decode interleaving (each ``step()`` first admits
arrived requests — one prefill each — then advances every running slot by
one batched decode step), slot recycling (a finished request frees its
blocks and its slot in the same step; the next pending request takes them
without recompiling anything), and recompute-preemption (when the pool
cannot supply a running request's next block, the youngest other request
is evicted, its blocks freed, and it re-enters the queue with its
already-emitted tokens folded into the prompt — emitted tokens are never
retracted).

Tokens stream per request: every emitted token fires ``on_token(uid,
token, done)`` (scheduler-wide and per-request callbacks) the step it is
produced.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.engine import PagedEngine

OnToken = Callable[[Any, int, bool], None]


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in the caller's clock
    (the trace simulator drives a virtual clock); ``on_token`` streams
    this request's tokens as they are produced."""

    uid: Any
    prompt: np.ndarray          # (S,) int32 token ids
    max_new: int
    arrival: float = 0.0
    on_token: Optional[OnToken] = None


@dataclasses.dataclass
class _Running:
    req: Request
    slot: int
    admit_seq: int
    n_ctx: int                  # tokens whose KV is in the pool (prompt')
    last_tok: int               # most recent emitted token (next step's input)
    emitted: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    preemptions: int = 0
    decode_steps: int = 0
    emitted_tokens: int = 0


class Scheduler:
    def __init__(self, engine: PagedEngine,
                 on_token: Optional[OnToken] = None):
        self.engine = engine
        self.on_token = on_token
        self.pending: "deque[Request]" = deque()
        self.running: Dict[int, _Running] = {}
        self.free_slots = list(range(engine.max_slots - 1, -1, -1))
        self.finished: Dict[Any, np.ndarray] = {}
        self.stats = SchedulerStats()
        self._admit_seq = 0
        # Full per-uid emission history: survives recompute-preemption
        # (_Running.emitted only tracks the current residency — its length
        # is what the requeued max_new is discounted by).
        self._history: Dict[Any, List[int]] = {}

    # -- queue -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    @property
    def idle(self) -> bool:
        return not self.pending and not self.running

    # -- internals -------------------------------------------------------

    def _emit(self, st: _Running, tok: int) -> Tuple[Any, int, bool]:
        st.emitted.append(int(tok))
        st.last_tok = int(tok)
        self._history.setdefault(st.req.uid, []).append(int(tok))
        self.stats.emitted_tokens += 1
        done = (len(st.emitted) >= st.req.max_new
                or st.n_ctx + 1 >= self.engine.max_len)
        for cb in (st.req.on_token, self.on_token):
            if cb is not None:
                cb(st.req.uid, int(tok), done)
        return (st.req.uid, int(tok), done)

    def _finish(self, st: _Running) -> None:
        self.engine.pool.free_slot(st.slot)
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        self.finished[st.req.uid] = np.asarray(
            self._history.get(st.req.uid, st.emitted), np.int32)
        self.stats.finished += 1

    def _preempt(self, st: _Running) -> None:
        """Recompute-preemption: fold emitted tokens into the prompt and
        requeue at the front; the victim's blocks and slot free now."""
        self.engine.pool.free_slot(st.slot)
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        req = st.req
        if st.emitted:
            req = dataclasses.replace(
                req, prompt=np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(st.emitted, np.int32)]),
                max_new=req.max_new - len(st.emitted))
        self.pending.appendleft(req)
        self.stats.preemptions += 1

    def _admit(self, now: Optional[float],
               emitted: List[Tuple[Any, int, bool]]) -> None:
        pool = self.engine.pool
        while self.pending and self.free_slots:
            req = self.pending[0]
            if now is not None and req.arrival > now:
                break  # FIFO: later arrivals queue behind
            n0 = int(np.asarray(req.prompt).size)
            if not pool.can_admit(n0):
                from repro.serve.pool import blocks_for
                if blocks_for(n0 + 1, pool.block_l) > pool.num_blocks:
                    raise RuntimeError(
                        f"pool of {pool.num_blocks} blocks cannot ever "
                        f"admit a request of {n0} prompt tokens")
                break  # transient: blocks free up as running requests end
            self.pending.popleft()
            slot = self.free_slots.pop()
            ok = pool.alloc_upto(slot, n0)
            assert ok, "can_admit guaranteed the blocks"
            tok0 = self.engine.prefill_into_slot(slot, req.prompt)
            self._admit_seq += 1
            st = _Running(req=req, slot=slot, admit_seq=self._admit_seq,
                          n_ctx=n0, last_tok=tok0)
            self.running[slot] = st
            self.stats.admitted += 1
            emitted.append(self._emit(st, tok0))
            if emitted[-1][2]:  # max_new == 1 (or budget exhausted)
                self._finish(st)

    def _ensure_blocks(self, horizon: int = 1) -> None:
        """Every running slot needs blocks covering its next ``horizon``
        positions before the batched step (the whole burst runs against
        one fixed block table); when the pool runs dry the *youngest*
        running request (possibly the requester itself) is preempted —
        oldest-first priority, so head-of-line requests always drain."""
        pool = self.engine.pool
        for slot in sorted(self.running,
                           key=lambda s: self.running[s].admit_seq):
            st = self.running.get(slot)
            if st is None:  # preempted earlier this round
                continue
            while not pool.alloc_upto(slot, st.n_ctx + horizon):
                victim = max(self.running.values(),
                             key=lambda r: r.admit_seq)
                if victim.slot == slot and len(self.running) == 1:
                    raise RuntimeError(
                        f"pool of {pool.num_blocks} blocks cannot hold one "
                        f"request of {st.n_ctx + horizon} tokens")
                self._preempt(victim)
                if victim.slot == slot:
                    break  # requester preempted itself; skip its step

    def _burst_len(self, burst: int) -> int:
        """Clamp the requested burst to what this round can actually use.

        Hard cap: no running slot may step past ``max_len`` (its blocks
        and positions end there). Efficiency cap: once every running slot
        has hit its token budget there is nothing left to emit, so the
        burst never outruns the *largest* remaining budget — slots that
        finish mid-burst keep decoding harmlessly (their extra tokens are
        computed but never replayed), which is what keeps the executable
        shape fixed."""
        cap = min(self.engine.max_len - st.n_ctx
                  for st in self.running.values())
        need = max(st.req.max_new - len(st.emitted)
                   for st in self.running.values())
        return max(1, min(int(burst), cap, need))

    # -- the loop --------------------------------------------------------

    def step(self, now: Optional[float] = None, burst: int = 1
             ) -> List[Tuple[Any, int, bool]]:
        """Admit arrived requests, then advance every running slot by up
        to ``burst`` tokens in one jitted dispatch. Admission, slot
        recycling and preemption happen only at burst boundaries (here,
        before the device call); per-token streaming callbacks are
        replayed in step order from the burst's (K, max_slots) token
        buffer, so a request that hits its budget mid-burst still sees
        ``done`` on exactly its last token. Returns the (uid, token,
        done) tuples emitted this step."""
        emitted: List[Tuple[Any, int, bool]] = []
        self._admit(now, emitted)
        if not self.running:
            return emitted
        K = self._burst_len(burst)
        try:
            self._ensure_blocks(K)
        except RuntimeError:
            if K == 1:
                raise
            # Pool too tight for the whole burst horizon even after
            # evicting everyone else: degrade to single-step pacing
            # rather than refusing a request burst=1 could serve.
            K = 1
            self._ensure_blocks(K)
        if not self.running:
            return emitted  # everyone preempted back to the queue

        toks = np.zeros(self.engine.max_slots, np.int32)
        pos = np.zeros(self.engine.max_slots, np.int32)
        for st in self.running.values():
            toks[st.slot] = st.last_tok
            pos[st.slot] = st.n_ctx  # the input token's absolute position
        nxt = self.engine.decode_burst(toks, pos, K)  # (K, max_slots)
        self.stats.decode_steps += K

        live = list(self.running.values())
        for i in range(K):
            for st in live:
                if self.running.get(st.slot) is not st:
                    continue  # finished earlier in this burst
                st.n_ctx += 1
                _, _, done = res = self._emit(st, int(nxt[i, st.slot]))
                emitted.append(res)
                if done:
                    self._finish(st)
        return emitted

    def run(self, requests=None, now_fn=None, max_steps: int = 100_000,
            burst: int = 1) -> Dict[Any, np.ndarray]:
        """Drive until every submitted request finishes. ``now_fn`` feeds
        the admission clock (trace simulation); None admits on submit
        order only. ``burst`` > 1 decodes K tokens per scheduler step
        (one scan dispatch), touching the host only between bursts."""
        if requests:
            for r in requests:
                self.submit(r)
        for _ in range(max_steps):
            if self.idle:
                return dict(self.finished)
            self.step(now=None if now_fn is None else now_fn(),
                      burst=burst)
        raise RuntimeError(f"scheduler did not drain in {max_steps} steps")
