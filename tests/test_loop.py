"""Fault-tolerant loop: failure injection -> restore -> continue."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import policies
from repro.train import loop as loop_mod
from repro.train.state import TrainState
from repro.optim import adamw

_DIMS = policies.ScopeDims(n_periods=1, n_rem=0, man_bits=7, exp_bits=8)


def _mini_state():
    params = {"w": jnp.zeros((4,))}
    return TrainState(
        params=params, opt=adamw.init(params),
        pstate=policies.get("qm+bitchop").init_state(_DIMS),
        step=jnp.zeros((), jnp.int32), rng=jax.random.PRNGKey(0),
        grad_residual=None)


def _step(state, batch):
    new = state._replace(
        params={"w": state.params["w"] + batch["x"].mean()},
        step=state.step + 1)
    return new, {"loss": jnp.sum(new.params["w"])}


def _batches(start):
    def gen():
        i = start
        while True:
            yield {"x": jnp.full((2,), float(i + 1))}
            i += 1
    return gen()


def test_loop_runs_and_checkpoints(tmp_path):
    cfg = loop_mod.LoopConfig(total_steps=10, ckpt_every=4,
                              ckpt_dir=str(tmp_path / "ck"))
    res = loop_mod.run(_step, _mini_state(), _batches, cfg)
    assert int(res.state.step) == 10
    assert res.restarts == 0
    # deterministic data: w = sum(1..10)
    assert float(res.state.params["w"][0]) == sum(range(1, 11))


def test_loop_recovers_from_injected_failure(tmp_path):
    cfg = loop_mod.LoopConfig(total_steps=10, ckpt_every=2,
                              ckpt_dir=str(tmp_path / "ck"))
    fired = {"done": False}

    def fault(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("simulated node failure")

    res = loop_mod.run(_step, _mini_state(), _batches, cfg, fault_hook=fault)
    assert res.restarts == 1
    assert int(res.state.step) == 10
    assert float(res.state.params["w"][0]) == sum(range(1, 11))  # exact replay


def test_loop_gives_up_after_max_restarts(tmp_path):
    cfg = loop_mod.LoopConfig(total_steps=10, ckpt_every=2,
                              ckpt_dir=str(tmp_path / "ck"), max_restarts=2)

    def always_fail(step):
        if step == 5:
            raise RuntimeError("persistent failure")

    try:
        loop_mod.run(_step, _mini_state(), _batches, cfg,
                     fault_hook=always_fail)
        assert False, "should have raised"
    except RuntimeError:
        pass


def test_loop_resumes_from_existing_checkpoint(tmp_path):
    ck = str(tmp_path / "ck")
    cfg = loop_mod.LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=ck)
    loop_mod.run(_step, _mini_state(), _batches, cfg)
    # second run continues to 12 from the saved state
    cfg2 = loop_mod.LoopConfig(total_steps=12, ckpt_every=3, ckpt_dir=ck)
    res = loop_mod.run(_step, _mini_state(), _batches, cfg2)
    assert int(res.state.step) == 12
    assert float(res.state.params["w"][0]) == sum(range(1, 13))


def test_straggler_watchdog(tmp_path):
    import time

    def slow_step(state, batch):
        time.sleep(0.05)
        return _step(state, batch)

    cfg = loop_mod.LoopConfig(total_steps=3, step_deadline_s=0.01)
    res = loop_mod.run(slow_step, _mini_state(), _batches, cfg)
    assert res.straggler_steps == 3
