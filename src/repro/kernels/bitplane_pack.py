"""Pallas TPU kernels: dense bit-plane container pack/unpack.

The variable payload-width realization of the paper's containers: instead
of rounding every payload up to an 8/16-bit lane (kernels/sfp_pack.py),
the payload word — sign + delta-exponent + kept mantissa, P = 1 + E + K
bits for any width 3..16 — is stored as P byte-aligned *bit planes* per
128-lane group (16 bytes per plane, Gecko-style), so an ``sfp-m2e4``
tensor really occupies 7 bits/value plus the shared 8-bit group bases.

The pack body is shared with kernels/sfp_pack.py (``_pack_body``: the
fused Q(M, n) quantize + delta-exponent encode over one VMEM block); this
module adds the word <-> plane transpose on either side, so quantize,
container encode and plane packing all happen in a single pass over the
activation — one HBM read, exactly like the fixed-lane fused kernel.

Layout (bit-level oracle: kernels/ref.py ``bitplane_pack``/``_unpack``):
  planes (R, P*16) uint8 — row r, plane p, byte i holds bit p of the
  payload words of lanes 8i..8i+7 of group r (bit j <-> lane 8i + j);
  bases  (R, 1)   uint8 — the shared per-group base exponents.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import containers
from repro.kernels import ref as kref
from repro.kernels.sfp_pack import (DEFAULT_BLOCK_ROWS, _pack_body, _row_grid,
                                    _to_rows)

LANES = kref.GROUP  # 128


def vmem_estimate(*, fields: kref.PackFields,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  dtype=jnp.bfloat16, fused: bool = True) -> int:
    """Static per-grid-step VMEM footprint model, in bytes.

    Same accounting as ``sfp_pack.vmem_estimate`` with the plane-packed
    output window ((block_rows, P*16) uint8) and one extra int32 word tile
    for the word <-> plane transpose. Budget model for
    ``repro.analysis.vmem``, not an allocator.
    """
    isz = jnp.dtype(dtype).itemsize
    pb = fields.group_payload_bytes
    blocks = 2 * (
        block_rows * LANES * isz             # x in
        + block_rows * pb                    # plane bytes out (uint8)
        + block_rows * 1                     # bases out (uint8)
    )
    if fused:
        blocks += 2 * 4                      # n scalar (1, 1) int32
    temps = 5 * block_rows * LANES * 4
    return blocks + temps


def _bitplane_pack_kernel(x_ref, plane_ref, base_ref, *, spec, fields):
    word, base = _pack_body(x_ref[...], fields, spec)
    plane_ref[...] = kref.plane_pack_words(word, fields.payload_bits)
    base_ref[...] = base


def _bitplane_quantize_pack_kernel(n_ref, x_ref, plane_ref, base_ref, *,
                                   spec, fields):
    word, base = _pack_body(x_ref[...], fields, spec, n=n_ref[0, 0])
    plane_ref[...] = kref.plane_pack_words(word, fields.payload_bits)
    base_ref[...] = base


def _bitplane_unpack_kernel(plane_ref, base_ref, o_ref, *, spec,
                            fields: kref.PackFields):
    # Same decode body as the ref oracle and the flash-decode tiles
    # (SWAR plane transpose + uint8 field machine where the geometry
    # allows) — one definition, bit-exact everywhere.
    o_ref[...] = kref.unpack_planes(plane_ref[...], base_ref[...], fields,
                                    spec)


def _plane_pack_call(x, n, *, fields: kref.PackFields, block_rows: int,
                     interpret: Optional[bool]):
    interpret = kref.default_interpret(interpret)
    spec = containers.spec_for(x)
    rows2d, _pad = _to_rows(x)
    rows2d, rows, rpad, block_rows = _row_grid(rows2d, block_rows)
    grid = (rows2d.shape[0] // block_rows,)
    pb = fields.group_payload_bytes  # P * 16 plane bytes per group row

    out_specs = [
        pl.BlockSpec((block_rows, pb), lambda i: (i, 0)),
        pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((rows2d.shape[0], pb), jnp.uint8),
        jax.ShapeDtypeStruct((rows2d.shape[0], 1), jnp.uint8),
    ]
    row_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    if n is None:
        planes, bases = pl.pallas_call(
            functools.partial(_bitplane_pack_kernel, spec=spec,
                              fields=fields),
            grid=grid, in_specs=[row_spec], out_specs=out_specs,
            out_shape=out_shape, interpret=interpret)(rows2d)
    else:
        planes, bases = pl.pallas_call(
            functools.partial(_bitplane_quantize_pack_kernel, spec=spec,
                              fields=fields),
            grid=grid,
            in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), row_spec],
            out_specs=out_specs, out_shape=out_shape,
            interpret=interpret)(jnp.asarray(n, jnp.int32).reshape(1, 1),
                                 rows2d)
    if rpad:
        planes, bases = planes[:rows], bases[:rows]
    return planes, bases


@functools.partial(jax.jit, static_argnames=("fields", "block_rows",
                                             "interpret"))
def bitplane_pack(x: jax.Array, *, fields: kref.PackFields,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: Optional[bool] = None):
    """Dense pack: (planes (R, P*16) uint8, bases (R, 1) uint8)."""
    return _plane_pack_call(x, None, fields=fields, block_rows=block_rows,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("fields", "block_rows",
                                             "interpret"))
def bitplane_quantize_pack(x: jax.Array, n: jax.Array, *,
                           fields: kref.PackFields,
                           block_rows: int = DEFAULT_BLOCK_ROWS,
                           interpret: Optional[bool] = None):
    """Fused Q(M, n) + dense plane pack: one VMEM pass, one HBM read.

    Bit-exact against mantissa quantization followed by ``bitplane_pack``;
    ``n`` is a traced scalar carried in SMEM (updated per step by the
    precision policy).
    """
    return _plane_pack_call(x, n, fields=fields, block_rows=block_rows,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "fields",
                                             "block_rows", "interpret"))
def bitplane_unpack(planes: jax.Array, bases: jax.Array, *, shape: tuple,
                    dtype, fields: kref.PackFields,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: Optional[bool] = None) -> jax.Array:
    interpret = kref.default_interpret(interpret)
    spec = containers.spec_for(jnp.dtype(dtype))
    pb = fields.group_payload_bytes

    rows = planes.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        planes = jnp.pad(planes, ((0, rpad), (0, 0)))
        bases = jnp.pad(bases, ((0, rpad), (0, 0)))
    grid = (planes.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_bitplane_unpack_kernel, spec=spec, fields=fields),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, pb), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((planes.shape[0], LANES), spec.dtype),
        interpret=interpret,
    )(planes, bases)
    if rpad:
        out = out[:rows]
    n = 1
    for s in shape:
        n *= s
    return out.reshape(-1)[:n].reshape(shape)
