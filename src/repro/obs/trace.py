"""Per-request span tracer exporting Chrome ``trace_event`` JSON.

Every request gets its own lane (tid) inside one process row, so the
Perfetto timeline reads as: one horizontal track per request, spans for
`queued → prefill → decode[burst] → ...`, instants for submit / preempt /
recover / retire, with geometry, blocks held, and downshift flags as
span args. The output format is the Trace Event Format's JSON-array
flavor (``{"traceEvents": [...]}``) — the same container
``jax.profiler.trace`` produces — so a serve trace opens in
Perfetto/``chrome://tracing`` next to the profiler capture from
`bench_decode_micro.py`.

Timestamps are microseconds from a monotonic clock; the tracer never
touches device values, so it adds no host sync — callers hand it host
scalars only, after any jitted step has already been consumed at the
host boundary.
"""
from __future__ import annotations

import json
import time
from typing import Any, NamedTuple

_PID = 1  # single-process: one row in the viewer


class _Open(NamedTuple):
    name: str
    tid: int
    t0_us: float
    args: dict[str, Any]


class SpanTracer:
    def __init__(self, clock=time.perf_counter) -> None:
        self._clock = clock
        self._t0 = clock()
        self.events: list[dict[str, Any]] = []
        self._tids: dict[str, int] = {}

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _tid(self, lane: str) -> int:
        tid = self._tids.get(lane)
        if tid is None:
            tid = self._tids[lane] = len(self._tids) + 1
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
                "args": {"name": lane}})
        return tid

    def begin(self, name: str, lane: str, **args: Any) -> _Open:
        """Open a span on ``lane`` (request uid or subsystem name)."""
        return _Open(name, self._tid(lane), self._now_us(), args)

    def end(self, span: _Open, **extra: Any) -> None:
        t1 = self._now_us()
        self.events.append({
            "name": span.name, "ph": "X", "pid": _PID, "tid": span.tid,
            "ts": span.t0_us, "dur": max(t1 - span.t0_us, 0.0),
            "args": {**span.args, **extra}})

    def complete(self, name: str, lane: str, dur_s: float,
                 **args: Any) -> None:
        """Record an already-finished span ending now, ``dur_s`` long."""
        t1 = self._now_us()
        dur = max(dur_s, 0.0) * 1e6
        # A span can out-span the tracer (the first prefill includes jit
        # compile; the tracer may be younger): clamp its start into the
        # trace's epoch rather than emitting a negative timestamp.
        self.events.append({
            "name": name, "ph": "X", "pid": _PID, "tid": self._tid(lane),
            "ts": max(t1 - dur, 0.0), "dur": dur, "args": args})

    def instant(self, name: str, lane: str, **args: Any) -> None:
        self.events.append({
            "name": name, "ph": "i", "s": "t", "pid": _PID,
            "tid": self._tid(lane), "ts": self._now_us(), "args": args})

    def export(self) -> dict[str, Any]:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.export(), fh)

    # ---- queries (test/report support) ----------------------------------

    def spans(self, lane: str | None = None,
              name: str | None = None) -> list[dict[str, Any]]:
        tid = self._tids.get(lane) if lane is not None else None
        return [e for e in self.events
                if e["ph"] in ("X", "i")
                and (lane is None or e["tid"] == tid)
                and (name is None or e["name"] == name)]

    def lanes(self) -> list[str]:
        return list(self._tids)
