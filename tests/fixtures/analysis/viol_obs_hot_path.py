"""Seeded violations: telemetry mutations inside a traced scope.

The repro.obs API is host-side Python; from jitted code each call below
either records a trace-time constant (once, at trace time — not per
step) or would need a host callback to mean anything. The loop records
at the host boundary after the step returns.
"""
import jax
import jax.numpy as jnp

from repro import obs as obs_mod

obs = obs_mod.Obs(trace=True, timeline=True)


def step(x):
    y = jnp.tanh(x)
    obs.registry.counter("fixture_steps_total", "hot").inc()  # LINT: obs-no-hot-path-sync
    obs.tracer.instant("mid_step", "train")  # LINT: obs-no-hot-path-sync
    obs.event("fixture_event", val=1.0)  # LINT: obs-no-hot-path-sync
    obs.timeline.record_serve(0, occupancy=0.5)  # LINT: obs-no-hot-path-sync
    return y


out = jax.jit(step)(jnp.zeros((4,)))


def host_report(dt):
    # NOT traced: recording after the jitted step returned is the point.
    obs.registry.histogram("fixture_step_seconds", "wall",
                           unit="s").observe(dt)
    obs.tracer.complete("step", "train", dt)
