"""bit_exact codec: fake-quant accounting mode.

The payload *is* the (mantissa-truncated) tensor in its native dtype — no
byte-level repacking happens on device. This is the paper's accounting
configuration: the quantizer runs for real (so accuracy effects are
faithful) while the footprint is what the paper's variable-length encoding
*would* write — sign + kept mantissa + Gecko-compressed exponents
(core/footprint.py's bit-exact model).
"""
from __future__ import annotations

import jax

from repro.codecs import base
from repro.kernels import ops

BIT_EXACT = "bit_exact"


class BitExactCodec(base.Codec):
    name = BIT_EXACT

    def pack(self, x: jax.Array, bits=None) -> base.PackedTensor:
        q = x if bits is None else ops.mantissa_quantize(x, bits)
        return base.PackedTensor(self.name, x.shape, x.dtype, {"payload": q})

    def unpack(self, packed: base.PackedTensor) -> jax.Array:
        return packed.data["payload"]

    def lossless_for(self, dtype) -> bool:
        return True  # bits=None pack is the identity

    def packed_bits(self, x: jax.Array, bits=None) -> float:
        from repro.core import containers, footprint
        n = (containers.spec_for(x).man_bits if bits is None else bits)
        return float(footprint.sfp_footprint(x, n).total_bits)
