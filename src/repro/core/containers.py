"""Floating-point container manipulation.

Implements the bit-level plumbing behind Schrödinger's FP: splitting
FP32/BF16 values into (sign, exponent, mantissa) fields, the mantissa
truncation quantizer Q(M, n) of eq. (5), and the stochastic fractional
bitlength extension of eq. (6).

All functions are pure jnp and differentiable only where explicitly made so
(see quantum_mantissa.py for the custom VJPs).
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FloatSpec:
    """Static description of an IEEE-ish floating point container."""

    name: str
    dtype: jnp.dtype
    int_dtype: jnp.dtype
    total_bits: int
    exp_bits: int
    man_bits: int
    bias: int

    @property
    def sign_shift(self) -> int:
        return self.total_bits - 1

    @property
    def exp_shift(self) -> int:
        return self.man_bits

    @property
    def exp_mask(self) -> int:
        return (1 << self.exp_bits) - 1

    @property
    def man_mask(self) -> int:
        return (1 << self.man_bits) - 1


FP32 = FloatSpec("fp32", jnp.dtype(jnp.float32), jnp.dtype(jnp.uint32), 32, 8, 23, 127)
BF16 = FloatSpec("bf16", jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.uint16), 16, 8, 7, 127)
FP16 = FloatSpec("fp16", jnp.dtype(jnp.float16), jnp.dtype(jnp.uint16), 16, 5, 10, 15)

_SPECS = {s.dtype: s for s in (FP32, BF16, FP16)}


def spec_for(x: Union[jax.Array, jnp.dtype]) -> FloatSpec:
    dtype = jnp.dtype(x.dtype if hasattr(x, "dtype") else x)
    try:
        return _SPECS[dtype]
    except KeyError as e:  # pragma: no cover - guarded by callers
        raise ValueError(f"No FloatSpec for dtype {dtype}") from e


def bitcast_to_int(x: jax.Array) -> jax.Array:
    """Reinterpret a float array as its unsigned integer container."""
    spec = spec_for(x)
    return jax.lax.bitcast_convert_type(x, spec.int_dtype)


def bitcast_to_float(u: jax.Array, spec: FloatSpec) -> jax.Array:
    return jax.lax.bitcast_convert_type(u.astype(spec.int_dtype), spec.dtype)


def split_fields(x: jax.Array):
    """Split into (sign, biased_exponent, mantissa) unsigned integer fields."""
    spec = spec_for(x)
    u = bitcast_to_int(x)
    sign = (u >> spec.sign_shift) & 1
    exp = (u >> spec.exp_shift) & spec.exp_mask
    man = u & spec.man_mask
    return sign, exp, man


def combine_fields(sign: jax.Array, exp: jax.Array, man: jax.Array, spec: FloatSpec) -> jax.Array:
    u = (
        (sign.astype(spec.int_dtype) << spec.sign_shift)
        | ((exp.astype(spec.int_dtype) & spec.exp_mask) << spec.exp_shift)
        | (man.astype(spec.int_dtype) & spec.man_mask)
    )
    return bitcast_to_float(u, spec)


def _mantissa_keep_mask(n: jax.Array, spec: FloatSpec) -> jax.Array:
    """Bitmask keeping the top ``n`` mantissa bits. ``n`` may be traced.

    Equivalent to ``(2^n - 1) << (m - n)`` from eq. (5), expressed as
    ``man_mask ^ (2^(m-n) - 1)`` which is shift-safe for n in [0, m].
    """
    n = jnp.asarray(n, dtype=jnp.int32)
    n = jnp.clip(n, 0, spec.man_bits)
    drop = (spec.man_bits - n).astype(spec.int_dtype)
    one = jnp.asarray(1, dtype=spec.int_dtype)
    low = jnp.left_shift(one, drop) - one  # 2^(m-n) - 1
    return jnp.asarray(spec.man_mask, dtype=spec.int_dtype) ^ low


def truncate_mantissa(x: jax.Array, n) -> jax.Array:
    """Q(M, n): zero all but the top ``n`` mantissa bits (paper eq. 5).

    ``n`` is an integer (scalar or broadcastable array, possibly traced).
    Not differentiable — see quantum_mantissa.qm_quantize for the STE
    wrapper.
    """
    spec = spec_for(x)
    u = bitcast_to_int(x)
    keep = _mantissa_keep_mask(n, spec)
    mask = (
        jnp.asarray(~spec.man_mask & ((1 << spec.total_bits) - 1), dtype=spec.int_dtype)
        | keep
    )
    return bitcast_to_float(u & mask, spec)


def round_mantissa(x: jax.Array, n) -> jax.Array:
    """Round-to-nearest-even mantissa reduction to ``n`` bits.

    A beyond-paper variant of eq. (5): instead of truncation, adds half an
    ULP of the target precision before masking. Used by the gradient
    compression path where unbiasedness matters less than magnitude
    preservation; the paper's quantizer is ``truncate_mantissa``.
    """
    spec = spec_for(x)
    n = jnp.clip(jnp.asarray(n, dtype=jnp.int32), 0, spec.man_bits)
    u = bitcast_to_int(x)
    drop = (spec.man_bits - n).astype(spec.int_dtype)
    one = jnp.asarray(1, dtype=spec.int_dtype)
    # round-half-away: add 2^(drop-1) where drop > 0, then mask.
    half = jnp.where(drop > 0, jnp.left_shift(one, jnp.maximum(drop, 1) - one), 0)
    exp_all_ones = ((u >> spec.exp_shift) & spec.exp_mask) == spec.exp_mask
    u2 = u + half.astype(spec.int_dtype)
    # Adding into the mantissa may carry into the exponent — that is the
    # correct IEEE behaviour (rounds up to the next binade). Guard inf/nan.
    u2 = jnp.where(exp_all_ones, u, u2)
    keep = _mantissa_keep_mask(n, spec)
    mask = (
        jnp.asarray(~spec.man_mask & ((1 << spec.total_bits) - 1), dtype=spec.int_dtype)
        | keep
    )
    return bitcast_to_float(u2 & mask, spec)


def stochastic_bitlength(n_float: jax.Array, key: jax.Array, max_bits: int,
                         min_bits: int = 0) -> jax.Array:
    """Eq. (6): draw an integer bitlength from a real-valued one.

    Returns floor(n) + Bernoulli(frac(n)), clipped to [min_bits, max_bits].
    One draw per call — the paper (§IV-A3) finds per-tensor granularity
    sufficient, so callers pass one key per tensor per step. ``min_bits``
    defaults to 0 (the mantissa case); Quantum Exponent clamps to 2 because
    a 1-bit IEEE exponent has no normal codes.
    """
    nf = jnp.clip(jnp.asarray(n_float, jnp.float32), float(min_bits),
                  float(max_bits))
    floor_n = jnp.floor(nf)
    frac = nf - floor_n
    bump = jax.random.bernoulli(key, frac).astype(jnp.int32)
    return jnp.clip(floor_n.astype(jnp.int32) + bump, min_bits, max_bits)


MIN_EXP_BITS = 2  # a 1-bit IEEE-style exponent field has no normal codes


def exponent_range(e: jax.Array, spec: FloatSpec):
    """Unbiased normal-exponent range [lo, hi] of an ``e``-bit container.

    IEEE convention: an e-bit exponent field with bias 2^(e-1)-1 keeps
    biased codes 1..2^e-2 for normals (0 = zero/subnormal, all-ones =
    inf/nan), i.e. unbiased exponents in [2 - 2^(e-1), 2^(e-1) - 1].
    ``e`` may be traced; it is clipped to [MIN_EXP_BITS, spec.exp_bits].
    """
    e = jnp.clip(jnp.asarray(e, jnp.int32), MIN_EXP_BITS, spec.exp_bits)
    bias_e = jnp.left_shift(1, e - 1) - 1
    lo = 1 - bias_e
    hi = (jnp.left_shift(1, e) - 2) - bias_e
    return lo, hi


def truncate_exponent(x: jax.Array, e, bias_offset=0) -> jax.Array:
    """Clamp ``x`` to the exponent range of an ``e``-bit container.

    The exponent-side analogue of eq. (5): values whose unbiased exponent
    falls below the e-bit normal range flush to (signed) zero — as do the
    source container's own zeros/subnormals — values above it saturate to
    the largest in-range binade (exponent clamped, mantissa kept, so a
    preceding mantissa truncation survives), and inf/nan pass through
    untouched. ``e`` may be a traced int32; it is clipped to
    [MIN_EXP_BITS, spec.exp_bits], and at e == spec.exp_bits the only
    effect is the flush of source subnormals (FTZ semantics).

    ``bias_offset`` (int, traced ok) shifts the representable window by
    that many binades — an AdaptivFloat-style per-tensor exponent bias: a
    positive offset spends the e-bit range on larger magnitudes, a
    negative one on smaller. The shifted window is clipped to the source
    container's own normal range (there is nowhere else to encode it).

    Not differentiable — see quantum_exponent.qe_quantize for the STE +
    bitlength-gradient wrapper (and policies/afloat.py for the learned
    bias offset).
    """
    spec = spec_for(x)
    sign, exp, man = split_fields(x)
    lo, hi = exponent_range(e, spec)
    if not (isinstance(bias_offset, int) and bias_offset == 0):
        b = jnp.asarray(bias_offset, jnp.int32)
        src_lo = 1 - spec.bias
        src_hi = (spec.exp_mask - 1) - spec.bias
        lo = jnp.clip(lo + b, src_lo, src_hi)
        hi = jnp.clip(hi + b, src_lo, src_hi)
    unb = exp.astype(jnp.int32) - spec.bias
    special = exp == spec.exp_mask          # inf / nan: keep verbatim
    underflow = (~special) & (unb < lo)     # incl. exp==0 (zero/subnormal)
    overflow = (~special) & (unb > hi)
    exp_new = jnp.where(overflow, (hi + spec.bias).astype(exp.dtype), exp)
    exp_new = jnp.where(underflow, jnp.zeros_like(exp), exp_new)
    man_new = jnp.where(underflow, jnp.zeros_like(man), man)
    return combine_fields(sign, exp_new, man_new, spec)


def exponent_field(x: jax.Array) -> jax.Array:
    """The biased exponent field as uint8 (input to Gecko)."""
    _, exp, _ = split_fields(x)
    return exp.astype(jnp.uint8)


def finite_like(x: jax.Array) -> jax.Array:
    """True where x is finite (exponent field not all-ones)."""
    spec = spec_for(x)
    _, exp, _ = split_fields(x)
    return exp != spec.exp_mask
