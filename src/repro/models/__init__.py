"""Model zoo: unified decoder (dense/MoE/SSM/hybrid/audio/vlm) + paper CNNs."""
