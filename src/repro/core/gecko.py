"""Gecko: lossless exponent compression (paper §IV-C).

Training exponents concentrate tightly around the bias (127). Gecko stores
each exponent with only as many bits as its magnitude needs, amortizing the
width metadata over groups:

Delta mode (the paper's primary scheme):
  * values are grouped 64 at a time, viewed as an 8x8 matrix;
  * each of the 8 columns stores an 8-bit *base* exponent = its row-0 value;
  * rows 1..7 store sign+magnitude *deltas* against the column bases;
  * each delta row carries one 3-bit width field sized by the row's max
    magnitude: a row whose max |delta| needs k bits costs
    3 + 8*(k+1) bits (sign+magnitude per value), or just the 3-bit field
    when every delta in the row is zero (k = 0). [DESIGN.md D2]

Bias mode (the paper's alternative):
  * a fixed programmable bias (127) is subtracted from every exponent;
  * values are grouped 8 at a time with one 3-bit width field per group.

Both encoders here are *bit-exact invertible* (property-tested) and return
exact bit counts without materializing bitstreams. The byte-aligned
on-device realization lives in repro/kernels/sfp_pack.py.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

DELTA_GROUP = (8, 8)  # (rows, cols) — 64 exponents per group
BIAS_GROUP = 8
DEFAULT_BIAS = 127


def _bitwidth(x: jax.Array) -> jax.Array:
    """Bits needed to represent unsigned magnitude x (0 -> 0 bits).

    Exact for x < 2^15 (we only ever see x <= 255).
    """
    x = x.astype(jnp.int32)
    w = jnp.zeros_like(x)
    for b in range(8, -1, -1):  # 255 needs 8 bits
        w = jnp.where((x >> b) > 0, jnp.maximum(w, b + 1), w)
    return w


class GeckoDelta(NamedTuple):
    """Mechanical encoding (lossless); bit accounting is separate."""

    bases: jax.Array      # (G, 8)  uint8 column bases (row 0)
    deltas: jax.Array     # (G, 7, 8) int16 row deltas vs column base
    row_widths: jax.Array  # (G, 7) int32 magnitude bits per row
    n_values: int          # original (un-padded) element count


class GeckoBias(NamedTuple):
    deltas: jax.Array       # (G, 8) int16 value - bias
    group_widths: jax.Array  # (G,) int32
    bias: int
    n_values: int


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        # Edge-replicate: hardware pads the trailing partial group; repeating
        # the last exponent keeps the padded deltas at zero cost.
        x = jnp.concatenate([x, jnp.broadcast_to(x[-1:], (rem,))])
    return x


def encode_delta(exponents: jax.Array) -> GeckoDelta:
    """Encode a flat uint8 exponent stream (8x8 delta scheme)."""
    e = _pad_to(exponents.reshape(-1).astype(jnp.uint8), 64)
    g = e.reshape(-1, 8, 8).astype(jnp.int16)  # (G, row, col)
    bases = g[:, 0, :]
    deltas = g[:, 1:, :] - bases[:, None, :]
    row_max = jnp.max(jnp.abs(deltas), axis=2)  # (G, 7)
    row_widths = _bitwidth(row_max)
    return GeckoDelta(
        bases=bases.astype(jnp.uint8),
        deltas=deltas,
        row_widths=row_widths,
        n_values=int(exponents.size),
    )


def decode_delta(enc: GeckoDelta) -> jax.Array:
    g0 = enc.bases.astype(jnp.int16)[:, None, :]
    rest = enc.deltas + g0
    full = jnp.concatenate([g0, rest], axis=1)  # (G, 8, 8)
    flat = full.reshape(-1).astype(jnp.uint8)
    return flat[: enc.n_values]


def delta_bits(enc: GeckoDelta) -> jax.Array:
    """Exact compressed size in bits (metadata + payload), padded groups included."""
    per_row = jnp.where(enc.row_widths > 0, 3 + 8 * (enc.row_widths + 1), 3)
    bases_bits = enc.bases.shape[0] * 8 * 8  # 8 bases x 8b per group
    # fp32 accumulation: bit counts overflow int32 for multi-GB tensors and
    # x64 is disabled; ~7 significant digits is ample for accounting.
    return jnp.asarray(bases_bits, jnp.float32) + jnp.sum(
        per_row.astype(jnp.float32))


def encode_bias(exponents: jax.Array, bias: int = DEFAULT_BIAS) -> GeckoBias:
    e = _pad_to(exponents.reshape(-1).astype(jnp.uint8), BIAS_GROUP)
    d = e.astype(jnp.int16) - jnp.int16(bias)
    d = d.reshape(-1, BIAS_GROUP)
    widths = _bitwidth(jnp.max(jnp.abs(d), axis=1))
    return GeckoBias(deltas=d, group_widths=widths, bias=bias,
                     n_values=int(exponents.size))


def decode_bias(enc: GeckoBias) -> jax.Array:
    flat = (enc.deltas + jnp.int16(enc.bias)).reshape(-1).astype(jnp.uint8)
    return flat[: enc.n_values]


def bias_bits(enc: GeckoBias) -> jax.Array:
    per_group = jnp.where(
        enc.group_widths > 0, 3 + BIAS_GROUP * (enc.group_widths + 1), 3
    )
    return jnp.sum(per_group.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Pure accounting entry points (jit-friendly; no NamedTuple plumbing).
# ---------------------------------------------------------------------------

def compressed_bits(exponents: jax.Array, mode: str = "delta",
                    bias: int = DEFAULT_BIAS) -> jax.Array:
    """Exact Gecko-compressed size of a uint8 exponent stream, in bits."""
    if mode == "delta":
        return delta_bits(encode_delta(exponents))
    elif mode == "bias":
        return bias_bits(encode_bias(exponents, bias))
    raise ValueError(f"unknown gecko mode: {mode}")


def compression_ratio(exponents: jax.Array, mode: str = "delta",
                      bias: int = DEFAULT_BIAS) -> jax.Array:
    """(M + C) / O per the paper: metadata+compressed over original 8b/value."""
    comp = compressed_bits(exponents, mode, bias)
    return comp / jnp.asarray(exponents.size * 8, jnp.float32)


def per_value_bits(exponents: jax.Array, mode: str = "delta",
                   bias: int = DEFAULT_BIAS) -> jax.Array:
    """Post-encoding bitlength of each value's exponent (Fig 10 CDF).

    Row-0 bases count as 8b in delta mode; delta values count sign+magnitude
    of their row width.
    """
    if mode == "delta":
        enc = encode_delta(exponents)
        g = enc.bases.shape[0]
        base_bits = jnp.full((g, 1, 8), 8, jnp.int32)
        row_bits = jnp.where(enc.row_widths > 0, enc.row_widths + 1, 0)
        rest_bits = jnp.broadcast_to(row_bits[:, :, None], (g, 7, 8))
        bits = jnp.concatenate([base_bits, rest_bits], axis=1).reshape(-1)
        return bits[: enc.n_values]
    elif mode == "bias":
        enc = encode_bias(exponents, bias)
        per_group = jnp.where(enc.group_widths > 0, enc.group_widths + 1, 0)
        bits = jnp.broadcast_to(per_group[:, None],
                                (per_group.shape[0], BIAS_GROUP)).reshape(-1)
        return bits[: enc.n_values]
    raise ValueError(f"unknown gecko mode: {mode}")
