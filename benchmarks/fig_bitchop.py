"""Fig 6/7/8: BitChop bitlength trajectory + per-step histogram."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run():
    bc = common.lm_run("bitchop")
    base = common.lm_run("none")
    bits = np.asarray([t["bc_bits"] for t in bc["qm_traj"]])
    hist, _ = np.histogram(bits, bins=np.arange(9) - 0.5)
    return {
        "mean_bits": float(bits.mean()),
        "bits_histogram": hist.tolist(),
        "final_bits": int(bits[-1]),
        "mantissa_vs_bf16": float(bits.mean() / 7.0),
        "xent_bc": float(np.mean([h["xent"] for h in bc["history"][-10:]])),
        "xent_base": float(np.mean([h["xent"]
                                    for h in base["history"][-10:]])),
        "traj": bits.tolist()[::5],
    }


def main():
    r = run()
    print(f"BitChop: mean {r['mean_bits']:.2f} bits "
          f"({100*r['mantissa_vs_bf16']:.0f}% of BF16 mantissa), "
          f"final {r['final_bits']}")
    print(f"histogram over steps (0..7 bits): {r['bits_histogram']}")
    print(f"loss parity: bc {r['xent_bc']:.3f} vs base {r['xent_base']:.3f}")
    return r


if __name__ == "__main__":
    main()
