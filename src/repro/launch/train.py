"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --preset tiny \
      --policy qm+qe --steps 200 --ckpt-dir /tmp/ckpt

``--policy`` takes any registered precision policy (none, static, qm, qe,
bitchop, bitwave) or a '+'-composition such as ``qm+qe`` (learn mantissa
AND exponent bitlengths in one run). Presets scale the assigned configs
down for the CPU environment; on real hardware drop --preset and pass
--mesh to shard across the fleet. The loop is fault-tolerant: it
checkpoints every --ckpt-every steps (recording the policy in the
manifest) and restores+continues on step failure. The final report
includes the modeled stash footprint under the learned/adapted decisions —
exponent-bit savings from qe/bitwave show up there.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs, configs, policies
from repro import obs as obs_mod
from repro.configs.base import reduced
from repro.launch.args import container_name, policy_name
from repro.data import pipeline, synthetic
from repro.models.model import DecoderModel
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.train import loop as loop_mod
from repro.train import step as step_mod


def build_policy(args) -> policies.Policy:
    """Resolve --policy, routing the qm-* / qe-* flags to their sub-policy.

    QE rides its own knobs (the exponent field is smaller and flushing a
    binade is harsher than dropping a mantissa bit), so each '+'-part is
    constructed with its own kwarg set and composed once.
    """
    per_sub = {
        "qm": dict(gamma=args.gamma, lr=args.qm_lr,
                   init_bits=args.qm_init_bits),
        "qe": dict(gamma=args.qe_gamma, lr=args.qe_lr),
    }
    parts = args.policy.split("+")
    if len(set(parts)) != len(parts):
        raise SystemExit(f"duplicate sub-policy in --policy {args.policy!r}")
    subs = [policies.get(part, container=args.container,
                         **per_sub.get(part, {}))
            for part in parts]
    return (subs[0] if len(subs) == 1
            else policies.CompositePolicy(policies=tuple(subs)))


def build(args):
    cfg = configs.get(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
        batch, seq = 8, 64
    elif args.preset == "small":
        cfg = reduced(cfg, n_layers=max(2 * len(cfg.period), 4), d_model=256)
        batch, seq = 8, 128
    else:
        batch, seq = args.batch, args.seq

    policy = build_policy(args)
    model = DecoderModel(cfg, policy)
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=args.lr),
        schedule=Schedule(kind="cosine", base_lr=args.lr,
                          warmup_steps=min(50, args.steps // 10),
                          total_steps=args.steps),
        num_microbatches=args.microbatches,
        grad_compress_bits=args.grad_compress_bits,
    )
    return cfg, model, tc, batch, seq


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--policy", default="qm", metavar="NAME[+NAME...]",
                    type=policy_name,
                    help="precision policy from the registry "
                         f"({'/'.join(policies.names())}), composable with "
                         "'+', e.g. qm+qe")
    ap.add_argument("--container", default="bit_exact", type=container_name,
                    help="stash codec: any registered name "
                         f"({'/'.join(codecs.names())}) or a parametric "
                         "dense geometry like sfp-m2e4")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--gamma", type=float, default=0.05,
                    help="QM footprint-penalty strength (eq. 7)")
    ap.add_argument("--qm-init-bits", type=float, default=7.0)
    ap.add_argument("--qm-lr", type=float, default=0.05)
    ap.add_argument("--qe-gamma", type=float, default=0.05,
                    help="QE footprint-penalty strength")
    ap.add_argument("--qe-lr", type=float, default=0.05)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress-bits", type=int, default=None)
    ap.add_argument("--per-layer-stash", action="store_true",
                    help="pack each period's stash at its own policy-"
                         "learned dense container (model.stash_plan); the "
                         "plan refreshes every --stash-refresh steps and "
                         "the step re-jits when it changes")
    ap.add_argument("--stash-refresh", type=int, default=None,
                    help="steps between per-layer stash plan refreshes "
                         "(default: --ckpt-every)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--metrics", default=None,
                    help="per-step metrics JSONL (the obs event stream)")
    ap.add_argument("--metrics-out", default=None,
                    help="write Prometheus-text metrics (step-time "
                         "histogram, failure counters) here at exit")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON of train-step "
                         "spans here at exit (opens in Perfetto)")
    ap.add_argument("--timeline-out", default=None,
                    help="stream the per-layer precision timeline "
                         "(JSONL; one entry per --timeline-every steps)")
    ap.add_argument("--timeline-every", type=int, default=10)
    ap.add_argument("--profile-steps", type=int, default=None,
                    metavar="N",
                    help="bracket jax.profiler.trace around N steps "
                         "(starting at --profile-start)")
    ap.add_argument("--profile-start", type=int, default=1)
    ap.add_argument("--profile-dir",
                    default="experiments/traces/train")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main():
    # Container/policy typos fail in the usage message: both flags carry
    # registry-backed argparse validators (launch/args.py).
    args = build_parser().parse_args()

    cfg, model, tc, batch, seq = build(args)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"policy={model.policy.name} container={args.container}")

    train_step = jax.jit(step_mod.make_train_step(model, tc),
                         donate_argnums=(0,))
    state = step_mod.init_state(model, jax.random.PRNGKey(args.seed), tc)

    dcfg = synthetic.SyntheticConfig(vocab=cfg.vocab, seq_len=seq,
                                     global_batch=batch, seed=args.seed)

    def batches(start):
        it = synthetic.batches(dcfg, start)
        def to_batch(b):
            out = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.prefix_tokens:
                out["cond_embeddings"] = jnp.zeros(
                    (batch, cfg.prefix_tokens, cfg.d_model),
                    cfg.compute_dtype)
            return out
        return (to_batch(b) for b in it)

    def ckpt_extra(state):
        # Stamp the policy's *current* decision summary alongside the run
        # identity: policy-aware serving (serve/precision.py) derives the
        # KV pool's container geometry from these learned bitlengths via
        # CheckpointManager.read_extra — no state restore needed.
        d = model.policy.decision_summary(state.pstate, model.dims)
        return {"policy": model.policy.name, "container": args.container,
                "decision": {"man_bits": float(d["man_bits"]),
                             "exp_bits": float(d["exp_bits"])}}

    obs = obs_mod.Obs(metrics_path=args.metrics_out,
                      trace_path=args.trace_out,
                      timeline_path=args.timeline_out)

    def timeline_fn(state):
        # Late-binds `model`: the per-layer-stash loop rebuilds the model
        # each refresh segment, and the timeline must follow the live one.
        return model.policy.layer_decisions(state.pstate, model.dims)

    lc = loop_mod.LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, metrics_file=args.metrics,
        log_every=max(1, args.steps // 50),
        ckpt_extra=ckpt_extra, obs=obs, timeline_fn=timeline_fn,
        timeline_every=args.timeline_every,
        profile_steps=(None if args.profile_steps is None
                       else (args.profile_start, args.profile_steps)),
        profile_dir=args.profile_dir)
    if args.per_layer_stash:
        # Per-layer realized containers: the stash plan is static under
        # jit, so the loop runs in segments — every refresh boundary the
        # plan is re-derived from the live policy state and the step
        # re-jits only when a layer's container actually changed (learned
        # bitlengths move slowly, so re-lowering is rare).
        import dataclasses as _dc
        refresh = max(1, args.stash_refresh or args.ckpt_every)
        plan = None
        history = []
        res = None
        done = int(np.asarray(state.step))
        while done < args.steps:
            new_plan = model.stash_plan(state.pstate)
            if new_plan != plan:
                plan = new_plan
                print(f"[train] per-layer stash plan @ step {done}: "
                      f"{','.join(plan)}")
                model = DecoderModel(cfg, model.policy,
                                     stash_containers=plan)
                train_step = jax.jit(step_mod.make_train_step(model, tc),
                                     donate_argnums=(0,))
            seg = _dc.replace(lc, total_steps=min(done + refresh,
                                                  args.steps),
                              metrics_truncate=(res is None))
            res = loop_mod.run(train_step, state, batches, seg)
            state = res.state
            history.extend(res.history)
            done = int(np.asarray(state.step))
        res = _dc.replace(res, state=state, history=history)
        print(f"[train] final per-layer stash plan: {','.join(plan)}")
    else:
        res = loop_mod.run(train_step, state, batches, lc)
    last = res.history[-1]
    print(json.dumps({k: last[k] for k in
                      ("step", "loss", "xent", "qm_act_mean", "qm_w_mean",
                       "qe_act_mean", "qe_w_mean", "bc_bits", "bw_man_bits",
                       "bw_exp_bits") if k in last}, indent=2))
    # Modeled stash footprint under the final decisions: sign + learned
    # mantissa bits + (learned/adapted) exponent bits per value.
    fp = policies.modeled_footprint(model.policy, res.state.pstate,
                                    model.dims)
    print("footprint " + json.dumps({k: round(v, 4) for k, v in fp.items()}))
    obs.close()  # writes --metrics-out / --trace-out, closes the timeline


if __name__ == "__main__":
    main()
