"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; each row's ``derived`` field
carries the headline metric the paper reports in that table/figure.
Artifacts (full dicts) are written to experiments/bench_results.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "experiments"


def main() -> None:
    from benchmarks import (bench_codecs, bench_decode, bench_decode_micro,
                            bench_policies, bench_serve, fig_bitchop,
                            fig_gecko, fig_qm_bitlengths,
                            fig_relative_compression, table1_footprint,
                            table2_perf_energy)

    rows = []
    results = {}

    def bench(name, fn, derive):
        t0 = time.time()
        r = fn()
        us = (time.time() - t0) * 1e6
        results[name] = r
        rows.append(f"{name},{us:.0f},{derive(r)}")

    bench("table1_footprint", table1_footprint.run,
          lambda r: f"qm_vs_fp32={r['resnet8_qm']['vs_fp32']:.3f};"
                    f"bc_vs_fp32={r['resnet8_bitchop']['vs_fp32']:.3f};"
                    f"qm_acc_delta={r['resnet8_qm']['acc_delta']:+.3f}")
    bench("table2_perf_energy", table2_perf_energy.run,
          lambda r: f"qm_speedup={r['paper_accel']['speedup_qm']:.2f}x;"
                    f"qm_energy={r['paper_accel']['energy_qm']:.2f}x;"
                    f"bc_speedup={r['paper_accel']['speedup_bc']:.2f}x")
    bench("fig_qm_bitlengths", fig_qm_bitlengths.run,
          lambda r: f"final_act_bits={r['final_act_mean']:.2f};"
                    f"xent_delta={r['xent_delta']:+.3f}")
    bench("fig_bitchop", fig_bitchop.run,
          lambda r: f"mean_bits={r['mean_bits']:.2f};"
                    f"final_bits={r['final_bits']}")
    bench("fig_gecko", fig_gecko.run,
          lambda r: f"w_ratio={r['weights']['ratio_delta']:.3f};"
                    f"a_ratio={r['activations']['ratio_delta']:.3f}")
    bench("fig_relative_compression", fig_relative_compression.run,
          lambda r: f"sfp_qm_vs_bf16={r['sfp_qm']:.3f};"
                    f"gist_vs_bf16={r['gist']:.3f}")
    bench("bench_codecs", bench_codecs.run,
          lambda r: f"fused_speedup={r['speedup']:.2f}x;"
                    f"bit_exact={r['bit_exact_fusion']};"
                    "dense_m2e4_vs_bf16="
                    f"{r['dense_vs_fixed']['sfp-m2e4_vs_bf16']:.3f}")
    def decode_ratio(r):
        return r["points"][0]["fused_bytes_vs_bf16"]["sfp8_fused"]

    bench("bench_decode", bench_decode.run,
          lambda r: f"sfp8_fused_bytes_vs_bf16={decode_ratio(r):.3f}")
    def micro_gbps(r, name):
        return r["backends"]["ref"][name]["phases"]["generate"]["gbps"]

    bench("bench_decode_micro", bench_decode_micro.run,
          lambda r: f"m2e4_unpack_gbps={micro_gbps(r, 'sfp-m2e4'):.2f};"
                    f"sfp8_unpack_gbps={micro_gbps(r, 'sfp8'):.2f}")
    bench("bench_policies", bench_policies.run,
          lambda r: "qm_overhead="
                    f"{r['policies']['qm']['overhead_vs_none']:.2f}x;"
                    "qm+qe_overhead="
                    f"{r['policies']['qm+qe']['overhead_vs_none']:.2f}x")
    bench("bench_serve", bench_serve.run,
          lambda r: "paged_bytes_vs_bf16="
                    f"{r['points'][0]['paged_bytes_vs_bf16']:.3f}")

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "bench_results.json").write_text(json.dumps(results, indent=2,
                                                       default=str))
    # Headline artifact for the codec subsystem (fused quantize+pack win).
    (OUT.parent / "BENCH_codecs.json").write_text(
        json.dumps(results["bench_codecs"], indent=2, default=str))
    # Headline artifact for the packed flash-decode path (HBM bytes/step).
    (OUT.parent / "BENCH_decode.json").write_text(
        json.dumps(results["bench_decode"], indent=2, default=str))
    # Headline artifact for the pack/unpack roofline microbenchmark.
    (OUT.parent / "BENCH_decode_micro.json").write_text(
        json.dumps(results["bench_decode_micro"], indent=2, default=str))
    # Headline artifact for the policy registry (per-step overhead).
    (OUT.parent / "BENCH_policies.json").write_text(
        json.dumps(results["bench_policies"], indent=2, default=str))
    # Headline artifact for the paged serving engine (cache bytes/step).
    (OUT.parent / "BENCH_serve.json").write_text(
        json.dumps(results["bench_serve"], indent=2, default=str))
    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
