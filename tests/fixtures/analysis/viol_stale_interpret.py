"""Seeded violations: hard-coded interpret=True instead of auto-resolve."""


def kernel_call(x, interpret=True):  # LINT: stale-interpret-flag
    return x


y = kernel_call(0, interpret=True)  # LINT: stale-interpret-flag


def fine(x, interpret=None):
    # The sanctioned shape: default None, resolved via default_interpret.
    return x
