"""Pallas TPU kernels: SFP8/SFP16 container pack/unpack.

The paper's compressor/decompressor (§V) adapted to the TPU memory
hierarchy (DESIGN.md §2): instead of a bit-serial packer at the DRAM pins,
values are re-containered in 8/16-bit lanes on the HBM<->VMEM path with one
shared 8-bit base exponent per 128-lane group (a Gecko column base). The
mantissa width signal from Quantum Mantissa / BitChop decides which
container a tensor gets; the pack kernel fuses the mantissa truncation with
the exponent delta encoding — exactly the fusion the hardware packers do.

Layouts (see kernels/ref.py for the bit-level oracle):
  SFP8  byte = sign<<7 | dexp4<<3 | man3          (bf16 payload)
  SFP16 word = sign<<15 | dexp5<<10 | manK<<(10-K) (K=10 fp32 / 7 bf16)
(dexp == max, man == 0) encodes exact zero; dexp saturates (values more
than 2^-15 below the group max flush — bounded error, see tests).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import containers
from repro.kernels import ref as kref

LANES = kref.GROUP  # 128
DEFAULT_BLOCK_ROWS = 64


def _pack_kernel(x_ref, payload_ref, base_ref, *, spec, man_keep, dexp_bits,
                 out_int):
    x = x_ref[...]
    u = jax.lax.bitcast_convert_type(x, spec.int_dtype).astype(jnp.int32)
    sign = (u >> spec.sign_shift) & 1
    e = (u >> spec.exp_shift) & spec.exp_mask
    man = u & spec.man_mask

    dexp_max = (1 << dexp_bits) - 1
    base = jnp.max(e, axis=-1, keepdims=True)
    dexp = base - e
    man_top = man >> (spec.man_bits - man_keep)
    flush = (e == 0) | (dexp > dexp_max)
    dexp = jnp.where(flush, dexp_max, jnp.minimum(dexp, dexp_max))
    man_top = jnp.where(flush, 0, man_top)
    sign = jnp.where(e == 0, 0, sign)

    if out_int == jnp.uint8:
        word = (sign << 7) | (dexp << 3) | man_top
    else:
        word = (sign << 15) | (dexp << (15 - dexp_bits)) | (
            man_top << (15 - dexp_bits - man_keep))
    payload_ref[...] = word.astype(out_int)
    base_ref[...] = base.astype(jnp.uint8)


def _unpack_kernel(payload_ref, base_ref, o_ref, *, spec, man_keep,
                   dexp_bits):
    p = payload_ref[...].astype(jnp.int32)
    dexp_max = (1 << dexp_bits) - 1
    if payload_ref.dtype == jnp.uint8:
        sign = (p >> 7) & 1
        dexp = (p >> 3) & dexp_max
        man_top = p & ((1 << man_keep) - 1)
    else:
        sign = (p >> 15) & 1
        dexp = (p >> (15 - dexp_bits)) & dexp_max
        man_top = (p >> (15 - dexp_bits - man_keep)) & ((1 << man_keep) - 1)
    base = base_ref[...].astype(jnp.int32)
    e = jnp.maximum(base - dexp, 0)
    man = man_top << (spec.man_bits - man_keep)
    flush = (dexp == dexp_max) & (man_top == 0)
    e = jnp.where(flush, 0, e)
    man = jnp.where(flush, 0, man)
    sign = jnp.where(flush, 0, sign)
    word = (
        (sign << spec.sign_shift) | (e << spec.exp_shift) | man
    ).astype(spec.int_dtype)
    o_ref[...] = jax.lax.bitcast_convert_type(word, spec.dtype)


def _to_rows(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % LANES
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), pad


@functools.partial(jax.jit, static_argnames=("container", "block_rows",
                                             "interpret"))
def sfp_pack(x: jax.Array, *, container: str = "sfp8",
             block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Pack ``x`` into (payload rows, per-row base exponents).

    Returns (payload (R, 128) uint8|uint16, bases (R, 1) int32). Rows are
    128-lane groups of the flattened tensor (Gecko columns).
    """
    spec = containers.spec_for(x)
    man_keep, dexp_bits = kref._sfp_fields(container, spec)
    out_int = jnp.uint8 if container == "sfp8" else jnp.uint16

    rows2d, _pad = _to_rows(x)
    rows = rows2d.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        rows2d = jnp.pad(rows2d, ((0, rpad), (0, 0)))
    grid = (rows2d.shape[0] // block_rows,)

    payload, bases = pl.pallas_call(
        functools.partial(_pack_kernel, spec=spec, man_keep=man_keep,
                          dexp_bits=dexp_bits, out_int=out_int),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(rows2d.shape, out_int),
            jax.ShapeDtypeStruct((rows2d.shape[0], 1), jnp.uint8),
        ],
        interpret=interpret,
    )(rows2d)
    if rpad:
        payload, bases = payload[:rows], bases[:rows]
    return payload, bases


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "container",
                                             "block_rows", "interpret"))
def sfp_unpack(payload: jax.Array, bases: jax.Array, *, shape: tuple,
               dtype, container: str = "sfp8",
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = True) -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    man_keep, dexp_bits = kref._sfp_fields(container, spec)

    rows = payload.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        payload = jnp.pad(payload, ((0, rpad), (0, 0)))
        bases = jnp.pad(bases, ((0, rpad), (0, 0)))
    grid = (payload.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_unpack_kernel, spec=spec, man_keep=man_keep,
                          dexp_bits=dexp_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(payload.shape, spec.dtype),
        interpret=interpret,
    )(payload, bases)
    if rpad:
        out = out[:rows]
    n = 1
    for s in shape:
        n *= s
    return out.reshape(-1)[:n].reshape(shape)
