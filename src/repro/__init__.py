"""Schrödinger's FP on TPU: dynamic floating-point containers for training
and serving, as a production-grade multi-pod JAX framework.

Reproduces Nikolić et al., 2022 (Quantum Mantissa / BitChop / Gecko / the
SFP encoder-decoder pipeline) and extends it with TPU-native realized
containers, a compressed-stash training step, compressed KV-cache serving,
and compressed cross-pod gradient exchange. See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
