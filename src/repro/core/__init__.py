"""Core contribution of Schrödinger's FP: dynamic floating-point containers.

Public surface:
  containers        - FP bit-field plumbing, Q(M, n) truncation (eq. 5-6)
  quantum_mantissa  - learned per-tensor mantissa bitlengths (eq. 5-7)
  bitchop           - loss-EMA heuristic bitlength controller (eq. 8-9)
  gecko             - lossless exponent delta compression
  footprint         - bit-exact SFP footprint accounting (Table I / Fig 12-13)
  sfp               - container policies + stash compression used by train/serve
"""
from repro.core import bitchop, containers, footprint, gecko, quantum_mantissa, sfp  # noqa: F401
