"""Serving substrate: prefill/decode engine, (compressed) KV cache."""
