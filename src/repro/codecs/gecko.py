"""gecko8: the paper's delta-mode exponent compression, actually realized.

core/gecko.py proves the 8x8 delta scheme is losslessly invertible and
counts its bits; this codec *materializes* it. A float tensor becomes

  signman  — one byte per value: sign<<7 | top-7 mantissa bits (after the
             Q(M, n) truncation signal, fused into the byte build);
  bases    — (G, 8) uint8 Gecko column bases (row 0 of each 8x8 group);
  widths   — (G, 7) uint8 per-delta-row magnitude bitwidths (== the
             reference encoder's row_widths);
  planes   — (G, 63) uint8 dense sign+magnitude bit planes (row r of width
             w has exactly w + 1 meaningful plane bytes; the rest are 0).

The device representation keeps planes dense (static shapes for jit/scan);
``stream_from_parts`` compacts them into the actual byte-aligned stream:

  [bases: 8G bytes][widths: 2-per-byte nibbles, 4G bytes]
  [row payload in (group, row, plane) order: (w+1) bytes per row, rows
   with w == 0 elided]

which costs exactly core/gecko.py's ``delta_bits`` plus 11 bits/group
(width fields byte-aligned to 4-bit nibbles instead of the idealized 3
bits). bf16 tensors with bits >= 7 round-trip losslessly — sign and all 7
mantissa bits live in signman, exponents are Gecko-lossless.

Pack/unpack of the exponent planes run through the Pallas kernel pair in
kernels/gecko_pack.py (jnp oracle: kernels/ref.py), dispatched by the
standard ops.force_backend mechanism.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import containers
from repro.codecs import base
from repro.kernels import ops
from repro.kernels.ref import GECKO_GROUP, GECKO_PLANES, GECKO_ROWS

GECKO8 = "gecko8"
_SIGNMAN_BITS = 8           # 1 sign + 7 mantissa bits per value
_WIDTH_BYTES = 4            # 7 x 4-bit width nibbles, byte-aligned
_HEADER_BYTES = 8 + _WIDTH_BYTES  # per-group bases + widths


def _exponent_groups(e: jax.Array) -> jax.Array:
    """Flatten a uint8 exponent stream into edge-padded (G, 64) groups
    (edge replication keeps padded deltas at zero cost, like core/gecko)."""
    flat = e.reshape(-1)
    pad = (-flat.size) % GECKO_GROUP
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.broadcast_to(flat[-1:], (pad,))])
    return flat.reshape(-1, GECKO_GROUP)


class Gecko8Codec(base.Codec):
    name = GECKO8

    def pack(self, x: jax.Array, bits=None) -> base.PackedTensor:
        spec = containers.spec_for(x)
        sign, e, man = containers.split_fields(x)
        man = man.astype(jnp.int32)
        if bits is not None:
            keep = containers._mantissa_keep_mask(bits, spec)
            man = man & keep.astype(jnp.int32)
        man_top = man >> (spec.man_bits - 7)
        signman = ((sign.astype(jnp.int32) << 7) | man_top).astype(jnp.uint8)
        bases, widths, planes = ops.gecko_encode(
            _exponent_groups(e.astype(jnp.uint8)))
        return base.PackedTensor(self.name, x.shape, x.dtype, {
            "signman": signman, "bases": bases, "widths": widths,
            "planes": planes})

    def unpack(self, packed: base.PackedTensor) -> jax.Array:
        spec = containers.spec_for(packed.dtype)
        n = 1
        for s in packed.shape:
            n *= s
        e = ops.gecko_decode(packed.data["bases"], packed.data["planes"])
        e = e.reshape(-1)[:n].reshape(packed.shape).astype(spec.int_dtype)
        b = packed.data["signman"].astype(jnp.int32)
        sign = (b >> 7) & 1
        man = (b & 0x7F) << (spec.man_bits - 7)
        return containers.combine_fields(
            sign.astype(spec.int_dtype), e,
            man.astype(spec.int_dtype), spec)

    def lossless_for(self, dtype) -> bool:
        # Sign + 7 mantissa bits in signman, exponents Gecko-lossless:
        # bit-exact exactly when the source mantissa fits in 7 bits.
        return containers.spec_for(jnp.dtype(dtype)).man_bits <= 7

    def packed_bits(self, x: jax.Array, bits=None) -> float:
        _, e, _ = containers.split_fields(x)
        _, widths, _ = ops.gecko_encode(_exponent_groups(e.astype(jnp.uint8)))
        return float(int(x.size) * _SIGNMAN_BITS + _stream_bits(widths))

    # -- host-side byte-aligned stream --------------------------------------

    def encode_host(self, arr: np.ndarray, bits: Optional[int] = None
                    ) -> Tuple[np.ndarray, Dict[str, Any]]:
        packed = self.pack(jnp.asarray(arr), bits)
        signman = np.asarray(packed.data["signman"]).reshape(-1)
        gecko_stream = stream_from_parts(
            np.asarray(packed.data["bases"]),
            np.asarray(packed.data["widths"]),
            np.asarray(packed.data["planes"]))
        meta = {"n_values": int(signman.size),
                "n_groups": int(packed.data["bases"].shape[0])}
        if bits is not None:
            meta["bits"] = int(bits)
        return np.concatenate([signman, gecko_stream]), meta

    def decode_host(self, stream: np.ndarray, meta: Dict[str, Any],
                    shape: Tuple[int, ...], dtype) -> np.ndarray:
        n = int(meta["n_values"])
        g = int(meta["n_groups"])
        signman = stream[:n]
        bases, widths, planes = parts_from_stream(stream[n:], g)
        packed = base.PackedTensor(self.name, shape, dtype, {
            "signman": jnp.asarray(signman).reshape(shape),
            "bases": jnp.asarray(bases),
            "widths": jnp.asarray(widths),
            "planes": jnp.asarray(planes)})
        return np.asarray(self.unpack(packed))


# ---------------------------------------------------------------------------
# Exponent-stream entry points (the §IV-C mechanism itself; the float codec
# above composes these with the signman byte).
# ---------------------------------------------------------------------------


def pack_exponent_stream(e: jax.Array) -> Tuple[np.ndarray, int]:
    """uint8 exponent stream -> (byte-aligned packed stream, n_values)."""
    bases, widths, planes = ops.gecko_encode(_exponent_groups(e))
    return (stream_from_parts(np.asarray(bases), np.asarray(widths),
                              np.asarray(planes)), int(e.size))


def unpack_exponent_stream(stream: np.ndarray, n_values: int) -> np.ndarray:
    """Invert pack_exponent_stream (bit-exact)."""
    n_groups = -(-n_values // GECKO_GROUP)
    bases, widths, planes = parts_from_stream(np.asarray(stream), n_groups)
    e = np.asarray(ops.gecko_decode(jnp.asarray(bases), jnp.asarray(planes)))
    return e.reshape(-1)[:n_values]


def _row_lengths(widths: np.ndarray) -> np.ndarray:
    """Payload bytes per delta row: w + 1 plane bytes, 0 for all-zero rows."""
    w = widths.astype(np.int64)
    return np.where(w > 0, w + 1, 0)


def _stream_bits(widths) -> int:
    lengths = _row_lengths(np.asarray(widths))
    g = lengths.shape[0]
    return int(8 * (g * _HEADER_BYTES + lengths.sum()))


def stream_bytes(widths) -> int:
    """Exact size of the byte-aligned stream for the given row widths."""
    return _stream_bits(widths) // 8


def _pack_width_nibbles(widths: np.ndarray) -> np.ndarray:
    """(G, 7) widths (0..8) -> (G, 4) bytes, two 4-bit nibbles per byte."""
    w = np.concatenate([widths.astype(np.uint8),
                        np.zeros((widths.shape[0], 1), np.uint8)], axis=1)
    return (w[:, 0::2] | (w[:, 1::2] << 4)).astype(np.uint8)


def _unpack_width_nibbles(nib: np.ndarray) -> np.ndarray:
    w = np.zeros((nib.shape[0], 8), np.uint8)
    w[:, 0::2] = nib & 0x0F
    w[:, 1::2] = nib >> 4
    return w[:, :GECKO_ROWS]


def _plane_mask(widths: np.ndarray) -> np.ndarray:
    """(G, 7) -> (G, 7, 9) bool: which dense plane bytes the stream keeps.

    True exactly for the first (w + 1) planes of each row with w > 0. The
    flattened mask order (group-major, then row, then plane) matches the
    stream's payload byte order, so compaction is a single boolean gather.
    """
    lengths = _row_lengths(widths)
    p = np.arange(GECKO_PLANES)
    return p[None, None, :] < lengths[..., None]


def stream_from_parts(bases: np.ndarray, widths: np.ndarray,
                      planes: np.ndarray) -> np.ndarray:
    """Compact dense kernel outputs into the byte-aligned stream."""
    mask = _plane_mask(widths).reshape(-1)
    payload = planes.reshape(-1)[mask]
    return np.concatenate([
        bases.reshape(-1).astype(np.uint8),
        _pack_width_nibbles(widths).reshape(-1),
        payload.astype(np.uint8)])


def parts_from_stream(stream: np.ndarray, n_groups: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Expand a byte-aligned stream back into dense (bases, widths, planes)."""
    g = n_groups
    bases = stream[: 8 * g].reshape(g, 8)
    nib = stream[8 * g: 8 * g + _WIDTH_BYTES * g].reshape(g, _WIDTH_BYTES)
    widths = _unpack_width_nibbles(nib)
    payload = stream[(8 + _WIDTH_BYTES) * g:]
    mask = _plane_mask(widths).reshape(-1)
    planes = np.zeros(g * GECKO_ROWS * GECKO_PLANES, np.uint8)
    planes[np.flatnonzero(mask)] = payload[: int(mask.sum())]
    return (bases.astype(np.uint8), widths,
            planes.reshape(g, GECKO_ROWS * GECKO_PLANES))
