"""Finding records + the waiver baseline for the static checker.

A ``Finding`` is one rule violation anchored to a file/line and a scope
(function, entry point, or kernel×geometry pair). Findings are keyed
``rule:path:scope`` — line numbers are deliberately NOT part of the key,
so waivers survive unrelated edits to the same file.

The baseline file (``analysis_baseline.json`` at the repo root) holds
explicit waivers, each with a one-line justification:

    {"waivers": [
        {"key": "host-sync-in-jit:src/repro/x.py:foo",
         "reason": "host boundary: scheduler reads one scalar per step"}
    ]}

A waiver with no matching finding is *stale* and reported (the violation
was fixed — delete the waiver), but does not fail the run.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str      # repo-relative, '/'-separated
    line: int
    scope: str     # function / entry-point / kernel name it anchors to
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.scope}"

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "scope": self.scope, "message": self.message,
                "key": self.key}


def load_baseline(path) -> Dict[str, str]:
    """Read the waiver file; returns {finding key: justification}."""
    p = pathlib.Path(path)
    if not p.exists():
        return {}
    raw = json.loads(p.read_text())
    waivers = {}
    for w in raw.get("waivers", []):
        if not w.get("reason", "").strip():
            raise ValueError(f"waiver {w.get('key')!r} has no reason; every "
                             "waiver needs a one-line justification")
        waivers[w["key"]] = w["reason"]
    return waivers


def split_by_baseline(findings: Sequence[Finding],
                      waivers: Dict[str, str]
                      ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Partition into (active, waived) and report stale waiver keys."""
    active, waived = [], []
    hit = set()
    for f in findings:
        if f.key in waivers:
            waived.append(f)
            hit.add(f.key)
        else:
            active.append(f)
    stale = sorted(set(waivers) - hit)
    return active, waived, stale
