"""Unified container-codec subsystem (see base.py for the contract).

Every compressed-tensor path in the system — activation stash, KV cache,
gradient wire format, checkpoint payloads — resolves its container here:

    codec = codecs.get("sfp8")
    packed = codec.pack(x, bits=n)       # fused quantize+pack
    x_q = codecs.unpack(packed)          # or codec.unpack(packed)
    codec.packed_bits(x)                 # exact realized footprint

Registered containers:
  bit_exact — fake-quant accounting mode (payload is the quantized tensor;
              footprint is the paper's idealized variable-length encoding)
  sfp8      — 1s + 4 delta-exp + 3 mantissa byte, shared base per 128 lanes
  sfp16     — 1s + 5 delta-exp + 10/7 mantissa word, shared base per group
  gecko8    — sign+mantissa byte + *realized* Gecko delta-mode exponent
              stream (paper §IV-C), byte-aligned; lossless for bf16

New containers register via codecs.register() and become available to all
call sites at once; parametric families resolve lazily via
register_factory(): the *dense* ``sfp-m{K}e{E}`` geometries (variable
payload width 1 + E + K bits/value, stored as byte-aligned bit planes —
the policy-learned bitlengths realized as actual bytes) and the legacy
fixed-lane ``sfp{8|16}-m{K}e{E}`` family.
"""
from repro.codecs.base import (Codec, PackedTensor, get, names, register,
                               register_factory, suggest_name, unpack,
                               validate_name)
from repro.codecs.bit_exact import BIT_EXACT, BitExactCodec
from repro.codecs.gecko import GECKO8, Gecko8Codec
from repro.codecs.sfp import (SFP8, SFP16, SFPCodec, dense_fields,
                              dense_name, fields_for, maybe_codec)

# The paper's default realized container (and the KV-cache default).
DEFAULT_CONTAINER = SFP8

register(BitExactCodec())
register(SFPCodec(SFP8))
register(SFPCodec(SFP16))
register(Gecko8Codec())
register_factory(maybe_codec)

__all__ = [
    "Codec", "PackedTensor", "get", "names", "register", "register_factory",
    "suggest_name", "validate_name",
    "unpack", "fields_for", "dense_fields", "dense_name",
    "DEFAULT_CONTAINER", "BIT_EXACT", "SFP8", "SFP16", "GECKO8",
    "BitExactCodec", "SFPCodec", "Gecko8Codec",
]
