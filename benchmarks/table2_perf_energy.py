"""Table II: performance + energy-efficiency gains (analytic accelerator
model, as in the paper §VI-C — they too found cycle-accurate simulation of
full training impractical).

Model: per stashed layer, time = max(compute / FLOPS, traffic / DRAM_BW);
energy = flops * e_mac + traffic * e_dram. The paper's accelerator is
16 TFLOPS + 8x LPDDR4-3200; its effective DRAM traffic per stashed value
(tiling re-reads, weight/gradient movement, 32MB-buffer spills at batch
256) is not published, so we calibrate a single traffic-amplification
scalar k (bytes moved per stashed fp32 value = k * 8) such that the BF16
column reproduces the paper's published 1.53x ResNet speedup — then read
off SFP_QM / SFP_BC with OUR measured footprint ratios. One scalar
calibrated against one published number, predicting four others
(documented in EXPERIMENTS.md).

The same model with TPU-v5e constants translates Table II to the target
hardware: v5e's 3x higher flops/byte balance pushes every layer deeper
into the memory-bound regime, where SFP's traffic reduction converts to
time nearly 1:1 — the paper's "would benefit from higher computational
performance hardware" remark (§VI-C), quantified.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks import common, table1_footprint

FLOPS = 16e12
DRAM_BW = 8 * 25.6e9          # 8 channels LPDDR4-3200
E_MAC = 0.6e-12               # J/flop (65nm-scale)
E_DRAM = 20e-12               # J/byte (LPDDR4 access+IO)
PAPER_BF16_SPEEDUP = 1.53     # Table II, ResNet18

TPU_FLOPS = 197e12
TPU_BW = 819e9
TPU_E_MAC = 0.15e-12
TPU_E_DRAM = 8e-12


def _layers(stash):
    """Per stashed tensor: (flops, minimal fp32 traffic = write+read)."""
    out = []
    for s in stash:
        t = np.asarray(s["tensor"])
        n = int(t.size)
        c = int(t.shape[-1]) if t.ndim >= 2 else 64
        flops = 3 * 2 * 9 * c * n      # 3x3 conv producing it, fwd + 2x bwd
        out.append((float(flops), float(2 * 4 * n)))
    return out


def _totals(layers, ratio, fl, bw, em, ed):
    T = E = 0.0
    for flops, fp32_bytes in layers:
        traffic = fp32_bytes * ratio
        T += max(flops / fl, traffic / bw)
        E += flops * em + traffic * ed
    return T, E


def _calibrate_traffic(raw) -> float:
    """Traffic amplification k reproducing the paper's bf16 1.53x."""
    lo, hi = 0.1, 2000.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        layers = [(f, b * mid) for f, b in raw]
        t32, _ = _totals(layers, 1.0, FLOPS, DRAM_BW, E_MAC, E_DRAM)
        t16, _ = _totals(layers, 0.5, FLOPS, DRAM_BW, E_MAC, E_DRAM)
        if t32 / t16 > PAPER_BF16_SPEEDUP:
            hi = mid        # too memory-bound: less amplification
        else:
            lo = mid
    return 0.5 * (lo + hi)


def run() -> Dict:
    fp = table1_footprint.run()
    base = common.cnn_run("none")
    _, stash = common.cnn_stash(base, "none")
    raw = _layers(stash)
    k = _calibrate_traffic(raw)
    layers = [(f, b * k) for f, b in raw]

    ratios = {
        "bf16": 0.5,
        "qm": fp["resnet8_qm"]["vs_fp32"],
        "bc": fp["resnet8_bitchop"]["vs_fp32"],
        "qm_js": fp["resnet8_qm"]["js_vs_fp32"],
    }
    out = {"calibrated_traffic_x": k, "ratios": ratios}
    for hwname, consts in (
            ("paper_accel", (FLOPS, DRAM_BW, E_MAC, E_DRAM)),
            ("tpu_v5e", (TPU_FLOPS, TPU_BW, TPU_E_MAC, TPU_E_DRAM))):
        fl, bw, em, ed = consts
        t32, e32 = _totals(layers, 1.0, fl, bw, em, ed)
        r = {}
        for name, ratio in ratios.items():
            t, e = _totals(layers, ratio, fl, bw, em, ed)
            r[f"speedup_{name}"] = t32 / t
            r[f"energy_{name}"] = e32 / e
        out[hwname] = r
    return out


def main():
    res = run()
    print(f"(traffic calibrated x{res['calibrated_traffic_x']:.1f} so bf16 "
          f"matches the paper's {PAPER_BF16_SPEEDUP}x; footprint ratios "
          f"{ {k: round(v, 3) for k, v in res['ratios'].items()} })")
    for hwname in ("paper_accel", "tpu_v5e"):
        r = res[hwname]
        print(f"[{hwname}] vs FP32 baseline "
              f"(paper: QM 2.30x/6.12x, BC 2.15x/4.54x perf/energy):")
        print(f"  perf    x: bf16 {r['speedup_bf16']:.2f}  "
              f"SFP_QM {r['speedup_qm']:.2f}  SFP_BC {r['speedup_bc']:.2f}  "
              f"SFP_QM+JS {r['speedup_qm_js']:.2f}")
        print(f"  energy  x: bf16 {r['energy_bf16']:.2f}  "
              f"SFP_QM {r['energy_qm']:.2f}  SFP_BC {r['energy_bc']:.2f}  "
              f"SFP_QM+JS {r['energy_qm_js']:.2f}")
    return res


if __name__ == "__main__":
    main()
