"""Pallas TPU kernel: online-softmax (flash) attention.

The compute hot-spot the compressed KV cache and activation stash feed
into. Supports causal masking, sliding windows (gemma local layers), logit
soft-capping (gemma2) and native GQA via folded q-head groups (``q_rep``)
— K/V are never repeated to the full q-head count.

Grid is (batch*heads, q_blocks, kv_blocks) with the kv index innermost; a
VMEM scratch accumulator carries the running (max, denominator, numerator)
across kv blocks — the standard TPU flash schedule, sized so one
(block_q x d) + (block_k x d) working set fits VMEM with MXU-aligned dims
(multiples of 128).

Oracle: repro.kernels.ref.attention. Validated in interpret mode on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import NEG_INF, default_interpret


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_k: int, causal: bool,
                  window: Optional[int], softcap: Optional[float],
                  scale: float, q_rep: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # GQA folding: q_rep consecutive query rows are the head group of one
    # logical sequence position, so their causal position is row // q_rep.
    q_pos = (qi * block_q
             + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)) // q_rep
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "q_rep", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, q_rep: int = 1,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention over (B, S, H, D); K/V carry the same head count.

    GQA callers fold the q-head group into the query rows instead of
    repeating K/V: pass q as (B, Sq*q_rep, KH, D) with rows ordered
    (seq, group member) and ``q_rep = H // KH`` — the kernel then derives
    the causal position of row r as r // q_rep, and each KV block is
    streamed once per head group (see kernels.ops.attention).
    """
    interpret = default_interpret(interpret)
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    assert k.shape == (B, Sk, H, D) and v.shape == (B, Sk, H, D)
    assert Sq % q_rep == 0, (Sq, q_rep)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    q_pad = (-Sq) % block_q
    k_pad = (-Sk) % block_k

    # (B*H, S, D) layout: one grid row per (batch, head).
    qt = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1).reshape(B * H, Sk, D)
    vt = jnp.moveaxis(v, 2, 1).reshape(B * H, Sk, D)
    if q_pad:
        qt = jnp.pad(qt, ((0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        kt = jnp.pad(kt, ((0, 0), (0, k_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, k_pad), (0, 0)))

    grid = (B * H, qt.shape[1] // block_q, kt.shape[1] // block_k)
    scale = 1.0 / (D ** 0.5)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_k=Sk, causal=causal, window=window,
                          softcap=softcap, scale=scale, q_rep=q_rep),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            _vmem_scratch((block_q, 1)),
            _vmem_scratch((block_q, 1)),
            _vmem_scratch((block_q, D)),
        ],
        interpret=interpret,
    )(qt, kt, vt)

    if q_pad:
        out = out[:, :Sq]
    return jnp.moveaxis(out.reshape(B, H, Sq, D), 1, 2)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
