"""olmoe-1b-7b [moe] — 64 experts, top-8 routing.

[arXiv:2409.02060; hf] 16L, d_model=2048, 16H (GQA kv=16), expert
d_ff=1024, vocab=50304.
"""
from repro.configs.base import ArchConfig, GLOBAL, register

OLMOE_1B_7B = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    period=(GLOBAL,),
    n_experts=64,
    top_k=8,
    d_ff_expert=1024,
    act="silu",
    source="arXiv:2409.02060 (OLMoE); assignment spec",
))
