"""Jitted dispatch wrappers: Pallas kernel on TPU, jnp reference elsewhere.

All model/runtime code calls through these so the same program runs on the
CPU test/dry-run environment (reference path; identical FLOP/byte shape)
and on real TPUs (Pallas path). ``force_backend()`` is the test hook.

These wrappers are format-agnostic: SFP entry points take a
``kernels.ref.PackFields`` payload geometry and the Gecko entry points take
raw exponent groups. Container *names* resolve to geometries in exactly
one place — the codec registry (``repro.codecs``) — which is also the only
API most callers should use.

The SFP packed representation is a plain (payload, bases) array pair —
array-only so it can ride through lax.scan as the compressed stash.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gecko_pack as _gp
from repro.kernels import mantissa_quant as _mq
from repro.kernels import ref as _ref
from repro.kernels import sfp_pack as _sp

PackFields = _ref.PackFields  # re-export: the kernel-facing format descriptor

_FORCED: Optional[str] = None  # None | 'pallas' | 'ref' | 'interpret'


def force_backend(name: Optional[str]) -> None:
    """Test hook: force 'pallas' (TPU), 'interpret' (CPU pallas), or 'ref'."""
    global _FORCED
    _FORCED = name


def backend() -> str:
    if _FORCED:
        return _FORCED
    return "pallas" if jax.default_backend() == "tpu" else "ref"


class Packed(NamedTuple):
    """SFP-compressed tensor: uint8/uint16 payload + per-group bases."""

    payload: jax.Array  # (R, 128) uint8 or uint16 payload words
    bases: jax.Array    # (R, 1) uint8 shared base exponents


# -- mantissa quantization ---------------------------------------------------

def mantissa_quantize(x: jax.Array, n) -> jax.Array:
    b = backend()
    if b == "pallas":
        return _mq.mantissa_quantize(x, n, interpret=False)
    if b == "interpret":
        return _mq.mantissa_quantize(x, n, interpret=True)
    return _ref.mantissa_truncate(x, n)


# -- SFP containers ----------------------------------------------------------

def sfp_compress(x: jax.Array, fields: PackFields) -> Packed:
    b = backend()
    if b in ("pallas", "interpret"):
        payload, bases = _sp.sfp_pack(x, fields=fields,
                                      interpret=(b == "interpret"))
    else:
        payload, bases = _ref.sfp_pack(x, fields)
    return Packed(payload=payload, bases=bases)


def sfp_decompress(packed: Packed, shape: tuple, dtype,
                   fields: PackFields) -> jax.Array:
    b = backend()
    if b in ("pallas", "interpret"):
        return _sp.sfp_unpack(packed.payload, packed.bases, shape=tuple(shape),
                              dtype=jnp.dtype(dtype), fields=fields,
                              interpret=(b != "pallas"))
    return _ref.sfp_unpack(packed.payload, packed.bases, tuple(shape),
                           jnp.dtype(dtype), fields)


def sfp_compress_nd(x: jax.Array, fields: PackFields, n=None) -> Packed:
    """Rank-preserving pack (sharding-friendly; last dim % 128 == 0).

    ``n`` (optional traced scalar) fuses Q(M, n) mantissa truncation into
    the pack — a single HBM read instead of the mantissa_quantize ->
    sfp_compress_nd two-kernel sequence.
    """
    b = backend()
    if b in ("pallas", "interpret"):
        # TPU path: the kernel operates on 128-lane rows; the reshape is a
        # no-op relayout on device. Interpret mode mirrors it for tests.
        rows = x.reshape(-1, _ref.GROUP)
        interp = (b == "interpret")
        if n is None:
            payload, bases = _sp.sfp_pack(rows, fields=fields,
                                          interpret=interp)
        else:
            payload, bases = _sp.sfp_quantize_pack(rows, n, fields=fields,
                                                   interpret=interp)
        return Packed(payload=payload.reshape(x.shape),
                      bases=bases.reshape(*x.shape[:-1],
                                          x.shape[-1] // _ref.GROUP))
    payload, bases = _ref.sfp_pack_nd(x, fields, n=n)
    return Packed(payload=payload, bases=bases)


def sfp_decompress_nd(packed: Packed, dtype, fields: PackFields) -> jax.Array:
    b = backend()
    if b in ("pallas", "interpret"):
        shape = packed.payload.shape
        rows = packed.payload.reshape(-1, _ref.GROUP)
        bases = packed.bases.reshape(-1, 1)
        out = _sp.sfp_unpack(rows, bases, shape=shape, dtype=jnp.dtype(dtype),
                             fields=fields, interpret=(b != "pallas"))
        return out
    return _ref.sfp_unpack_nd(packed.payload, packed.bases, jnp.dtype(dtype),
                              fields)


def sfp_quantize_compress(x: jax.Array, n, fields: PackFields) -> Packed:
    """Fused Q(M, n) + flat pack: one pass over ``x`` (single HBM read)."""
    b = backend()
    if b in ("pallas", "interpret"):
        payload, bases = _sp.sfp_quantize_pack(x, n, fields=fields,
                                               interpret=(b == "interpret"))
        return Packed(payload=payload, bases=bases)
    payload, bases = _ref.sfp_pack(x, fields, n=n)
    return Packed(payload=payload, bases=bases)


def sfp_roundtrip(x: jax.Array, fields: PackFields) -> jax.Array:
    """compress->decompress (fake-quant view of the realized container)."""
    return sfp_decompress(sfp_compress(x, fields), x.shape, x.dtype, fields)


# -- Gecko exponent compression ---------------------------------------------

def gecko_encode(groups: jax.Array):
    """(G, 64) uint8 exponent groups -> (bases, widths, planes)."""
    b = backend()
    if b in ("pallas", "interpret"):
        return _gp.gecko_pack(groups, interpret=(b == "interpret"))
    return _ref.gecko_plane_encode(groups)


def gecko_decode(bases: jax.Array, planes: jax.Array) -> jax.Array:
    """(bases (G, 8), planes (G, 63)) -> (G, 64) uint8 exponents."""
    b = backend()
    if b in ("pallas", "interpret"):
        return _gp.gecko_unpack(bases, planes, interpret=(b == "interpret"))
    return _ref.gecko_plane_decode(bases, planes)


# -- attention ---------------------------------------------------------------

def attention(q, k, v, *, causal=True, window=None, softcap=None,
              prefix_len: int = 0, q_offset: int = 0) -> jax.Array:
    """GQA attention; Pallas flash kernel on TPU, jnp reference off-TPU."""
    b = backend()
    if b in ("pallas", "interpret") and prefix_len == 0 and q_offset == 0:
        H, KH = q.shape[2], k.shape[2]
        if H != KH:
            k = jnp.repeat(k, H // KH, axis=2)
            v = jnp.repeat(v, H // KH, axis=2)
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap,
                                   interpret=(b == "interpret"))
    return _ref.attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, prefix_len=prefix_len,
                          q_offset=q_offset)
