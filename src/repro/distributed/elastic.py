"""Elastic scaling: rebuild meshes and reshard state when capacity changes.

The flow on a real fleet: a node dies -> the job restarts on the surviving
slice -> `plan_remesh` picks the largest valid (data, model) mesh for the
new device count -> the checkpoint restores with the new shardings
(CheckpointManager.restore re-places host-loaded leaves). Divisibility
constraints come from the model config (TP degree must divide fused head /
ff dims; batch must divide the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_devices: int


def valid_tp_degrees(cfg: ArchConfig, max_tp: int = 64) -> List[int]:
    """TP degrees that divide every model-sharded dim."""
    dims = [cfg.padded_vocab]
    if cfg.n_heads:
        dims += [cfg.n_heads * cfg.head_dim_, cfg.n_kv_heads * cfg.head_dim_]
    if cfg.d_ff:
        dims.append(cfg.d_ff)
    if cfg.is_moe:
        dims.append(cfg.n_experts)
    if cfg.ssm_state:
        dims.append(cfg.d_inner)
    if "rglru" in cfg.period:
        dims.append(cfg.lru_width_)
    out = []
    for tp in range(1, max_tp + 1):
        if all(d % tp == 0 for d in dims):
            out.append(tp)
    return out


def plan_remesh(n_devices: int, cfg: ArchConfig, global_batch: int,
                prefer_tp: int = 16) -> RemeshPlan:
    """Largest (data, model) mesh usable with ``n_devices`` survivors."""
    tps = [t for t in valid_tp_degrees(cfg, prefer_tp) if t <= n_devices]
    best: Optional[RemeshPlan] = None
    for tp in sorted(tps, reverse=True):
        data = n_devices // tp
        while data > 1 and global_batch % data != 0:
            data -= 1
        used = data * tp
        plan = RemeshPlan(shape=(data, tp), axes=("data", "model"),
                          dropped_devices=n_devices - used)
        if best is None or used > best.shape[0] * best.shape[1] or (
                used == best.shape[0] * best.shape[1]
                and abs(tp - prefer_tp) < abs(best.shape[1] - prefer_tp)):
            best = plan
    assert best is not None, "no valid mesh"
    return best


def build_mesh(plan: RemeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = plan.shape[0] * plan.shape[1]
    import numpy as np
    return Mesh(np.asarray(devices[:n]).reshape(plan.shape), plan.axes)
