"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family; unverified] Spec per assignment: 48L,
d_model=3840, 16H (GQA kv=8), d_ff=15360, vocab=262144.
"""
from repro.configs.base import ArchConfig, GLOBAL, LOCAL, register

GEMMA3_12B = register(ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262_144,
    period=(LOCAL,) * 5 + (GLOBAL,),   # 5:1 local:global
    window=1024,
    rope_theta=1_000_000.0,
    qk_norm=True,
    act="gelu",
    emb_scale=True,
    source="hf:google/gemma-3 family; assignment spec",
))
