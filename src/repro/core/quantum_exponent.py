"""Quantum Exponent: learning exponent bitlengths with gradient descent.

Paper §IV. The exponent-side sibling of Quantum Mantissa: a real-valued
bitlength parameter e per (tensor, kind) is optimized jointly with the
model, with the same stochastic-rounding forward and expectation-derivative
backward as qm_quantize:

  forward  : q = T(x, floor(e) + Bernoulli(frac(e)))
  backward : dL/dx = dL/dq                                     (STE)
             dL/de = sum(dL/dq * (T(x, floor(e)+1) - T(x, floor(e))))

where T is containers.truncate_exponent — values outside the e-bit normal
range flush to zero (underflow) or saturate (overflow). dL/de is the exact
derivative of E[T(x, e)] = (1-{e}) T(x, floor e) + {e} T(x, floor e + 1),
piecewise-linear in e, so it costs one extra truncation in the backward
pass — the same O(n) overhead argument as §IV-A3.

Exponent bitlengths live in [MIN_EXP_BITS, spec.exp_bits]: a 1-bit IEEE
exponent has no normal codes, so the learnable range bottoms out at 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import containers


@jax.custom_vjp
def qe_quantize(x: jax.Array, e: jax.Array, key: jax.Array) -> jax.Array:
    """Stochastic fractional-bitlength exponent truncation.

    Args:
      x:   float array (fp32 or bf16).
      e:   scalar float32 exponent bitlength parameter (differentiable).
      key: PRNG key; one Bernoulli draw per call (per-tensor granularity).
    """
    spec = containers.spec_for(x)
    e_int = containers.stochastic_bitlength(
        e, key, spec.exp_bits, min_bits=containers.MIN_EXP_BITS)
    return containers.truncate_exponent(x, e_int)


def _qe_fwd(x, e, key):
    spec = containers.spec_for(x)
    e_int = containers.stochastic_bitlength(
        e, key, spec.exp_bits, min_bits=containers.MIN_EXP_BITS)
    q = containers.truncate_exponent(x, e_int)
    # Save x and e (scalar); T(x, floor), T(x, floor+1) are recomputed in
    # the backward pass — keeping the stash small is the point.
    return q, (x, e)


def _qe_bwd(res, g):
    x, e = res
    spec = containers.spec_for(x)
    ef = jnp.clip(jnp.asarray(e, jnp.float32), float(containers.MIN_EXP_BITS),
                  float(spec.exp_bits))
    floor_e = jnp.floor(ef).astype(jnp.int32)
    ceil_e = jnp.minimum(floor_e + 1, spec.exp_bits)
    q_lo = containers.truncate_exponent(x, floor_e)
    q_hi = containers.truncate_exponent(x, ceil_e)
    # dE[T]/de = T(x, floor+1) - T(x, floor)  (0 once e >= exp_bits)
    diff = (q_hi - q_lo).astype(jnp.float32)
    de = jnp.sum(g.astype(jnp.float32) * diff).astype(jnp.float32)
    dx = g.astype(x.dtype)  # straight-through
    return dx, de, None


qe_quantize.defvjp(_qe_fwd, _qe_bwd)


def qe_quantize_deterministic(x: jax.Array, e: jax.Array) -> jax.Array:
    """Deployment-mode truncation: round the learned bitlength up (§IV-A4)."""
    spec = containers.spec_for(x)
    e_int = jnp.clip(jnp.ceil(jnp.asarray(e, jnp.float32)),
                     containers.MIN_EXP_BITS,
                     spec.exp_bits).astype(jnp.int32)
    return containers.truncate_exponent(x, e_int)
