"""Policy-aware serving precision: learned bitlengths -> pool codec geometry.

The paper's deployment round-up (§IV-A4): bitlengths learned during
training (Quantum Mantissa / Quantum Exponent / BitWave) carry over to
inference. Training stamps its final per-run ``PrecisionDecision`` summary
into every checkpoint manifest (``CheckpointManager.save(extra=...)`` via
the train loop); this module reads it back with ``read_extra`` and derives
the serving KV pool's container from it — a *dense* ``sfp-m{K}e{E}``
geometry (codecs/sfp.py) whose bit-plane payload holds exactly
1 + learned-exponent + learned-mantissa bits per value, so the pool's
bytes shrink with the policy instead of rounding up to an 8/16-bit lane
(the fixed-lane word layout survives as the fast path when the budget
lands exactly on a lane width).

No policy state is restored and no model leaves are touched: the decision
summary is tiny JSON metadata, so a serving host can size its pool before
it ever loads weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.checkpoint.manager import CheckpointManager


def container_for_decision(man_bits: float, exp_bits: float) -> str:
    """Map a (possibly fractional) learned decision to a container name.

    Delegates to ``codecs.dense_name``: bitlengths round up, the
    delta-exponent field clamps to [2, 7], and the payload is the dense
    1 + dexp + man bit-plane geometry (realized as a fixed-lane word only
    when it lands exactly on 8/16 bits).
    """
    from repro import codecs

    return codecs.dense_name(man_bits, exp_bits)


def decision_from_extra(extra: Dict[str, Any]) -> Optional[Dict[str, float]]:
    d = extra.get("decision")
    if not isinstance(d, dict):
        return None
    try:
        return {"man_bits": float(d["man_bits"]),
                "exp_bits": float(d["exp_bits"])}
    except (KeyError, TypeError, ValueError):
        return None


@dataclasses.dataclass
class PressureController:
    """Hysteresis watermark controller for precision-downshift degradation.

    The paper's runtime-adaptable container width gives serving a
    degradation axis beyond "reject or preempt": when free pool *bytes*
    drop below the ``low`` watermark, new admissions downshift to the
    engine's narrower ``degraded_container`` geometry (priced at its
    smaller per-block byte rate by the pool's dense byte accounting), and
    restore the configured geometry once the free fraction recovers above
    ``high``. The low/high gap is hysteresis — without it the controller
    chatters on the watermark as admissions/frees cross it every step.

    Already-running slots are never touched: the downshift applies to new
    prompt KV only (requantized at prefill), so degradation is gradual and
    reversible by attrition.
    """

    low: float = 0.25    # degrade when free_bytes/capacity < low
    high: float = 0.50   # restore once free_bytes/capacity >= high
    degraded: bool = False

    def __post_init__(self):
        if not (0.0 <= self.low < self.high <= 1.0):
            raise ValueError(f"watermarks need 0 <= low < high <= 1, "
                             f"got low={self.low} high={self.high}")

    def update(self, free_bytes: float, capacity_bytes: float) -> bool:
        """Advance the controller; returns True while degraded."""
        frac = free_bytes / capacity_bytes if capacity_bytes > 0 else 1.0
        if self.degraded:
            if frac >= self.high:
                self.degraded = False
        elif frac < self.low:
            self.degraded = True
        return self.degraded


def container_from_checkpoint(ckpt_dir: str,
                              step: Optional[int] = None) -> str:
    """Serving container for a trained run's checkpoint directory.

    Prefers the stamped PrecisionDecision summary (policy-learned
    geometry); falls back to the container the run trained with, then to
    the registry default. Raises if the directory holds no checkpoints.
    """
    from repro import codecs

    mgr = CheckpointManager(ckpt_dir)
    if step is None:
        step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    extra = mgr.read_extra(step)
    decision = decision_from_extra(extra)
    if decision is not None:
        return container_for_decision(**decision)
    return extra.get("container") or codecs.DEFAULT_CONTAINER
