"""Per-kernel allclose vs the pure-jnp oracles, sweeping shapes and dtypes
(interpret mode executes the kernel bodies on CPU).

Container names resolve to payload geometries through the codec registry
(repro.codecs.fields_for); the kernels themselves are format-agnostic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.core import containers as C
from repro.kernels import flash_attention as fa
from repro.kernels import gecko_pack as gp
from repro.kernels import mantissa_quant as mq
from repro.kernels import ops, ref
from repro.kernels import sfp_pack as sp


def _fields(container, dtype):
    return codecs.fields_for(container, dtype)


@pytest.mark.parametrize("shape", [(128,), (3, 100), (5, 7, 64), (2, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [0, 1, 4, 7])
def test_mantissa_quant_kernel_matches_oracle(shape, dtype, n):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32) * 10
         ).astype(dtype)
    got = mq.mantissa_quantize(x, jnp.int32(n), interpret=True, block_rows=8)
    want = ref.mantissa_truncate(x, n)
    np.testing.assert_array_equal(
        np.asarray(C.bitcast_to_int(got)), np.asarray(C.bitcast_to_int(want)))


@pytest.mark.parametrize("rows", [1, 3, 64, 130])
@pytest.mark.parametrize("container,dtype", [("sfp8", jnp.bfloat16),
                                             ("sfp16", jnp.bfloat16),
                                             ("sfp16", jnp.float32)])
def test_sfp_pack_kernel_matches_oracle(rows, container, dtype):
    f = _fields(container, dtype)
    x = (jax.random.normal(jax.random.PRNGKey(1), (rows, 128), jnp.float32)
         * 5).astype(dtype)
    pk, bk = sp.sfp_pack(x, fields=f, interpret=True, block_rows=16)
    pr, br = ref.sfp_pack(x, f)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    uk = sp.sfp_unpack(pk, bk, shape=x.shape, dtype=dtype,
                       fields=f, interpret=True, block_rows=16)
    ur = ref.sfp_unpack(pr, br, x.shape, dtype, f)
    np.testing.assert_array_equal(np.asarray(C.bitcast_to_int(uk)),
                                  np.asarray(C.bitcast_to_int(ur)))


@pytest.mark.parametrize("n", [0, 2, 5])
@pytest.mark.parametrize("container,dtype", [("sfp8", jnp.bfloat16),
                                             ("sfp16", jnp.float32)])
def test_fused_quantize_pack_matches_two_kernel_sequence(n, container, dtype):
    """The fused kernel must be bit-exact against mantissa_quantize
    followed by sfp_pack — same payload, same bases."""
    f = _fields(container, dtype)
    x = (jax.random.normal(jax.random.PRNGKey(7), (64, 128), jnp.float32)
         * 3).astype(dtype)
    pk, bk = sp.sfp_quantize_pack(x, jnp.int32(n), fields=f, interpret=True,
                                  block_rows=16)
    q = mq.mantissa_quantize(x, jnp.int32(n), interpret=True, block_rows=16)
    pr, br = sp.sfp_pack(q, fields=f, interpret=True, block_rows=16)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    # ...and against the fused jnp oracle.
    po, bo = ref.sfp_pack(x, f, n=jnp.int32(n))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(po))
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(bo))


@pytest.mark.parametrize("container,man_keep", [("sfp8", 3), ("sfp16", 7)])
def test_sfp_roundtrip_exact_when_within_budget(container, man_keep):
    """Values pre-truncated to the container's mantissa budget and within
    the delta-exponent range round-trip bit-exactly."""
    f = _fields(container, jnp.bfloat16)
    x = (jax.random.normal(jax.random.PRNGKey(2), (4, 256), jnp.float32)
         ).astype(jnp.bfloat16)
    x = C.truncate_mantissa(x, man_keep)
    p, b, = ref.sfp_pack_nd(x, f)
    back = ref.sfp_unpack_nd(p, b, jnp.bfloat16, f)
    np.testing.assert_array_equal(np.asarray(x).view(np.uint16),
                                  np.asarray(back).view(np.uint16))


def test_sfp8_bounded_error_out_of_budget():
    f = _fields("sfp8", jnp.bfloat16)
    x = (jax.random.normal(jax.random.PRNGKey(3), (8, 512), jnp.float32)
         ).astype(jnp.bfloat16)
    back = ops.sfp_decompress_nd(ops.sfp_compress_nd(x, f), jnp.bfloat16, f)
    err = np.abs(np.asarray(back, np.float32) - np.asarray(x, np.float32))
    group_max = np.abs(np.asarray(x, np.float32)).reshape(8, 4, 128).max(-1)
    rel = err.reshape(8, 4, 128) / group_max[..., None]
    assert rel.max() < 0.13  # 3 mantissa bits -> <= 2^-3 rel; + flush margin


def test_sfp_nd_matches_flat():
    f = _fields("sfp8", jnp.bfloat16)
    x = (jax.random.normal(jax.random.PRNGKey(4), (2, 3, 256), jnp.float32)
         ).astype(jnp.bfloat16)
    pn, bn = ref.sfp_pack_nd(x, f)
    pf, bf = ref.sfp_pack(x, f)
    np.testing.assert_array_equal(np.asarray(pn).reshape(-1, 128),
                                  np.asarray(pf))
    np.testing.assert_array_equal(np.asarray(bn).reshape(-1, 1),
                                  np.asarray(bf))


def test_sfp_preserves_exact_zeros():
    f = _fields("sfp8", jnp.bfloat16)
    x = jnp.zeros((1, 128), jnp.bfloat16).at[0, 3].set(1.5)
    back = ref.sfp_unpack_nd(*ref.sfp_pack_nd(x, f), jnp.bfloat16, f)
    assert float(back[0, 0]) == 0.0 and float(back[0, 3]) == 1.5


@pytest.mark.parametrize("n_groups", [1, 5, 128, 260])
def test_gecko_pack_kernel_matches_oracle(n_groups):
    rng = np.random.RandomState(0)
    e = jnp.asarray(np.clip(rng.normal(127, 4, (n_groups, 64)).round(),
                            0, 255).astype(np.uint8))
    bk, wk, pk = gp.gecko_pack(e, interpret=True, block_groups=64)
    br, wr, pr = ref.gecko_plane_encode(e)
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    dk = gp.gecko_unpack(bk, pk, interpret=True, block_groups=64)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(e))


def test_gecko_kernel_extreme_exponents():
    """Full-range deltas (|d| up to 255 -> width 8) survive the kernels."""
    e = jnp.asarray(np.array([[0, 255] * 32, [255] + [0] * 63],
                             np.uint8))
    bk, wk, pk = gp.gecko_pack(e, interpret=True)
    dk = gp.gecko_unpack(bk, pk, interpret=True)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(e))
    assert int(np.max(np.asarray(wk))) == 8


@pytest.mark.parametrize("S,window,softcap", [
    (256, None, None), (256, 64, None), (256, None, 50.0), (192, 50, 30.0)])
def test_flash_attention_matches_oracle(S, window, softcap):
    B, H, D = 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    got = fa.flash_attention(q, k, v, causal=True, window=window,
                             softcap=softcap, block_q=64, block_k=64,
                             interpret=True)
    want = ref.attention(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("H,KH,window,softcap", [
    (8, 2, None, None), (4, 1, 64, None), (6, 3, 40, 20.0)])
def test_flash_attention_gqa_folded_matches_oracle(H, KH, window, softcap):
    """ops.attention folds the q-head group into the query rows (q_rep)
    instead of repeating K/V to H heads; causal/window masks must follow
    the logical position row // q_rep."""
    B, S, D = 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    ops.force_backend("interpret")
    try:
        got = ops.attention(q, k, v, causal=True, window=window,
                            softcap=softcap)
    finally:
        ops.force_backend(None)
    want = ref.attention(q, k, v, causal=True, window=window,
                         softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    B, S, H, D = 1, 128, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32
                                 ).astype(jnp.bfloat16) for kk in ks)
    got = fa.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_ops_dispatch_ref_backend():
    ops.force_backend("ref")
    try:
        x = jnp.ones((4, 128), jnp.bfloat16) * 1.5
        q = ops.mantissa_quantize(x, 2)
        assert q.dtype == jnp.bfloat16
    finally:
        ops.force_backend(None)
