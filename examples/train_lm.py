"""End-to-end training driver: LM + precision policies, fault-tolerant loop.

  PYTHONPATH=src python examples/train_lm.py --steps 300 --preset small
  PYTHONPATH=src python examples/train_lm.py --arch gemma2-2b --preset tiny
  PYTHONPATH=src python examples/train_lm.py --policy qm+qe --steps 200
  PYTHONPATH=src python examples/train_lm.py --policy bitwave --steps 200

`--policy` accepts any registry policy (none/static/qm/qe/bitchop/bitwave)
or a '+'-composition: `qm+qe` learns mantissa AND exponent bitlengths in
one run. Presets reduce the assigned configs for this CPU box; `--preset
full --batch 256 --seq 4096` is the production shape (use launch/train.py
with a mesh on real hardware). Watch qm_act_mean collapse from 7 bits to
1-3 within the first tens of steps while xent tracks the baseline; the
final footprint line prices sign + mantissa + exponent bits per value.
"""
import sys

from repro.launch import train as train_cli

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "gemma2-2b", "--preset", "small",
                     "--policy", "qm", "--steps", "200",
                     "--metrics", "experiments/train_lm_metrics.jsonl",
                     "--ckpt-dir", "/tmp/sfp_ckpt", "--ckpt-every", "50"]
    train_cli.main()
