"""Controller policies: BitChop (mantissa) and BitWave (mantissa+exponent).

Both observe the per-batch training loss and steer network-wide integer
bitlengths through the eq. 8-9 EMA controller in core.bitchop — no
learned parameters, so ``learn`` is empty and everything lives in
``ctrl``. Weights stay untouched ("Presently, BitChop adjusts the
mantissa only for the activations" — §IV-B); BitWave extends the same
controller to spend shrink decisions on the exponent field too.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import bitchop
from repro.policies import base


@dataclasses.dataclass(frozen=True)
class BitChopPolicy(base.Policy):
    """BitChop (§IV-B): loss-EMA controlled network-wide mantissa bits."""

    alpha: float = 0.1
    eps_alpha: float = 0.1
    eps_scale: float = 1.0
    max_bits: Optional[int] = None  # None -> container mantissa bits
    min_bits: int = 0
    period: int = 1
    warmup_steps: int = 8
    lr_change_hold: int = 100

    name = "bitchop"
    requires_act_bits = True

    @property
    def quantizes_weights(self):  # §IV-B: activations only
        return False

    def _cfg(self, dims: base.ScopeDims) -> bitchop.BitChopConfig:
        return bitchop.BitChopConfig(
            alpha=self.alpha, eps_alpha=self.eps_alpha,
            eps_scale=self.eps_scale,
            max_bits=(dims.man_bits if self.max_bits is None
                      else self.max_bits),
            min_bits=self.min_bits, period=self.period,
            warmup_steps=self.warmup_steps,
            lr_change_hold=self.lr_change_hold)

    def init_state(self, dims):
        return base.PolicyState(learn={}, ctrl=bitchop.init(self._cfg(dims)))

    def control_view(self, ctrl, dims):
        return {"act": bitchop.effective_bits(ctrl, self._cfg(dims))}

    def forward_view(self, learn, cview, dims):
        return cview

    def scan_slices(self, view, dims):
        return {"act": jnp.broadcast_to(view["act"], (dims.n_periods,))}

    def rem_slice(self, view, i, dims):
        return {"act": view["act"]}

    def act_decision(self, pslice, key, dims):
        return base.PrecisionDecision(
            man_bits=jnp.asarray(pslice["act"], jnp.int32),
            exp_bits=jnp.asarray(dims.exp_bits, jnp.int32))

    def quantize_act(self, x, pslice, key, dims):
        return base.ste_truncate(x, pslice["act"])

    def observe(self, ctrl, loss, lr_changed, dims):
        return bitchop.update(ctrl, loss, self._cfg(dims),
                              lr_changed=lr_changed)

    def metrics(self, state, dims):
        return {"bc_bits": bitchop.effective_bits(
            state.ctrl, self._cfg(dims)).astype(jnp.float32)}

    def snapshot(self, state):
        return {"bc_bits": state.ctrl.n}

    def decision_summary(self, state, dims):
        return {"man_bits": float(state.ctrl.n),
                "exp_bits": float(dims.exp_bits)}


@dataclasses.dataclass(frozen=True)
class BitWavePolicy(base.Policy):
    """BitWave: BitChop's controller driving mantissa AND exponent bits.

    One shrink budget per decision, spent round-robin (mantissa first);
    regressions grow both fields at once. Exponent truncation follows
    containers.truncate_exponent (flush-to-zero under, saturate over).
    """

    alpha: float = 0.1
    eps_alpha: float = 0.1
    eps_scale: float = 1.0
    max_man_bits: Optional[int] = None  # None -> container field widths
    min_man_bits: int = 0
    max_exp_bits: Optional[int] = None
    min_exp_bits: int = 2
    period: int = 1
    warmup_steps: int = 8
    lr_change_hold: int = 100

    name = "bitwave"
    adapts_exponent = True
    requires_act_bits = True

    @property
    def quantizes_weights(self):  # like BitChop: activations only
        return False

    def _cfg(self, dims: base.ScopeDims) -> bitchop.BitWaveConfig:
        return bitchop.BitWaveConfig(
            alpha=self.alpha, eps_alpha=self.eps_alpha,
            eps_scale=self.eps_scale,
            max_man_bits=(dims.man_bits if self.max_man_bits is None
                          else self.max_man_bits),
            min_man_bits=self.min_man_bits,
            max_exp_bits=(dims.exp_bits if self.max_exp_bits is None
                          else self.max_exp_bits),
            min_exp_bits=self.min_exp_bits, period=self.period,
            warmup_steps=self.warmup_steps,
            lr_change_hold=self.lr_change_hold)

    def init_state(self, dims):
        return base.PolicyState(learn={},
                                ctrl=bitchop.bitwave_init(self._cfg(dims)))

    def control_view(self, ctrl, dims):
        man, exp = bitchop.bitwave_effective(ctrl, self._cfg(dims))
        return {"act": man, "act_e": exp}

    def forward_view(self, learn, cview, dims):
        return cview

    def scan_slices(self, view, dims):
        return {k: jnp.broadcast_to(v, (dims.n_periods,))
                for k, v in view.items()}

    def rem_slice(self, view, i, dims):
        return view

    def act_decision(self, pslice, key, dims):
        # Callers that drive only one bitlength (the CNN benchmark path)
        # may omit the exponent leaf; full width is the safe default.
        exp = pslice.get("act_e", dims.exp_bits) if isinstance(pslice, dict) \
            else dims.exp_bits
        return base.PrecisionDecision(
            man_bits=jnp.asarray(pslice["act"], jnp.int32),
            exp_bits=jnp.asarray(exp, jnp.int32))

    def quantize_act(self, x, pslice, key, dims):
        return base.apply_decision_ste(
            x, self.act_decision(pslice, key, dims), dims,
            adapts_exponent=True)

    def observe(self, ctrl, loss, lr_changed, dims):
        return bitchop.bitwave_update(ctrl, loss, self._cfg(dims),
                                      lr_changed=lr_changed)

    def metrics(self, state, dims):
        man, exp = bitchop.bitwave_effective(state.ctrl, self._cfg(dims))
        return {"bw_man_bits": man.astype(jnp.float32),
                "bw_exp_bits": exp.astype(jnp.float32)}

    def snapshot(self, state):
        return {"bw_man": state.ctrl.n_man, "bw_exp": state.ctrl.n_exp}

    def decision_summary(self, state, dims):
        return {"man_bits": float(state.ctrl.n_man),
                "exp_bits": float(state.ctrl.n_exp)}
