"""Codec-compressed KV cache (beyond-paper application of the containers).

Decode is memory-bandwidth-bound by the KV cache read — exactly the regime
the paper targets at the DRAM interface. The cache stores the packed
representation of whichever registry codec the caller picks (default: the
paper's sfp8 container — 1 sign + 4 delta-exp + 3 mantissa per value, one
shared base exponent per 128 lanes) and decompresses on read; each decode
step packs only the new token's K/V row. Cache bytes drop ~2x vs bf16 at
<= 3 mantissa bits of precision, matching where Quantum Mantissa lands
(paper Fig 4).

All container specifics live behind repro.codecs: this module only splices
packed parts along the sequence axis, so any codec whose parts carry
(batch, seq, ...) leading dims — every fixed-width registry codec — works
unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.configs.base import ArchConfig, LOCAL
from repro.models import attention


class PackedKV(NamedTuple):
    k: codecs.PackedTensor  # parts shaped (B, L, ...), D = KH * head_dim
    v: codecs.PackedTensor


def _dims(cfg: ArchConfig, kind: str, max_len: int):
    D = cfg.n_kv_heads * cfg.head_dim_
    assert D % 128 == 0, (D, "KV feature dim must align to 128 lanes")
    L = min(max_len, cfg.window) if kind == LOCAL else max_len
    return D, L


def _codec(container: Optional[str]) -> codecs.Codec:
    return codecs.get(container or codecs.DEFAULT_CONTAINER)


def packed_cache_spec(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: Optional[str] = None) -> PackedKV:
    D, L = _dims(cfg, kind, max_len)
    spec = _codec(container).packed_spec((batch, L, D), cfg.compute_dtype)
    return PackedKV(k=spec, v=spec)


def packed_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: Optional[str] = None) -> PackedKV:
    spec = packed_cache_spec(cfg, kind, batch, max_len, container)
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    return zeros


def packed_cache_axes(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: Optional[str] = None) -> PackedKV:
    """Logical sharding axes: every packed part is (batch, seq, ...)."""
    spec = packed_cache_spec(cfg, kind, batch, max_len, container)
    return jax.tree.map(
        lambda s: ("batch", "cache_seq") + (None,) * (len(s.shape) - 2), spec)


def _splice(cache_pt: codecs.PackedTensor, new_pt: codecs.PackedTensor,
            slot) -> codecs.PackedTensor:
    """Write one packed token row into the ring buffer (every part shares
    the sequence axis at dim 1)."""
    data = {
        k: jax.lax.dynamic_update_slice_in_dim(cache_pt.data[k],
                                               new_pt.data[k], slot, axis=1)
        for k in cache_pt.data
    }
    return codecs.PackedTensor(cache_pt.codec, cache_pt.shape,
                               cache_pt.dtype, data)


def attention_decode_packed(params, h_tok: jax.Array, cache: PackedKV,
                            pos: jax.Array, cfg: ArchConfig, *, kind: str,
                            container: Optional[str] = None
                            ) -> Tuple[jax.Array, PackedKV]:
    """One-token decode over the compressed cache."""
    codec = _codec(container)
    B = h_tok.shape[0]
    hd, H, KH = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    D = KH * hd
    L = cache.k.shape[1]
    dtype = h_tok.dtype

    q, k_new, v_new = attention._project_qkv(
        params, h_tok, cfg, jnp.full((1,), pos, jnp.int32))
    slot = attention.decode_slot_index(pos, L, kind)

    # Pack only the new token's row and splice it in.
    k_pt = _splice(cache.k, codec.pack(k_new.reshape(B, 1, D).astype(dtype)),
                   slot)
    v_pt = _splice(cache.v, codec.pack(v_new.reshape(B, 1, D).astype(dtype)),
                   slot)

    # Decompress-on-read (fused into the attention contraction on TPU).
    k_c = codec.unpack(k_pt).reshape(B, L, KH, hd)
    v_c = codec.unpack(v_pt).reshape(B, L, KH, hd)
    o = attention.decode_attend(q, k_c, v_c, pos, cfg, kind)
    out = o.reshape(B, 1, H * hd) @ params["wo"]
    return out, PackedKV(k=k_pt, v=v_pt)


def pack_prefill_cache(cache_kv: attention.KVCache,
                       container: Optional[str] = None) -> PackedKV:
    """Compress a prefill-produced bf16 cache in one shot."""
    codec = _codec(container)
    B, L, KH, hd = cache_kv.k.shape
    return PackedKV(k=codec.pack(cache_kv.k.reshape(B, L, KH * hd)),
                    v=codec.pack(cache_kv.v.reshape(B, L, KH * hd)))
