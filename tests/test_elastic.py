import pytest

from repro import configs
from repro.distributed import elastic


def test_valid_tp_degrees_respect_divisibility():
    cfg = configs.get("gemma3-12b")
    degs = elastic.valid_tp_degrees(cfg, 64)
    assert 1 in degs and 16 in degs
    for t in degs:
        assert (cfg.n_heads * cfg.head_dim_) % t == 0
        assert cfg.d_ff % t == 0
        assert cfg.padded_vocab % t == 0


def test_plan_remesh_uses_survivors():
    cfg = configs.get("gemma2-2b")
    plan = elastic.plan_remesh(256, cfg, global_batch=256, prefer_tp=16)
    assert plan.shape[0] * plan.shape[1] == 256
    assert plan.dropped_devices == 0


def test_plan_remesh_after_losing_nodes():
    cfg = configs.get("gemma2-2b")
    # lost 3 of 256 -> best mesh with 253 survivors
    plan = elastic.plan_remesh(253, cfg, global_batch=256, prefer_tp=16)
    used = plan.shape[0] * plan.shape[1]
    assert used <= 253
    assert 256 % plan.shape[0] == 0  # batch still divides data axis
    assert plan.dropped_devices == 253 - used


def test_plan_remesh_moe_keeps_expert_divisibility():
    cfg = configs.get("olmoe-1b-7b")
    plan = elastic.plan_remesh(48, cfg, global_batch=64, prefer_tp=8)
    assert cfg.n_experts % plan.shape[1] == 0
