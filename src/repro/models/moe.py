"""Mixture-of-Experts FFN: top-k routing with capacity + scatter dispatch.

Dispatch is segment-sum scatter into an (E, C, d) buffer grouped per batch
row (T5X-style groups): positions within an expert come from a cumulative
sum over the (tokens x slots) one-hot assignment, tokens past capacity are
dropped (tracked in aux metrics). Expert weights shard over the `model`
mesh axis (expert parallelism); XLA inserts the token all-to-alls from the
sharding annotations. An explicit shard_map all-to-all variant is the
collective-bound hillclimb candidate (EXPERIMENTS.md §Perf).

Aux losses: Switch-style load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common


def moe_init(p: common.ParamFactory, cfg: ArchConfig):
    d, E, ffe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    params = {
        "router": p((d, E), ("embed", "experts"), dtype=jnp.float32),
        "w_in": p((E, d, ffe), ("experts", "embed", "expert_ff")),
        "w_out": p((E, ffe, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.glu:
        params["w_gate"] = p((E, d, ffe), ("experts", "embed", "expert_ff"))
    return params


def capacity_for(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(c, cfg.top_k)


def moe_forward(params, h: jax.Array, cfg: ArchConfig
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """h: (B, S, d) -> (B, S, d), aux metrics/losses."""
    B, S, d = h.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity_for(cfg, S)

    logits = (h.astype(jnp.float32) @ params["router"])  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)      # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert, slot-major order.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (B, S, K, E)
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # exclusive
    pos = jnp.sum(pos.reshape(B, S, K, E) * onehot, axis=-1)  # (B, S, K)
    keep = pos < C

    # Scatter tokens into the (E*C, d) buffer per batch row.
    seg_ids = jnp.where(keep, expert_idx * C + pos, E * C)   # overflow -> drop
    data = jnp.broadcast_to(h[:, :, None, :], (B, S, K, d)).reshape(B, S * K, d)
    seg_flat = seg_ids.reshape(B, S * K)

    def scatter_row(row_data, row_ids):
        return jax.ops.segment_sum(row_data, row_ids, num_segments=E * C + 1)

    buf = jax.vmap(scatter_row)(data, seg_flat)[:, : E * C, :]
    buf = buf.reshape(B, E, C, d).astype(h.dtype)

    # Expert FFN (E sharded over `model`).
    inner = jnp.einsum("becd,edf->becf", buf, params["w_in"])
    a = common.activation(cfg.act)(inner.astype(jnp.float32)).astype(h.dtype)
    if cfg.glu:
        a = a * jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    out_buf = jnp.einsum("becf,efd->becd", a, params["w_out"])

    # Gather back and combine with gate weights.
    out_flat = out_buf.reshape(B, E * C, d)
    gathered = jnp.take_along_axis(
        out_flat, jnp.minimum(seg_flat, E * C - 1)[..., None], axis=1)
    gathered = gathered.reshape(B, S, K, d) * (
        gate_vals * keep.astype(jnp.float32))[..., None].astype(h.dtype)
    out = jnp.sum(gathered, axis=2)

    # Aux losses (fp32): Switch load-balance + z-loss.
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32).reshape(-1, E),
        axis=0)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return out, aux


def moe_decode(params, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Single-token MoE: run the dispatch path with the whole batch as one
    group — capacity becomes ceil(B*K/E*cf), tiny, and no full expert-weight
    gathers ever materialize."""
    B, S, d = h.shape
    assert S == 1, "moe_decode is the single-token path"
    out, _aux = moe_forward(params, h.reshape(1, B, d), cfg)
    return out.reshape(B, S, d)
