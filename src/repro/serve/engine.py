"""Serving engine: prefill + decode with (optionally compressed) KV cache.

`cache_axes` mirrors DecoderModel.init_cache structurally and assigns the
logical sharding: batch over (pod, data), the KV sequence dim over `model`
(flash-decoding style — XLA's softmax reductions over the sharded dim
become exact all-reduces), recurrent-state widths over `model`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, GLOBAL, LOCAL, SSD
from repro.models import attention, mamba2, rglru
from repro.models.model import DecoderModel
from repro.serve import kvcache as _kvcache


def _slot_axes(kind: str, model: DecoderModel, batch: int, max_len: int):
    if kind in (GLOBAL, LOCAL):
        if model.kv_container is not None:
            # Packed parts are (batch, seq, ...): same logical axes. The
            # real (batch, max_len) matter here: PackedTensor carries its
            # logical shape as pytree aux data, and the axes tree must
            # pair leaf-for-leaf with the actual cache tree.
            return _kvcache.packed_cache_axes(model.cfg, kind, batch,
                                              max_len, model.kv_container)
        return attention.KVCache(k=("batch", "cache_seq", "kv", None),
                                 v=("batch", "cache_seq", "kv", None))
    if kind == SSD:
        return mamba2.SSDCache(conv_x=("batch", None, "ssm_inner"),
                               conv_B=("batch", None, "state"),
                               conv_C=("batch", None, "state"),
                               state=("batch", "heads", None, None))
    return rglru.LRUCache(conv=("batch", None, "lru"),
                          state=("batch", "lru"))


def cache_axes(model: DecoderModel, batch: int = 1, max_len: int = 1):
    """Logical sharding axes matching ``model.init_cache(batch, max_len)``.

    ``batch``/``max_len`` are structural only for raw caches (plain axis
    tuples), but packed caches embed their shapes as pytree metadata —
    pass the same values as init_cache when ``model.kv_container`` is set.
    """
    cfg = model.cfg
    is_tuple = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a)
    per = {f"slot{i}": _slot_axes(k, model, batch, max_len)
           for i, k in enumerate(cfg.period)}
    periods = jax.tree.map(lambda a: ("layers",) + tuple(a), per,
                           is_leaf=is_tuple)
    axes = {"periods": periods}
    if cfg.remainder:
        axes["rem"] = {f"slot{i}": _slot_axes(k, model, batch, max_len)
                       for i, k in enumerate(cfg.remainder)}
    return axes


def make_serve_step(model: DecoderModel, greedy: bool = True):
    """(params, cache, token, pos) -> (next_token, cache). One decode step."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step


def make_prefill_step(model: DecoderModel, max_len: int):
    def prefill_step(params, tokens, cond_embeddings=None):
        return model.prefill(params, tokens, max_len,
                             cond_embeddings=cond_embeddings)

    return prefill_step


@dataclasses.dataclass
class GenerationResult:
    tokens: Any
    steps: int


def make_decode_loop(model: DecoderModel, n_steps: int):
    """Jitted greedy decode loop: one ``lax.scan`` over ``n_steps`` steps.

    The whole loop is a single XLA executable, so per-step host dispatch
    overhead disappears; the cache is donated (``donate_argnums``) so XLA
    updates it in place instead of copying the (possibly packed) ring
    buffers every step. Returns (tokens (n_steps, B, 1), final cache).
    """

    serve_step = make_serve_step(model)

    def loop(params, cache, tok, pos0):
        def step(carry, i):
            tok, cache = carry
            tok, cache = serve_step(params, cache, tok, pos0 + i)
            return (tok, cache), tok

        (tok, cache), toks = jax.lax.scan(
            step, (tok, cache), jnp.arange(n_steps, dtype=jnp.int32))
        return toks, cache

    return jax.jit(loop, donate_argnums=(1,))


def generate(model: DecoderModel, params, prompt: jax.Array, max_new: int,
             max_len: Optional[int] = None,
             cond_embeddings: Optional[jax.Array] = None) -> GenerationResult:
    """Greedy batched generation: jitted prefill + one jitted scan loop."""
    B, S = prompt.shape
    P = model.cfg.prefix_tokens if cond_embeddings is not None else 0
    max_len = max_len or (P + S + max_new)
    prefill = jax.jit(make_prefill_step(model, max_len))
    logits, cache = prefill(params, prompt, cond_embeddings)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    if max_new > 1:
        loop = make_decode_loop(model, max_new - 1)
        toks, cache = loop(params, cache, tok,
                           jnp.asarray(P + S, jnp.int32))
        out.append(jnp.moveaxis(toks[..., 0], 0, 1))  # (n, B, 1) -> (B, n)
    return GenerationResult(tokens=jnp.concatenate(out, axis=1),
                            steps=max_new)
