"""Shared benchmark infrastructure: short cached training runs.

The paper's figures come from full ImageNet runs; this environment is a
single CPU core (DESIGN.md D1), so each benchmark trains a reduced model
for a few dozen steps — enough to reproduce the *mechanism*: bitlength
collapse, loss parity, exponent-distribution sharpening. Runs are cached
under experiments/bench_cache/ keyed by configuration.
"""
from __future__ import annotations

import json
import pickle
import time
from pathlib import Path
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, policies
from repro.configs.base import reduced
from repro.core import bitchop
from repro.data import synthetic
from repro.models import cnn as cnn_mod
from repro.models.model import DecoderModel
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.train import step as step_mod

CACHE = Path(__file__).resolve().parent.parent / "experiments" / "bench_cache"


def bench_policy(policy_name: str, container: str = "bit_exact",
                 steps: int = 120) -> policies.Policy:
    """Registry policy with short-run hyperparameters.

    The paper anneals gamma over 90 epochs (450k batches); in an 80-120
    step run the footprint-pressure-per-step must be ~3 orders larger for
    the bitlength dynamics (collapse + data-gradient pushback) to play
    out. Decay mirrors the paper's 0.1 -> 0.01 -> 0.001.
    """
    decay = (steps // 2, 3 * steps // 4)
    kw = {}
    parts = policy_name.split("+")
    if "qm" in parts or "qe" in parts:
        kw = dict(gamma=1.2, lr=0.4, gamma_decay_steps=decay)
    if "bitchop" in parts or "bitwave" in parts:
        kw = dict(warmup_steps=6, **kw)
    return policies.get(policy_name, container=container, **kw)


def _cached(key: str, fn):
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"{key}.pkl"
    if f.exists():
        with f.open("rb") as fh:
            return pickle.load(fh)
    out = fn()
    with f.open("wb") as fh:
        pickle.dump(out, fh)
    return out


def lm_run(policy_mode: str, steps: int = 120, arch: str = "gemma2-2b",
           container: str = "bit_exact", seed: int = 0) -> Dict:
    """Train a reduced LM; returns metrics history + policy trajectories.

    ``policy_mode`` is any registry policy name ('+'-composable:
    "qm+qe"). The per-step trajectory records the policy's snapshot —
    per-period bitlength arrays for learned policies (keys ``act``/``w``
    for QM, ``act_e``/``w_e`` for QE), controller bits for
    BitChop/BitWave (``bc_bits`` / ``bw_man``+``bw_exp``).
    """

    def go():
        cfg = reduced(configs.get(arch), n_layers=4, d_model=128)
        pol = bench_policy(policy_mode, container, steps)
        model = DecoderModel(cfg, pol)
        tc = step_mod.TrainConfig(
            opt=adamw.AdamWConfig(lr=5e-3),
            schedule=Schedule(total_steps=steps, warmup_steps=4,
                              base_lr=5e-3),
            num_microbatches=1)
        step = jax.jit(step_mod.make_train_step(model, tc))
        state = step_mod.init_state(model, jax.random.PRNGKey(seed), tc)
        dcfg = synthetic.SyntheticConfig(vocab=cfg.vocab, seq_len=64,
                                         global_batch=8, seed=seed,
                                         temperature=1.0, n_modes=16)
        corpus = synthetic.MarkovCorpus(dcfg)
        hist: List[Dict] = []
        traj = []
        for i in range(steps):
            b = corpus.batch(i)
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            hist.append({k: float(np.asarray(v)) for k, v in m.items()})
            traj.append({k: np.asarray(v).tolist()
                         for k, v in pol.snapshot(state.pstate).items()})
        params_small = jax.tree.map(np.asarray, state.params)
        final = {k: np.asarray(v).tolist()
                 for k, v in pol.snapshot(state.pstate).items()}
        fp = policies.modeled_footprint(pol, state.pstate, model.dims)
        return {"history": hist, "qm_traj": traj, "arch": cfg.name,
                "params": params_small, "final": final, "footprint": fp,
                "final_qm_act": final.get("act"),
                "final_qm_w": final.get("w")}

    return _cached(f"lm_{arch}_{policy_mode}_{container}_{steps}_{seed}", go)


def cnn_run(policy_mode: str, steps: int = 80, seed: int = 0) -> Dict:
    """Train ResNet-8 (paper-family model) with the chosen policy."""

    def go():
        cfg = cnn_mod.RESNET8
        pol = policies.get(policy_mode, container="bit_exact")
        m = cnn_mod.CNN(cfg, pol)
        params = m.init(jax.random.PRNGKey(seed))
        opt = adamw.init(params)
        ocfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0)
        # Per-layer bitlengths (the paper's granularity, §IV-A): one
        # parameter per stashed tensor, footprint-weighted in the penalty.
        probe = m.forward(params, cnn_mod.synthetic_images(
            jax.random.PRNGKey(0), 1, cfg)["images"], collect_stash=True)[1]
        site_names = [s_["name"] for s_ in probe]
        numels = {s_["name"]: int(np.asarray(s_["tensor"]).size)
                  for s_ in probe}
        total_numel = sum(numels.values())
        lam = {k: v / total_numel for k, v in numels.items()}
        qm_bits = {k: jnp.asarray(7.0, jnp.float32) for k in site_names}
        bc_state = bitchop.init(bitchop.BitChopConfig(warmup_steps=6,
                                                      max_bits=23))
        bc_cfg = bitchop.BitChopConfig(warmup_steps=6, max_bits=23)
        gamma, qm_lr = 2.0, 0.6

        @jax.jit
        def train_step(params, opt, qm_bits, bc_n, key, batch):
            def loss_fn(p, nb):
                if policy_mode == "qm":
                    act_bits = nb
                elif policy_mode == "bitchop":
                    act_bits = bc_n
                else:
                    act_bits = None
                l, aux = m.loss(p, batch, act_bits=act_bits, key=key)
                if policy_mode == "qm":
                    pen = sum(lam[k] * jnp.clip(nb[k], 0, 23)
                              for k in site_names)
                    l = l + gamma * pen
                return l, aux

            (l, aux), (gp, gn) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(params, qm_bits)
            params, opt, _ = adamw.update(gp, opt, params, ocfg,
                                          jnp.asarray(1e-2))
            qm_new = {k: jnp.clip(qm_bits[k] - qm_lr * gn[k], 0.0, 23.0)
                      for k in site_names}
            return params, opt, qm_new, l, aux

        hist = []
        for i in range(steps):
            batch = cnn_mod.synthetic_images(
                jax.random.fold_in(jax.random.PRNGKey(seed + 1), i), 16, cfg)
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 2), i)
            params, opt, qm_bits, l, aux = train_step(
                params, opt, qm_bits, bc_state.n, key, batch)
            bc_state = bitchop.update(bc_state, float(l), bc_cfg)
            mean_bits = float(np.mean([float(v) for v in qm_bits.values()]))
            hist.append({"loss": float(l), "acc": float(aux["acc"]),
                         "qm_bits": mean_bits,
                         "bc_bits": int(bc_state.n)})
        final_bits = {k: float(v) for k, v in qm_bits.items()}
        return {"history": hist, "params": jax.tree.map(np.asarray, params),
                "final_qm_bits": float(np.mean(list(final_bits.values()))),
                "final_qm_bits_per_layer": final_bits,
                "final_bc_bits": int(bc_state.n)}

    return _cached(f"cnn_resnet8_{policy_mode}_{steps}_{seed}", go)


def cnn_stash(run: Dict, policy_mode: str, act_bits=None):
    """Re-run a forward pass collecting the stashed activations.

    ``act_bits``: None | float | {site: float} (per-layer QM bits)."""
    cfg = cnn_mod.RESNET8
    m = cnn_mod.CNN(cfg, policies.get(
        "qm" if policy_mode == "qm" else "none", container="bit_exact"))
    params = jax.tree.map(jnp.asarray, run["params"])
    batch = cnn_mod.synthetic_images(jax.random.PRNGKey(7), 8, cfg)
    if isinstance(act_bits, dict):
        bits = {k: jnp.asarray(v, jnp.float32) for k, v in act_bits.items()}
    elif act_bits is not None:
        bits = jnp.asarray(act_bits, jnp.float32)
    else:
        bits = None
    _, stash = m.forward(params, batch["images"], act_bits=bits,
                         key=jax.random.PRNGKey(8), collect_stash=True)
    return params, stash


def timeit(fn, *args):
    t0 = time.time()
    out = fn(*args)
    return out, (time.time() - t0) * 1e6
