"""Codec subsystem benchmark: fused quantize+pack vs the two-kernel
sequence, plus realized footprints of every registered container — now
including the *dense* variable payload-width family.

The paper's hardware compressor fuses the mantissa quantizer with the
container packer so a tensor crosses the memory boundary once. The TPU
realization is kernels/sfp_pack.py's ``sfp_quantize_pack`` (fixed-lane)
and kernels/bitplane_pack.py (dense bit planes); this benchmark measures
the same fusion on the reference backend — two separately compiled
executables (the old ops.mantissa_quantize -> ops.sfp_compress_nd
sequence, which materializes the quantized intermediate) against the
single-pass fused pack — and prices the realized packed bytes of each
container via ``codecs.packed_bits`` (plane layout + bases, not idealized
bit counts).

Headline: dense ``sfp-m2e4`` stores 7 bits/value + 8 bits per 128-lane
group = 7.06 bits — 0.44x of bf16 and 0.22x of fp32, below the 0.504x
floor any fixed 8-bit lane imposes; ``sfp-m1e2`` (4 bits/value) reaches
0.25x of bf16. The run *asserts* the regression guard the CI smoke relies
on: dense sfp-m2e4 packed bytes < fixed-lane sfp8 packed bytes on the
bench shape.

Emitted as BENCH_codecs.json standalone (``--quick`` for the CI smoke
shape) or via benchmarks/run.py.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

SHAPE = (8192, 8192)   # 128 MB of bf16 activations: memory-bound regime
SHAPE_QUICK = (1024, 1024)
BITS = 3               # where Quantum Mantissa lands (paper Fig 4)
ITERS = 10
ITERS_QUICK = 3
# Dense geometries probed alongside the registry: the policy-derived
# deployment points (QM ~2-3 mantissa bits, QE ~4-5 exponent bits).
DENSE_PROBES = ("sfp-m1e2", "sfp-m2e4", "sfp-m3e5")
OUT = Path(__file__).resolve().parent.parent / "BENCH_codecs.json"


def _median_ms(fn, iters=ITERS) -> float:
    fn()  # compile + warm caches
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def run(quick: bool = False) -> dict:
    from repro import codecs
    from repro.kernels import ops, ref

    shape = SHAPE_QUICK if quick else SHAPE
    iters = ITERS_QUICK if quick else ITERS
    ops.force_backend("ref")
    try:
        x = (jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
             ).astype(jnp.bfloat16)
        fields = codecs.fields_for(codecs.SFP8, x.dtype)
        dense_fields = codecs.fields_for("sfp-m2e4", x.dtype)
        n = jnp.int32(BITS)

        quant = jax.jit(lambda x, n: ref.mantissa_truncate(x, n))
        pack = jax.jit(lambda q: ref.sfp_pack_nd(q, fields))
        fused = jax.jit(lambda x, n: ref.sfp_pack_nd(x, fields, n=n))
        dense_fused = jax.jit(
            lambda x, n: ref.bitplane_pack_nd(x, dense_fields, n=n))

        two_ms = _median_ms(
            lambda: jax.block_until_ready(pack(quant(x, n))), iters)
        fused_ms = _median_ms(
            lambda: jax.block_until_ready(fused(x, n)), iters)
        dense_ms = _median_ms(
            lambda: jax.block_until_ready(dense_fused(x, n)), iters)

        # Bit-exactness of the fusion (same payload, same bases).
        p2, b2 = pack(quant(x, n))
        p1, b1 = fused(x, n)
        exact = bool(jnp.all(p1 == p2)) and bool(jnp.all(b1 == b2))
        # Dense plane fusion: pack(quant(x)) == fused dense pack.
        dp2, db2 = jax.jit(
            lambda q: ref.bitplane_pack_nd(q, dense_fields))(quant(x, n))
        dp1, db1 = dense_fused(x, n)
        dense_exact = bool(jnp.all(dp1 == dp2)) and bool(jnp.all(db1 == db2))

        # Realized footprint of each container on a small probe — packed
        # bytes as materialized (payload planes/words + bases), so dense
        # geometries price their true 1 + E + K bits per value.
        probe = x[:64]
        names = sorted(set(codecs.names()) | set(DENSE_PROBES))
        footprints = {
            name: float(codecs.get(name).packed_bits(probe)) / probe.size
            for name in names
        }
    finally:
        ops.force_backend(None)

    m2e4 = footprints["sfp-m2e4"]
    sfp8 = footprints["sfp8"]
    dense_vs_fixed = {
        "sfp-m2e4_bits_per_value": m2e4,
        "sfp8_bits_per_value": sfp8,
        "sfp-m2e4_vs_bf16": m2e4 / 16.0,
        "sfp-m2e4_vs_fp32": m2e4 / 32.0,
        "sfp-m1e2_vs_bf16": footprints["sfp-m1e2"] / 16.0,
        # the fixed-lane floor: the cheapest 8-bit-lane container vs bf16
        "fixed_lane_floor_vs_bf16": sfp8 / 16.0,
        "below_fixed_lane_floor": m2e4 < sfp8,
    }
    # Regression guard (CI quick-smoke): realized dense bytes must beat
    # the fixed lane — this is the whole point of the bit-plane layout.
    assert m2e4 < sfp8, (m2e4, sfp8)

    return {
        "backend": "ref",
        "container": codecs.SFP8,
        "dense_container": "sfp-m2e4",
        "shape": list(shape),
        "dtype": "bfloat16",
        "bits": BITS,
        "two_kernel_ms": two_ms,
        "fused_ms": fused_ms,
        "dense_fused_ms": dense_ms,
        "speedup": two_ms / fused_ms,
        "bit_exact_fusion": exact,
        "bit_exact_dense_fusion": dense_exact,
        "bits_per_value": footprints,
        "dense_vs_fixed": dense_vs_fixed,
    }


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller shape + fewer iters (CI smoke); the "
                         "dense-vs-fixed regression guard still asserts")
    args = ap.parse_args(argv)
    r = run(quick=args.quick)
    OUT.write_text(json.dumps(r, indent=2))
    print(json.dumps(r, indent=2))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
