"""Seeded violations: float64 introductions (containers assume <=32-bit)."""
import jax.numpy as jnp
from jax import config

x = jnp.zeros((4,), dtype=jnp.float64)  # LINT: float64
y = x.astype("float64")  # LINT: float64
config.update("jax_enable_x64", True)  # LINT: float64
ok = jnp.zeros((4,), dtype=jnp.float32)
