"""BitChop in action: watch the controller chase the loss (Fig 5-8).

  PYTHONPATH=src python examples/bitchop_demo.py
"""
import numpy as np

from benchmarks import common

r = common.lm_run("bitchop", steps=80)
bits = [t["bc_bits"] for t in r["qm_traj"]]
loss = [h["xent"] for h in r["history"]]
print("step  loss   bits   " + "(eq. 8-9: shrink while improving)")
for i in range(0, len(bits), 8):
    bar = "#" * bits[i]
    print(f"{i:4d}  {loss[i]:5.2f}  {bits[i]}  {bar}")
hist, _ = np.histogram(bits, bins=np.arange(9) - 0.5)
print("bit histogram 0..7:", hist.tolist())
