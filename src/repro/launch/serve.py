"""Serving launcher: prefill + batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --preset tiny \
      --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import reduced
from repro.models.model import DecoderModel
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small",
                                                         "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    elif args.preset == "small":
        cfg = reduced(cfg, n_layers=max(2 * len(cfg.period), 4), d_model=256)

    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    cond = (jnp.zeros((args.batch, cfg.prefix_tokens, cfg.d_model),
                      cfg.compute_dtype) if cfg.prefix_tokens else None)
    t0 = time.time()
    res = engine.generate(model, params, prompt, max_new=args.max_new,
                          cond_embeddings=cond)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"arch={cfg.name} generated {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    print("sample:", np.asarray(res.tokens[0]).tolist())


if __name__ == "__main__":
    main()
