"""SFP container policies: how stashed tensors get compressed.

A policy binds together (a) where mantissa bitlengths come from (Quantum
Mantissa parameters, the BitChop controller, a static choice, or none) and
(b) the realized on-TPU container (bit-exact accounting vs byte-aligned
SFP8/SFP16 packing).

Used by repro/train/step.py for activation stash + weight fake-quant and by
repro/serve/kvcache.py for the compressed KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import containers, quantum_mantissa


MODE_NONE = "none"
MODE_QM = "qm"          # learned per-tensor bitlengths (Quantum Mantissa)
MODE_BITCHOP = "bitchop"  # network-wide heuristic bitlength
MODE_STATIC = "static"  # fixed bitlength (Gist-style ablation baseline)


@dataclasses.dataclass(frozen=True)
class SFPPolicy:
    mode: str = MODE_NONE
    container: str = "sfp8"        # 'sfp8' | 'sfp16' | 'bit_exact'
    static_act_bits: int = 3       # for MODE_STATIC
    static_weight_bits: int = 7
    quantize_weights: bool = True  # QM quantizes weights too; BitChop acts only
    gecko_mode: str = "delta"
    gamma: float = 0.1             # QM regularizer strength

    @property
    def enabled(self) -> bool:
        return self.mode != MODE_NONE


def act_bits_for(policy: SFPPolicy, qm_bits: Optional[jax.Array],
                 bitchop_bits: Optional[jax.Array], max_bits: int):
    """Resolve the activation mantissa bitlength for one tensor group."""
    if policy.mode == MODE_QM:
        assert qm_bits is not None
        return qm_bits
    if policy.mode == MODE_BITCHOP:
        assert bitchop_bits is not None
        return bitchop_bits
    if policy.mode == MODE_STATIC:
        return jnp.asarray(policy.static_act_bits, jnp.int32)
    return jnp.asarray(max_bits, jnp.int32)


def fake_quant_weights(policy: SFPPolicy, w: jax.Array, n: Optional[jax.Array],
                       key: Optional[jax.Array]) -> jax.Array:
    """Weight-side quantization at use site (QM: learned + differentiable)."""
    if not policy.enabled or not policy.quantize_weights:
        return w
    if policy.mode == MODE_QM:
        return quantum_mantissa.qm_quantize(w, n, key)
    if policy.mode == MODE_STATIC:
        return containers.truncate_mantissa(w, policy.static_weight_bits)
    # BitChop leaves weights alone ("Presently, BitChop adjusts the mantissa
    # only for the activations" — §IV-B).
    return w


def stash_quantize(policy: SFPPolicy, x: jax.Array, n, key) -> jax.Array:
    """Activation-side quantization applied to stashed tensors.

    Differentiable via STE (and with dn for QM) — see quantum_mantissa.
    """
    if not policy.enabled:
        return x
    if policy.mode == MODE_QM:
        return quantum_mantissa.qm_quantize(x, n, key)
    # BitChop / static: integer bitlength, STE.
    return _ste_truncate(x, n)


@jax.custom_vjp
def _ste_truncate(x, n):
    return containers.truncate_mantissa(x, n)


def _ste_fwd(x, n):
    return containers.truncate_mantissa(x, n), None


def _ste_bwd(_, g):
    return g, None


_ste_truncate.defvjp(_ste_fwd, _ste_bwd)
