"""Per-kernel allclose vs the pure-jnp oracles, sweeping shapes and dtypes
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import containers as C
from repro.kernels import flash_attention as fa
from repro.kernels import mantissa_quant as mq
from repro.kernels import ops, ref
from repro.kernels import sfp_pack as sp


@pytest.mark.parametrize("shape", [(128,), (3, 100), (5, 7, 64), (2, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [0, 1, 4, 7])
def test_mantissa_quant_kernel_matches_oracle(shape, dtype, n):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32) * 10
         ).astype(dtype)
    got = mq.mantissa_quantize(x, jnp.int32(n), interpret=True, block_rows=8)
    want = ref.mantissa_truncate(x, n)
    np.testing.assert_array_equal(
        np.asarray(C.bitcast_to_int(got)), np.asarray(C.bitcast_to_int(want)))


@pytest.mark.parametrize("rows", [1, 3, 64, 130])
@pytest.mark.parametrize("container,dtype", [("sfp8", jnp.bfloat16),
                                             ("sfp16", jnp.bfloat16),
                                             ("sfp16", jnp.float32)])
def test_sfp_pack_kernel_matches_oracle(rows, container, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(1), (rows, 128), jnp.float32)
         * 5).astype(dtype)
    pk, bk = sp.sfp_pack(x, container=container, interpret=True, block_rows=16)
    pr, br = ref.sfp_pack(x, container)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    uk = sp.sfp_unpack(pk, bk, shape=x.shape, dtype=dtype,
                       container=container, interpret=True, block_rows=16)
    ur = ref.sfp_unpack(pr, br, x.shape, dtype, container)
    np.testing.assert_array_equal(np.asarray(C.bitcast_to_int(uk)),
                                  np.asarray(C.bitcast_to_int(ur)))


@pytest.mark.parametrize("container,man_keep", [("sfp8", 3), ("sfp16", 7)])
def test_sfp_roundtrip_exact_when_within_budget(container, man_keep):
    """Values pre-truncated to the container's mantissa budget and within
    the delta-exponent range round-trip bit-exactly."""
    x = (jax.random.normal(jax.random.PRNGKey(2), (4, 256), jnp.float32)
         ).astype(jnp.bfloat16)
    x = C.truncate_mantissa(x, man_keep)
    p, b, = ref.sfp_pack_nd(x, container)
    back = ref.sfp_unpack_nd(p, b, jnp.bfloat16, container)
    np.testing.assert_array_equal(np.asarray(x).view(np.uint16),
                                  np.asarray(back).view(np.uint16))


def test_sfp8_bounded_error_out_of_budget():
    x = (jax.random.normal(jax.random.PRNGKey(3), (8, 512), jnp.float32)
         ).astype(jnp.bfloat16)
    back = ops.sfp_decompress_nd(ops.sfp_compress_nd(x, "sfp8"),
                                 jnp.bfloat16, "sfp8")
    err = np.abs(np.asarray(back, np.float32) - np.asarray(x, np.float32))
    group_max = np.abs(np.asarray(x, np.float32)).reshape(8, 4, 128).max(-1)
    rel = err.reshape(8, 4, 128) / group_max[..., None]
    assert rel.max() < 0.13  # 3 mantissa bits -> <= 2^-3 rel; + flush margin


def test_sfp_nd_matches_flat():
    x = (jax.random.normal(jax.random.PRNGKey(4), (2, 3, 256), jnp.float32)
         ).astype(jnp.bfloat16)
    pn, bn = ref.sfp_pack_nd(x, "sfp8")
    pf, bf = ref.sfp_pack(x, "sfp8")
    np.testing.assert_array_equal(np.asarray(pn).reshape(-1, 128),
                                  np.asarray(pf))
    np.testing.assert_array_equal(np.asarray(bn).reshape(-1, 1),
                                  np.asarray(bf))


def test_sfp_preserves_exact_zeros():
    x = jnp.zeros((1, 128), jnp.bfloat16).at[0, 3].set(1.5)
    back = ref.sfp_unpack_nd(*ref.sfp_pack_nd(x, "sfp8"), jnp.bfloat16, "sfp8")
    assert float(back[0, 0]) == 0.0 and float(back[0, 3]) == 1.5


@pytest.mark.parametrize("S,window,softcap", [
    (256, None, None), (256, 64, None), (256, None, 50.0), (192, 50, 30.0)])
def test_flash_attention_matches_oracle(S, window, softcap):
    B, H, D = 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    got = fa.flash_attention(q, k, v, causal=True, window=window,
                             softcap=softcap, block_q=64, block_k=64,
                             interpret=True)
    want = ref.attention(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    B, S, H, D = 1, 128, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D), jnp.float32
                                 ).astype(jnp.bfloat16) for kk in ks)
    got = fa.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_ops_dispatch_ref_backend():
    ops.force_backend("ref")
    try:
        x = jnp.ones((4, 128), jnp.bfloat16) * 1.5
        q = ops.mantissa_quantize(x, 2)
        assert q.dtype == jnp.bfloat16
    finally:
        ops.force_backend(None)
