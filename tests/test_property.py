"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import containers as C, footprint, gecko
from repro.kernels import ref

floats = st.lists(
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
              allow_infinity=False, width=32),
    min_size=1, max_size=200)


@settings(max_examples=40, deadline=None)
@given(floats, st.integers(0, 23))
def test_truncation_never_increases_magnitude(vals, n):
    x = jnp.asarray(vals, jnp.float32)
    q = C.truncate_mantissa(x, n)
    assert (np.abs(np.asarray(q)) <= np.abs(np.asarray(x)) + 0.0).all()
    # sign preserved (or value zeroed)
    same_sign = np.sign(np.asarray(q)) == np.sign(np.asarray(x))
    assert (same_sign | (np.asarray(q) == 0)).all()


@settings(max_examples=40, deadline=None)
@given(floats, st.integers(0, 23))
def test_truncation_idempotent(vals, n):
    x = jnp.asarray(vals, jnp.float32)
    q1 = C.truncate_mantissa(x, n)
    q2 = C.truncate_mantissa(q1, n)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=40, deadline=None)
@given(floats, st.integers(0, 22))
def test_truncation_relative_error_bound(vals, n):
    """|x - Q(x,n)| < 2^-n * |x| for normal x (ulp bound)."""
    x = jnp.asarray(vals, jnp.float32)
    x = jnp.where(jnp.abs(x) < 1e-30, 1.0, x)  # skip denormals
    q = C.truncate_mantissa(x, n)
    rel = np.abs(np.asarray(x - q)) / np.abs(np.asarray(x))
    assert (rel < 2.0 ** (-n)).all()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 255), min_size=1, max_size=256))
def test_gecko_bits_at_least_metadata(vals):
    e = jnp.asarray(np.asarray(vals, np.uint8))
    bits = float(gecko.compressed_bits(e, "delta"))
    n_groups = -(-len(vals) // 64)
    assert bits >= n_groups * (64 + 21)  # 8 bases x 8b + 7 rows x 3b


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=-100, max_value=100, allow_nan=False,
                          width=32), min_size=128, max_size=128))
def test_sfp8_roundtrip_closure(vals):
    """decode(encode(x)) is a fixed point: encoding it again is identity."""
    x = jnp.asarray(vals, jnp.float32).astype(jnp.bfloat16).reshape(1, 128)
    from repro import codecs
    f = codecs.fields_for("sfp8", jnp.bfloat16)
    once = ref.sfp_unpack_nd(*ref.sfp_pack_nd(x, f), jnp.bfloat16, f)
    twice = ref.sfp_unpack_nd(*ref.sfp_pack_nd(once, f), jnp.bfloat16, f)
    np.testing.assert_array_equal(np.asarray(once).view(np.uint16),
                                  np.asarray(twice).view(np.uint16))


# ---------------------------------------------------------------------------
# Dense bit-plane containers: every payload width 3..16 vs a pure-Python
# oracle (independent numpy re-implementation of the word encode + the
# plane transpose, bit by bit).
# ---------------------------------------------------------------------------


def _py_sfp_words(x16: np.ndarray, man_keep: int, dexp_bits: int,
                  payload_bits: int) -> np.ndarray:
    """Pure-numpy bf16 SFP word encode over one (R, 128) row block."""
    u = x16.view(np.uint16).astype(np.int64)
    sign, e, man = (u >> 15) & 1, (u >> 7) & 0xFF, u & 0x7F
    base = e.max(axis=-1, keepdims=True)
    dexp = base - e
    dmax = (1 << dexp_bits) - 1
    man_top = man >> (7 - man_keep)
    flush = (e == 0) | (dexp > dmax)
    dexp = np.where(flush, dmax, np.minimum(dexp, dmax))
    man_top = np.where(flush, 0, man_top)
    sign = np.where(e == 0, 0, sign)
    word = ((sign << (payload_bits - 1))
            | (dexp << (payload_bits - 1 - dexp_bits))
            | (man_top << (payload_bits - 1 - dexp_bits - man_keep)))
    return word, base[..., 0]


# The loop-based plane transpose oracle is shared with the dense-codec
# suite — one definition of the byte/bit order, asserted from both sides.
from test_dense_codecs import py_plane_pack as _py_planes  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 7), st.integers(1, 8),
       st.lists(st.floats(min_value=-100, max_value=100, allow_nan=False,
                          width=32), min_size=128, max_size=128))
def test_dense_container_all_widths_vs_python_oracle(man, dexp, vals):
    """Sweep every dense payload width 3..16: packed planes match the
    pure-Python bit-plane oracle and the roundtrip is a fixed point."""
    payload = 1 + man + dexp
    if payload > 16:
        man = 16 - 1 - dexp  # clamp like codecs.dense_fields
        payload = 16
    from repro import codecs
    f = codecs.dense_fields(man, dexp, C.BF16)
    assert f.payload_bits == payload
    x = jnp.asarray(vals, jnp.float32).astype(jnp.bfloat16).reshape(1, 128)
    planes, bases = ref.bitplane_pack(x, f)
    words, base_py = _py_sfp_words(np.asarray(x).view(np.uint16),
                                   f.man_keep, f.dexp_bits, f.payload_bits)
    np.testing.assert_array_equal(np.asarray(bases)[:, 0], base_py)
    np.testing.assert_array_equal(np.asarray(planes),
                                  _py_planes(words, f.payload_bits))
    # roundtrip closure: re-encoding the decode is the identity
    once = ref.bitplane_unpack(planes, bases, (1, 128), jnp.bfloat16, f)
    p2, b2 = ref.bitplane_pack(once, f)
    twice = ref.bitplane_unpack(p2, b2, (1, 128), jnp.bfloat16, f)
    np.testing.assert_array_equal(np.asarray(once).view(np.uint16),
                                  np.asarray(twice).view(np.uint16))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 7), st.integers(1, 400))
def test_footprint_accounting_bounds(bits, n):
    x = (jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32)
         ).astype(jnp.bfloat16)
    rep = footprint.sfp_footprint(x, bits)
    assert rep.total_bits > 0
    assert rep.mantissa_bits == bits * n
    assert rep.sign_bits == n
    # never worse than ~9 extra bits/value of exponent+metadata
    assert rep.total_bits <= n * (1 + bits + 10) + 64 * 8


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6))
def test_bitchop_never_leaves_bounds(seed):
    from repro.core import bitchop
    rng = np.random.RandomState(seed)
    cfg = bitchop.BitChopConfig(warmup_steps=1, max_bits=7, min_bits=0)
    stt = bitchop.init(cfg)
    for i in range(50):
        stt = bitchop.update(stt, float(3 + rng.randn()), cfg,
                             lr_changed=(i % 17 == 0))
        assert 0 <= int(stt.n) <= 7


# The loop-based unpack oracle, shared the same way: both directions of
# the byte/bit order asserted against one independent definition.
from test_dense_codecs import py_plane_unpack as _py_plane_unpack  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 16), st.integers(1, 5),
       st.integers(0, 2 ** 31 - 1), st.integers(0, 127))
def test_plane_expansion_all_widths_vs_python_oracle(payload, rows, seed,
                                                     tail):
    """The SWAR plane transpose (pack and the byte-granular expansion)
    is bit-exact against the loop oracle for every payload width 3..16,
    including a tail-padded final row (only ``128 - tail`` live lanes —
    the ragged end of a cache whose length is not a lane multiple)."""
    rng = np.random.RandomState(seed)
    words = rng.randint(0, 1 << payload, size=(rows, 128)).astype(np.int32)
    if tail:
        words[-1, 128 - tail:] = 0
    planes = np.asarray(ref.plane_pack_words(jnp.asarray(words), payload))
    np.testing.assert_array_equal(planes, _py_planes(words, payload))
    back = np.asarray(ref.plane_unpack_words(jnp.asarray(planes), payload))
    np.testing.assert_array_equal(back, words)
    np.testing.assert_array_equal(_py_plane_unpack(planes, payload), words)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 7), st.integers(1, 8), st.integers(0, 15),
       st.lists(st.floats(min_value=-100, max_value=100, allow_nan=False,
                          width=32), min_size=128, max_size=128))
def test_prefix_plane_expansion_equals_truncated_pack(man, dexp, cut, vals):
    """Self-speculative draft-read invariant, for every dense geometry
    and every valid prefix depth P': the *leading* P' bit planes of a
    packed block are byte-identical to packing the same values at the
    truncated geometry (man_keep - drop, same dexp, P' payload bits),
    and expand to exactly the truncated payload words — all asserted
    against the pure-Python word/plane oracles. This is what lets the
    draft pass read a strict byte subset of the full-width pool and
    still decode a well-formed narrower container."""
    payload = 1 + man + dexp
    if payload > 16:
        man = 16 - 1 - dexp  # clamp like codecs.dense_fields
        payload = 16
    from repro import codecs
    f = codecs.dense_fields(man, dexp, C.BF16)
    lo = f.dexp_bits + 2  # sign + full dexp + >= 1 mantissa bit
    pp = lo + cut % (f.payload_bits - lo + 1)   # valid P' in [lo, P]
    drop = f.payload_bits - pp
    nf = ref.prefix_fields(f, pp)
    assert (nf.payload_bits, nf.dexp_bits, nf.man_keep) == (
        pp, f.dexp_bits, f.man_keep - drop)
    x = jnp.asarray(vals, jnp.float32).astype(jnp.bfloat16).reshape(1, 128)
    planes, bases = ref.bitplane_pack(x, f)
    sliced = np.asarray(ref.prefix_plane_view(planes, f, pp))
    x16 = np.asarray(x).view(np.uint16)
    words, base_wide = _py_sfp_words(x16, f.man_keep, f.dexp_bits,
                                     f.payload_bits)
    narrow_words, base_narrow = _py_sfp_words(x16, f.man_keep - drop,
                                              f.dexp_bits, pp)
    # Truncating the wide word IS the narrow-geometry encode (incl. the
    # flush-to-zero cases), and the shared exponent base is unchanged.
    np.testing.assert_array_equal(narrow_words, words >> drop)
    np.testing.assert_array_equal(base_wide, base_narrow)
    # The leading planes are byte-for-byte the narrow container's pack...
    np.testing.assert_array_equal(sliced, _py_planes(narrow_words, pp))
    # ...and the SWAR expansion of the slice yields the truncated words.
    np.testing.assert_array_equal(
        np.asarray(ref.plane_unpack_words(jnp.asarray(sliced), pp)),
        narrow_words)
    # out-of-range prefix depths must be rejected, not mis-sliced
    with pytest.raises(ValueError):
        ref.prefix_fields(f, lo - 1)
    with pytest.raises(ValueError):
        ref.prefix_fields(f, f.payload_bits + 1)


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 16), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_plane_unpack_bijective_on_trash_blocks(payload, rows, seed):
    """Arbitrary garbage plane bytes (what the pool's trash block holds)
    decode to in-range payload words, match the loop oracle, and
    re-encode to the identical bytes — expansion and packing are inverse
    bijections on the full byte space, so trash-backed reads can never
    fabricate out-of-range state."""
    rng = np.random.RandomState(seed)
    planes = rng.randint(0, 256,
                         size=(rows, payload * 16)).astype(np.uint8)
    words = np.asarray(ref.plane_unpack_words(jnp.asarray(planes),
                                              payload))
    assert (words >= 0).all() and (words < (1 << payload)).all()
    np.testing.assert_array_equal(words, _py_plane_unpack(planes, payload))
    again = np.asarray(ref.plane_pack_words(jnp.asarray(words), payload))
    np.testing.assert_array_equal(again, planes)
