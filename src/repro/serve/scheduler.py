"""Continuous-batching request scheduler over the paged serving engine.

vLLM-style control loop, sized down to this repo's engine: a FIFO request
queue, admission gated on free packed blocks (the pool measures capacity
in *compressed* bytes, so a tighter container admits more concurrent
requests), prefill/decode interleaving (each ``step()`` first admits
arrived requests — one prefill each — then advances every running slot by
one batched decode step), slot recycling (a finished request frees its
blocks and its slot in the same step; the next pending request takes them
without recompiling anything), and recompute-preemption (when the pool
cannot supply a running request's next block, the youngest other request
is evicted, its blocks freed, and it re-enters the queue with its
already-emitted tokens folded into the prompt — emitted tokens are never
retracted).

On top of that sits the fault-tolerance layer (this PR's subject):

* **Deadlines / cancellation** — a request past its (absolute) deadline
  or cancelled by the client frees its blocks immediately, whether
  pending or running; misses/cancellations are counted, and partial
  output is kept in ``results``.
* **Bounded queue + load shedding** — with ``max_pending`` set, arrived
  requests beyond the bound are *explicitly* shed (newest first, never a
  preempted/recovering request) and recorded as such — no silent drops.
* **Block integrity + recovery** — before every decode the engine's
  per-block checksums are verified over all allocated blocks; mismatched
  blocks are quarantined in the pool and the owning request recovers by
  recompute-from-prompt (the same emitted-token folding preemption uses,
  so its stream is token-identical to a fault-free run). A NaN/Inf logit
  guard catches corruption the checksum cannot see (integrity disabled,
  or decodable-but-wrong planes): the offending slot's blocks are
  quarantined and the request recovers the same way. ``max_recoveries``
  bounds repeated failures; beyond it a request is marked ``failed``
  rather than looping.
* **Preemption-storm guard** — ``storm_guard=True`` makes admission
  reserve the blocks running slots need for their next burst horizon
  (new work cannot steal a running request's growth and trigger
  admit→preempt thrash), and ``recompute_budget`` caps re-prefill tokens
  per step so recompute-preemption can never dominate a step. Oldest
  requests always finish: eviction stays youngest-first.
* **Graceful degradation** — with a ``PressureController`` attached
  (serve/precision.py), admissions while free pool *bytes* sit below the
  low watermark are downshifted to the engine's narrower
  ``degraded_container`` geometry: prompt KV is requantized at prefill
  and the slot's blocks are priced at the narrower per-block byte rate,
  so pressure admits more work instead of shedding it.

Tokens stream per request: every emitted token fires ``on_token(uid,
token, done)`` (scheduler-wide and per-request callbacks) the step it is
produced. Terminal bookkeeping (``finished``/``results``/token history)
is LRU-bounded by ``history_limit`` unless ``retain_history=True`` — a
long-running server no longer accumulates per-uid token lists forever.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs as obs_mod
from repro.serve.engine import PagedEngine
from repro.serve.pool import TRASH_BLOCK, blocks_for

OnToken = Callable[[Any, int, bool], None]


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in the caller's clock
    (the trace simulator drives a virtual clock); ``on_token`` streams
    this request's tokens as they are produced. ``deadline`` (optional)
    is an *absolute* time in the same clock: past it the request is
    expired and its blocks freed, wherever it is in the pipeline."""

    uid: Any
    prompt: np.ndarray          # (S,) int32 token ids
    max_new: int
    arrival: float = 0.0
    on_token: Optional[OnToken] = None
    deadline: Optional[float] = None
    requeued: bool = False      # internal: re-entered the queue after
    #                             preemption/recovery (never shed)


@dataclasses.dataclass
class RequestResult:
    """Terminal record for one request (``Scheduler.results``)."""

    status: str                 # ok | expired | cancelled | shed | failed
    tokens: np.ndarray          # every token emitted (partial if not ok)
    container: str              # geometry the final residency stored KV at
    recoveries: int = 0
    drafted: int = 0            # speculative drafts proposed for this uid
    draft_accepted: int = 0     # drafts the full-width verify confirmed


@dataclasses.dataclass
class _Running:
    req: Request
    slot: int
    admit_seq: int
    n_ctx: int                  # tokens whose KV is in the pool (prompt')
    last_tok: int               # most recent emitted token (next step's input)
    narrow: bool = False        # admitted downshifted (degraded geometry)
    emitted: List[int] = dataclasses.field(default_factory=list)


class SchedulerStats:
    """Read-only compat view over the obs metrics registry.

    The counters themselves now live in ``repro.obs`` (labeled,
    Prometheus-exportable); this struct keeps the attribute surface every
    existing test/bench/report reads. Each attribute is a property summing
    the backing family, so ``sched.stats.shed`` and the metrics export can
    never disagree — and the terminal-outcome identity (ok + expired +
    cancelled + shed + failed == submitted) is structural, because every
    terminal path increments exactly one ``serve_requests_total{outcome}``
    series inside ``Scheduler._record``.
    """

    # attribute -> serve_requests_total outcome label
    _OUTCOMES = {"finished": "ok", "deadline_misses": "expired",
                 "shed": "shed", "cancelled": "cancelled",
                 "failed": "failed"}
    # attribute -> unlabeled counter family
    _COUNTERS = {"preemptions": "serve_preemptions_total",
                 "decode_steps": "serve_decode_steps_total",
                 "emitted_tokens": "serve_tokens_total",
                 "recoveries": "serve_recoveries_total",
                 "corrupt_blocks": "serve_corrupt_blocks_total",
                 "nan_guard_trips": "serve_nan_guard_trips_total",
                 "alloc_failures": "serve_alloc_failures_total",
                 "recompute_tokens": "serve_recompute_tokens_total",
                 "downshifted": "serve_downshifted_total",
                 "submitted": "serve_submitted_total",
                 "drafted": "serve_drafted_total",
                 "draft_accepted": "serve_draft_accepted_total",
                 "draft_rejected": "serve_draft_rejected_total",
                 "spec_rounds": "serve_spec_rounds_total"}

    def __init__(self, registry: obs_mod.MetricsRegistry):
        self._reg = registry

    def __getattr__(self, name: str):
        reg = object.__getattribute__(self, "_reg")
        outcome = SchedulerStats._OUTCOMES.get(name)
        if outcome is not None:
            fam = reg.counter("serve_requests_total", labels=("outcome",))
            return int(fam.total(outcome=outcome))
        fam_name = SchedulerStats._COUNTERS.get(name)
        if fam_name is not None:
            return int(reg.counter(fam_name).value)
        if name == "admitted":
            fam = reg.counter("serve_admitted_total", labels=("geometry",))
            return int(fam.total())
        raise AttributeError(name)

    def as_dict(self) -> Dict[str, int]:
        return {k: getattr(self, k)
                for k in (*self._OUTCOMES, *self._COUNTERS, "admitted")}

    def __repr__(self) -> str:
        return f"SchedulerStats({self.as_dict()})"


class Scheduler:
    def __init__(self, engine: PagedEngine,
                 on_token: Optional[OnToken] = None, *,
                 max_pending: Optional[int] = None,
                 history_limit: int = 1024,
                 retain_history: bool = False,
                 max_recoveries: int = 3,
                 recompute_budget: Optional[int] = None,
                 storm_guard: bool = False,
                 pressure: Optional[Any] = None,
                 obs: Optional[obs_mod.Obs] = None):
        if pressure is not None and engine.degraded_container is None:
            raise ValueError("a PressureController needs an engine built "
                             "with degraded_container set")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.engine = engine
        self.on_token = on_token
        self.max_pending = max_pending
        self.history_limit = int(history_limit)
        self.retain_history = bool(retain_history)
        self.max_recoveries = int(max_recoveries)
        self.recompute_budget = recompute_budget
        self.storm_guard = bool(storm_guard)
        self.pressure = pressure
        self.pending: "deque[Request]" = deque()
        self.running: Dict[int, _Running] = {}
        self.free_slots = list(range(engine.max_slots - 1, -1, -1))
        self.finished: Dict[Any, np.ndarray] = {}
        self.results: Dict[Any, RequestResult] = {}
        # Telemetry substrate. Every scheduler owns an Obs (a fresh one
        # unless injected), and points the engine/pool at it: benches and
        # tests run several schedulers over one warm engine and expect
        # per-run counters, so the engine records into whichever scheduler
        # drives it last.
        self.obs = obs if obs is not None else obs_mod.Obs()
        engine.obs = self.obs
        engine.pool.obs = self.obs
        reg = self.obs.registry
        self._c_submitted = reg.counter(
            "serve_submitted_total", "requests accepted by submit()")
        self._c_requests = reg.counter(
            "serve_requests_total", "terminal request outcomes",
            labels=("outcome",))
        self._c_admitted = reg.counter(
            "serve_admitted_total", "admissions by served geometry",
            labels=("geometry",))
        self._c_preempt = reg.counter(
            "serve_preemptions_total", "recompute-preemptions")
        self._c_decode = reg.counter(
            "serve_decode_steps_total", "engine decode steps (burst tokens)")
        self._c_tokens = reg.counter(
            "serve_tokens_total", "tokens emitted to clients")
        self._c_recov = reg.counter(
            "serve_recoveries_total", "recompute-from-prompt recoveries")
        self._c_recomp = reg.counter(
            "serve_recompute_tokens_total",
            "prompt tokens re-prefilled after requeue")
        self._c_allocfail = reg.counter(
            "serve_alloc_failures_total",
            "allocator refusals after a granted admission")
        self._c_corrupt = reg.counter(
            "serve_corrupt_blocks_total", "checksum mismatches detected")
        self._c_nan = reg.counter(
            "serve_nan_guard_trips_total", "non-finite logit guard trips")
        self._c_downshift = reg.counter(
            "serve_downshifted_total",
            "admissions downshifted to the degraded geometry")
        self._c_drafted = reg.counter(
            "serve_drafted_total",
            "speculative draft tokens proposed (prefix-precision reads)")
        self._c_draft_acc = reg.counter(
            "serve_draft_accepted_total",
            "draft tokens the full-width verify pass confirmed")
        self._c_draft_rej = reg.counter(
            "serve_draft_rejected_total",
            "draft tokens rejected at verify (state rolled back)")
        self._c_spec_rounds = reg.counter(
            "serve_spec_rounds_total",
            "speculative draft+verify rounds dispatched")
        self._h_ttft = reg.histogram(
            "serve_ttft_seconds", "submit-to-first-token wall time",
            unit="s")
        self._h_tok = reg.histogram(
            "serve_token_latency_seconds",
            "per-token wall time within a scheduler step", unit="s")
        self._h_step = reg.histogram(
            "serve_step_seconds", "scheduler step wall time", unit="s")
        self.stats = SchedulerStats(reg)
        self._submit_ts: Dict[Any, float] = {}   # uid -> perf_counter at
        #                                          submit (TTFT, first
        #                                          residency only)
        self._queued_spans: Dict[Any, Any] = {}  # uid -> open queued span
        self._step_i = 0
        self._admit_seq = 0
        # Per-uid emission history: survives recompute-preemption
        # (_Running.emitted only tracks the current residency — its length
        # is what the requeued max_new is discounted by). Entries move
        # into `results` at terminal time, so the live dict only ever
        # holds in-flight requests.
        self._history: Dict[Any, List[int]] = {}
        self._recoveries: Dict[Any, int] = {}
        # uid -> [drafted, accepted] speculative bookkeeping; survives
        # requeue like _history, moves into RequestResult at terminal time.
        self._spec_acc: Dict[Any, List[int]] = {}
        self._terminal: "deque[Any]" = deque()  # completion order (LRU)

    # -- queue -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Validate and enqueue. Malformed requests raise here, with the
        field named, instead of failing deep inside prefill; requests the
        pool can *never* hold raise RuntimeError up front."""
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size < 1:
            raise ValueError(f"request {req.uid!r}: prompt must be a "
                             f"non-empty 1-D token array, got shape "
                             f"{prompt.shape}")
        if int(req.max_new) < 1:
            raise ValueError(f"request {req.uid!r}: max_new must be >= 1, "
                             f"got {req.max_new}")
        if req.deadline is not None:
            d = float(req.deadline)
            if not math.isfinite(d) or d <= req.arrival:
                raise ValueError(
                    f"request {req.uid!r}: absurd deadline {req.deadline} "
                    f"(must be finite and after arrival {req.arrival})")
        pool = self.engine.pool
        n0 = int(prompt.size)
        if (n0 >= self.engine.max_len
                or blocks_for(n0 + 1, pool.block_l)
                > min(pool.num_blocks, pool.max_logical)):
            raise RuntimeError(
                f"pool of {pool.num_blocks} blocks / max_len "
                f"{self.engine.max_len} cannot ever admit a request of "
                f"{n0} prompt tokens")
        self.pending.append(req)
        self._c_submitted.inc()
        self._submit_ts.setdefault(req.uid, time.perf_counter())
        tracer = self.obs.tracer
        if tracer is not None:
            lane = str(req.uid)
            tracer.instant("submit", lane, prompt_tokens=n0,
                           max_new=int(req.max_new))
            self._queued_spans[req.uid] = tracer.begin("queued", lane)

    def cancel(self, uid: Any) -> bool:
        """Client cancellation: frees the request's blocks *now* (running)
        or removes it from the queue (pending). Partial output is kept in
        ``results``. Returns False for unknown/already-terminal uids."""
        for st in list(self.running.values()):
            if st.req.uid == uid:
                self._retire(st, "cancelled")
                return True
        for req in self.pending:
            if req.uid == uid:
                self.pending.remove(req)
                self._record(req.uid, "cancelled")
                return True
        return False

    @property
    def idle(self) -> bool:
        return not self.pending and not self.running

    # -- terminal bookkeeping --------------------------------------------

    def _record(self, uid: Any, status: str, narrow: bool = False) -> None:
        toks = np.asarray(self._history.pop(uid, []), np.int32)
        drafted, draft_acc = self._spec_acc.pop(uid, (0, 0))
        res = RequestResult(
            status=status, tokens=toks,
            container=(self.engine.degraded_container if narrow
                       else self.engine.container),
            recoveries=self._recoveries.pop(uid, 0),
            drafted=int(drafted), draft_accepted=int(draft_acc))
        self.results[uid] = res
        # The single terminal-outcome increment: every path that ends a
        # request funnels through here, so summing the outcome series
        # always equals serve_submitted_total once the queue drains.
        self._c_requests.labels(outcome=status).inc()
        self._submit_ts.pop(uid, None)
        tracer = self.obs.tracer
        if tracer is not None:
            q = self._queued_spans.pop(uid, None)
            if q is not None:  # went terminal while still pending
                tracer.end(q, outcome=status)
            tracer.instant("retire", str(uid), outcome=status,
                           tokens=int(toks.size),
                           recoveries=res.recoveries)
        if status == "ok":
            self.finished[uid] = toks
        self._terminal.append(uid)
        if not self.retain_history:
            while len(self._terminal) > self.history_limit:
                old = self._terminal.popleft()
                self.results.pop(old, None)
                self.finished.pop(old, None)

    def _retire(self, st: _Running, status: str,
                quarantine: Tuple[int, ...] = ()) -> None:
        self.engine.pool.free_slot(st.slot, quarantine=quarantine)
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        self._record(st.req.uid, status, narrow=st.narrow)

    # -- internals -------------------------------------------------------

    def _emit(self, st: _Running, tok: int) -> Tuple[Any, int, bool]:
        st.emitted.append(int(tok))
        st.last_tok = int(tok)
        self._history.setdefault(st.req.uid, []).append(int(tok))
        self._c_tokens.inc()
        t0 = self._submit_ts.pop(st.req.uid, None)
        if t0 is not None:  # first token this request ever emitted
            self._h_ttft.observe(time.perf_counter() - t0)
        done = (len(st.emitted) >= st.req.max_new
                or st.n_ctx + 1 >= self.engine.max_len)
        for cb in (st.req.on_token, self.on_token):
            if cb is not None:
                cb(st.req.uid, int(tok), done)
        return (st.req.uid, int(tok), done)

    def _finish(self, st: _Running) -> None:
        self._retire(st, "ok")

    def _requeue(self, st: _Running) -> Request:
        """Fold emitted tokens into the prompt and put the request back at
        the queue front (emitted tokens are never retracted)."""
        req = st.req
        if st.emitted:
            req = dataclasses.replace(
                req, prompt=np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(st.emitted, np.int32)]),
                max_new=req.max_new - len(st.emitted))
        req = dataclasses.replace(req, requeued=True)
        self.pending.appendleft(req)
        tracer = self.obs.tracer
        if tracer is not None:
            self._queued_spans[req.uid] = tracer.begin(
                "queued", str(req.uid), requeued=True)
        return req

    def _preempt(self, st: _Running) -> None:
        """Recompute-preemption: the victim's blocks and slot free now."""
        self.engine.pool.free_slot(st.slot)
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        if self.obs.tracer is not None:
            self.obs.tracer.instant("preempt", str(st.req.uid),
                                    slot=st.slot,
                                    emitted=len(st.emitted))
        self._requeue(st)
        self._c_preempt.inc()

    def _recover(self, st: _Running, quarantine: Tuple[int, ...]) -> None:
        """Recompute-from-prompt recovery after an integrity failure.

        The slot's bad blocks go to quarantine, the rest recycle, and the
        request re-enters the queue with its emitted tokens folded into
        the prompt — exactly the preemption mechanics, so the recovered
        stream is token-identical to a fault-free run. A request that
        keeps failing (``max_recoveries``) is marked ``failed`` instead of
        looping forever on a sticky fault.
        """
        uid = st.req.uid
        n = self._recoveries.get(uid, 0) + 1
        self._recoveries[uid] = n
        self._c_recov.inc()
        if self.obs.tracer is not None:
            self.obs.tracer.instant("recover", str(uid), attempt=n,
                                    quarantined=len(quarantine))
        if n > self.max_recoveries:
            self._retire(st, "failed", quarantine=quarantine)
            return
        self.engine.pool.free_slot(st.slot, quarantine=quarantine)
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        self._requeue(st)

    # -- fault handling (per step, before the device call) ---------------

    def _expire(self, now: Optional[float]) -> None:
        if now is None:
            return
        for st in list(self.running.values()):
            d = st.req.deadline
            if d is not None and now >= d:
                self._retire(st, "expired")
        expired = [r for r in self.pending
                   if r.deadline is not None and now >= r.deadline]
        for req in expired:
            self.pending.remove(req)
            self._record(req.uid, "expired")

    def _shed(self, now: Optional[float]) -> None:
        """Bounded admission queue: arrived requests beyond ``max_pending``
        are explicitly shed, newest-arrival first. Requeued (preempted or
        recovering) requests are never shed — they hold emitted tokens."""
        if self.max_pending is None:
            return
        arrived = sum(1 for r in self.pending
                      if now is None or r.arrival <= now)
        excess = arrived - self.max_pending
        if excess <= 0:
            return
        kept: List[Request] = []
        for req in reversed(self.pending):
            if (excess > 0 and not req.requeued
                    and (now is None or req.arrival <= now)):
                self._record(req.uid, "shed")
                excess -= 1
            else:
                kept.append(req)
        self.pending = deque(reversed(kept))

    def _verify_integrity(self) -> None:
        """Verify every allocated block's checksum before it is gathered;
        quarantine mismatches and recover their owners by recompute."""
        eng = self.engine
        if not eng.integrity or not self.running:
            return
        bad = eng.verify_blocks(eng.pool.owned_ids())
        if not bad:
            return
        self._c_corrupt.inc(len(bad))
        self.obs.event("corrupt_blocks", blocks=[int(p) for p in bad])
        by_slot: Dict[int, List[int]] = {}
        for phys in bad:
            owner = eng.pool.owner_of(phys)
            if owner is not None:
                by_slot.setdefault(owner, []).append(phys)
        for slot, blocks in by_slot.items():
            st = self.running.get(slot)
            if st is not None:
                self._recover(st, tuple(blocks))

    def scrub_quarantined(self) -> int:
        """Scrub (zero + re-checksum) every quarantined block on device and
        return it to the free list; returns how many were rehabilitated."""
        n = 0
        for phys in self.engine.pool.quarantined_blocks:
            self.engine.scrub_block(phys)
            self.engine.pool.rehabilitate(phys)
            n += 1
        if n:
            self.obs.event("scrub", blocks=n)
        return n

    # -- admission -------------------------------------------------------

    def _reserve_blocks(self) -> int:
        """Blocks the running slots still need to finish their (budget-
        bounded) generations. The storm guard holds these back from
        admission: new work can never take blocks a running request will
        need, so admission→preempt thrash cannot start and the oldest
        running request always runs to completion."""
        pool = self.engine.pool
        need = 0
        for st in self.running.values():
            remaining = st.req.max_new - len(st.emitted)
            end = min(st.n_ctx + remaining, self.engine.max_len)
            need += max(0, blocks_for(end, pool.block_l)
                        - pool.slot_blocks(st.slot))
        return need

    def _admit(self, now: Optional[float],
               emitted: List[Tuple[Any, int, bool]]) -> None:
        pool = self.engine.pool
        reserve = self._reserve_blocks() if self.storm_guard else 0
        recompute = 0
        while self.pending and self.free_slots:
            degraded = False
            if self.pressure is not None:
                # Re-evaluated per candidate, not per step: each admission
                # moves the free-byte fraction, and the downshift must
                # engage mid-loop once a flood pushes it under the low
                # watermark (hysteresis in the controller stops chatter).
                ps = pool.stats()
                degraded = self.pressure.update(ps.free_bytes,
                                                ps.capacity_bytes)
            rate = self.engine.degraded_block_bytes if degraded else None
            req = self.pending[0]
            if now is not None and req.arrival > now:
                break  # FIFO: later arrivals queue behind
            n0 = int(np.asarray(req.prompt).size)
            if req.requeued and self.recompute_budget is not None \
                    and recompute + n0 > self.recompute_budget \
                    and recompute > 0:
                break  # this step's re-prefill budget is spent
            if not pool.can_admit(n0, block_bytes=rate,
                                  reserve_blocks=reserve):
                if blocks_for(n0 + 1, pool.block_l) > pool.num_blocks:
                    raise RuntimeError(
                        f"pool of {pool.num_blocks} blocks cannot ever "
                        f"admit a request of {n0} prompt tokens")
                break  # transient: blocks free up as running requests end
            if self.storm_guard:
                # Admit only if the candidate's own worst-case residency
                # also fits beside the reservation — otherwise it is the
                # request that would later thrash against the runners.
                worst = blocks_for(min(n0 + req.max_new,
                                       self.engine.max_len), pool.block_l)
                if worst + reserve > pool.free_blocks:
                    break
            self.pending.popleft()
            slot = self.free_slots.pop()
            if not pool.alloc_upto(slot, n0, block_bytes=rate):
                # can_admit passed but the allocator refused (injected
                # alloc failure, or a race with the byte budget): requeue
                # gracefully instead of crashing the loop.
                self._c_allocfail.inc()
                try:
                    pool.free_slot(slot)  # clears the empty registration
                except KeyError:
                    pass  # injected failure fired before registration
                self.free_slots.append(slot)
                self.pending.appendleft(req)
                break
            if req.requeued:
                recompute += n0
                self._c_recomp.inc(n0)
            tracer = self.obs.tracer
            t_pf = time.perf_counter()
            tok0 = self.engine.prefill_into_slot(slot, req.prompt,
                                                 narrow=degraded)
            self._admit_seq += 1
            st = _Running(req=req, slot=slot, admit_seq=self._admit_seq,
                          n_ctx=n0, last_tok=tok0, narrow=degraded)
            self.running[slot] = st
            geom = (self.engine.degraded_container if degraded
                    else self.engine.container)
            self._c_admitted.labels(geometry=geom).inc()
            if degraded:
                self._c_downshift.inc()
            if tracer is not None:
                lane = str(req.uid)
                q = self._queued_spans.pop(req.uid, None)
                if q is not None:
                    tracer.end(q, requeued=req.requeued)
                tracer.complete(
                    "prefill", lane, time.perf_counter() - t_pf,
                    geometry=geom, blocks=pool.slot_blocks(slot),
                    downshift=bool(degraded), prompt_tokens=n0, slot=slot)
            if self.storm_guard:
                # The new runner's remaining growth joins the reservation
                # before the next candidate is considered.
                reserve += max(0, worst - pool.slot_blocks(slot))
            emitted.append(self._emit(st, tok0))
            if emitted[-1][2]:  # max_new == 1 (or budget exhausted)
                self._finish(st)

    def _ensure_blocks(self, horizon: int = 1) -> None:
        """Every running slot needs blocks covering its next ``horizon``
        positions before the batched step (the whole burst runs against
        one fixed block table); when the pool runs dry the *youngest*
        running request (possibly the requester itself) is preempted —
        oldest-first priority, so head-of-line requests always drain."""
        pool = self.engine.pool
        for slot in sorted(self.running,
                           key=lambda s: self.running[s].admit_seq):
            st = self.running.get(slot)
            if st is None:  # preempted earlier this round
                continue
            while not pool.alloc_upto(slot, st.n_ctx + horizon):
                victim = max(self.running.values(),
                             key=lambda r: r.admit_seq)
                if victim.slot == slot and len(self.running) == 1:
                    raise RuntimeError(
                        f"pool of {pool.num_blocks} blocks cannot hold one "
                        f"request of {st.n_ctx + horizon} tokens")
                self._preempt(victim)
                if victim.slot == slot:
                    break  # requester preempted itself; skip its step

    def _burst_len(self, burst: int) -> int:
        """Clamp the requested burst to what this round can actually use.

        Hard cap: no running slot may step past ``max_len`` (its blocks
        and positions end there). Efficiency cap: once every running slot
        has hit its token budget there is nothing left to emit, so the
        burst never outruns the *largest* remaining budget — slots that
        finish mid-burst keep decoding harmlessly (their extra tokens are
        computed but never replayed), which is what keeps the executable
        shape fixed."""
        cap = min(self.engine.max_len - st.n_ctx
                  for st in self.running.values())
        need = max(st.req.max_new - len(st.emitted)
                   for st in self.running.values())
        return max(1, min(int(burst), cap, need))

    # -- the loop --------------------------------------------------------

    def step(self, now: Optional[float] = None, burst: int = 1,
             speculate: Optional[int] = None,
             draft_planes: Optional[int] = None
             ) -> List[Tuple[Any, int, bool]]:
        """Expire, shed, verify, admit, then advance every running slot by
        up to ``burst`` tokens in one jitted dispatch. Admission, slot
        recycling and preemption happen only at burst boundaries (here,
        before the device call); per-token streaming callbacks are
        replayed in step order from the burst's (K, max_slots) token
        buffer, so a request that hits its budget mid-burst still sees
        ``done`` on exactly its last token. Returns the (uid, token,
        done) tuples emitted this step.

        ``speculate=K`` replaces the burst with one self-speculative
        round (``engine.speculate``): K draft steps at
        ``draft_planes``-bit prefix reads, one batched full-width
        verify, and per-slot acceptance — each slot commits between 1
        and K tokens, greedy-guaranteed identical to ``burst=1`` output.
        Rejected suffixes are rolled back on device; ``n_ctx`` advances
        only by the tokens actually emitted, so pool byte accounting is
        untouched by rejection. Draft precision is engine-wide (the
        executable is specialized on it): degraded (downshifted)
        admissions store narrow-requantized planes whose low mantissa
        bit planes are zero, so a prefix at or above the degraded width
        reads their KV exactly — they effectively draft at their own
        narrower prefix, and verification covers the rest.
        """
        t0 = time.perf_counter()
        emitted = self._step_inner(now, burst, speculate, draft_planes)
        wall = time.perf_counter() - t0
        self._h_step.observe(wall)
        if emitted:
            per = wall / len(emitted)
            for _ in emitted:
                self._h_tok.observe(per)
        if self.obs.timeline is not None:
            self._record_timeline()
        self._step_i += 1
        return emitted

    def _record_timeline(self) -> None:
        """One serve timeline entry: which geometry holds how many blocks
        and bytes right now. Bytes are priced by the same per-slot rates
        the pool charges, so the per-geometry sum byte-agrees with
        ``pool.used_bytes`` by construction."""
        eng = self.engine
        pool = eng.pool
        ps = pool.stats()
        gblocks: Dict[str, int] = {}
        gbytes: Dict[str, int] = {}
        for st in self.running.values():
            name = eng.degraded_container if st.narrow else eng.container
            nb = pool.slot_blocks(st.slot)
            gblocks[name] = gblocks.get(name, 0) + nb
            gbytes[name] = (gbytes.get(name, 0)
                            + nb * pool.slot_rate(st.slot))
        degraded = bool(self.pressure is not None and self.pressure.degraded)
        self.obs.timeline.record_serve(
            self._step_i,
            geometry_blocks=gblocks, geometry_bytes=gbytes,
            used_bytes=ps.used_bytes, free_bytes=ps.free_bytes,
            capacity_bytes=ps.capacity_bytes,
            occupancy=ps.used_blocks / max(1, ps.num_blocks),
            pressure="degraded" if degraded else "normal",
            quarantined=ps.quarantined, running=len(self.running))

    def _step_inner(self, now: Optional[float], burst: int,
                    speculate: Optional[int] = None,
                    draft_planes: Optional[int] = None
                    ) -> List[Tuple[Any, int, bool]]:
        emitted: List[Tuple[Any, int, bool]] = []
        self._expire(now)
        self._shed(now)
        self._verify_integrity()
        self._admit(now, emitted)
        if not self.running:
            return emitted
        if speculate is not None and int(speculate) < 1:
            raise ValueError(f"speculate must be >= 1, got {speculate}")
        K = self._burst_len(burst if speculate is None else speculate)
        try:
            self._ensure_blocks(K)
        except RuntimeError:
            if K == 1:
                raise
            # Pool too tight for the whole burst horizon even after
            # evicting everyone else: degrade to single-step pacing
            # rather than refusing a request burst=1 could serve.
            K = 1
            self._ensure_blocks(K)
        if not self.running:
            return emitted  # everyone preempted back to the queue

        pool = self.engine.pool
        toks = np.zeros(self.engine.max_slots, np.int32)
        pos = np.zeros(self.engine.max_slots, np.int32)
        for st in self.running.values():
            toks[st.slot] = st.last_tok
            pos[st.slot] = st.n_ctx  # the input token's absolute position
        # Snapshot the participating blocks now: _finish/_recover clear
        # table rows during replay, and these blocks' checksums must be
        # re-recorded after the decode wrote fresh KV into them.
        written = [int(p) for st in self.running.values()
                   for p in pool.tables[st.slot] if p != TRASH_BLOCK]
        slot_blocks = {st.slot: tuple(int(p) for p in pool.tables[st.slot]
                                      if p != TRASH_BLOCK)
                       for st in self.running.values()}
        t_dec = time.perf_counter()
        if speculate is None:
            nxt, bad = self.engine.decode_burst(toks, pos, K)
            # Uniform replay: every slot streams all K burst tokens.
            n_emit = np.full(self.engine.max_slots, K, np.int64)
            accepted = None
            self._c_decode.inc(K)
        else:
            nxt, bad, accepted, n_emit = self.engine.speculate(
                toks, pos, K, draft_planes)  # nxt/bad: (K, max_slots)
            self._c_decode.inc(2 * K)  # K draft + K verify model steps
            self._c_spec_rounds.inc()
        dec_wall = time.perf_counter() - t_dec

        live = list(self.running.values())
        tracer = self.obs.tracer
        if speculate is not None:
            # Acceptance bookkeeping happens before replay (terminal
            # replay paths pop _spec_acc into the request's result).
            for st in live:
                acc = int(accepted[st.slot])
                self._c_drafted.inc(K)
                self._c_draft_acc.inc(acc)
                self._c_draft_rej.inc(K - acc)
                pair = self._spec_acc.setdefault(st.req.uid, [0, 0])
                pair[0] += K
                pair[1] += acc
        if tracer is not None:
            # One decode/spec span per participating request per round:
            # the token positions advanced and the geometry served at.
            for st in live:
                geom = (self.engine.degraded_container if st.narrow
                        else self.engine.container)
                if speculate is None:
                    tracer.complete(
                        "decode", str(st.req.uid), dec_wall, burst=K,
                        slot=st.slot, n_ctx=st.n_ctx,
                        blocks=len(slot_blocks[st.slot]), geometry=geom)
                else:
                    tracer.complete(
                        "spec", str(st.req.uid), dec_wall, horizon=K,
                        accepted=int(accepted[st.slot]),
                        emitted=int(n_emit[st.slot]),
                        slot=st.slot, n_ctx=st.n_ctx,
                        blocks=len(slot_blocks[st.slot]), geometry=geom)
        poisoned: Dict[int, _Running] = {}
        for i in range(K):
            for st in live:
                if self.running.get(st.slot) is not st:
                    continue  # finished earlier in this burst
                if st.slot in poisoned:
                    continue  # NaN guard tripped earlier in this burst
                if i >= n_emit[st.slot]:
                    continue  # speculative round: rejected suffix
                if bad[i, st.slot]:
                    # Non-finite logits: this token and everything chained
                    # after it is garbage — stop streaming, recover below.
                    poisoned[st.slot] = st
                    continue
                st.n_ctx += 1
                _, _, done = res = self._emit(st, int(nxt[i, st.slot]))
                emitted.append(res)
                if done:
                    self._finish(st)
        for st in poisoned.values():
            if self.running.get(st.slot) is st:
                self._c_nan.inc()
                self._recover(st, slot_blocks[st.slot])
        self.engine.refresh_checksums(written)
        return emitted

    def run(self, requests=None, now_fn=None, max_steps: int = 100_000,
            burst: int = 1, fault_hook=None,
            speculate: Optional[int] = None,
            draft_planes: Optional[int] = None) -> Dict[Any, np.ndarray]:
        """Drive until every submitted request reaches a terminal state.
        ``now_fn`` feeds the admission clock (trace simulation); None
        admits on submit order only. ``burst`` > 1 decodes K tokens per
        scheduler step (one scan dispatch), touching the host only
        between bursts; ``speculate=K`` instead runs self-speculative
        draft+verify rounds (see ``step``). ``fault_hook(step)`` runs
        before each step — the serving analogue of the train loop's
        chaos hook (the FaultInjector plugs in here). Returns uid ->
        tokens for requests that finished ``ok``; other outcomes are in
        ``results``."""
        if requests:
            for r in requests:
                self.submit(r)
        for step_i in range(max_steps):
            if self.idle:
                return dict(self.finished)
            if fault_hook is not None:
                fault_hook(step_i)
            self.step(now=None if now_fn is None else now_fn(),
                      burst=burst, speculate=speculate,
                      draft_planes=draft_planes)
        raise RuntimeError(f"scheduler did not drain in {max_steps} steps")
