"""Unified telemetry (repro.obs): registry/tracer/timeline units, the
exporter validators, and the accounting-consistency property — across
chaos scenarios the metrics registry, the ``SchedulerStats`` compat
view, and ``Scheduler.results`` must agree (ok + expired + cancelled +
shed + failed == submitted), instrumentation must add zero executables,
and one fully instrumented flood must yield a Perfetto-loadable span
chain with a downshift annotation plus a precision timeline whose
per-geometry bytes sum exactly to the pool's accounting."""
import dataclasses
import json
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, policies
from repro import obs as obs_mod
from repro.configs.base import reduced
from repro.kernels import ops
from repro.models.model import DecoderModel
from repro.obs import validate as validate_mod
from repro.obs.registry import EventLog, MetricsRegistry, log_buckets
from repro.obs.timeline import PrecisionTimeline
from repro.obs.trace import SpanTracer
from repro.optim import adamw
from repro.serve import engine, faults, precision
from repro.serve.scheduler import Request, Scheduler, SchedulerStats
from repro.train import loop as loop_mod
from repro.train.state import TrainState

SCHEMAS = pathlib.Path(__file__).parent / "fixtures" / "obs"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counter_labels_totals_and_errors():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "terminal outcomes", labels=("outcome",))
    c.labels(outcome="ok").inc()
    c.labels(outcome="ok").inc()
    c.labels(outcome="shed").inc()
    assert c.total() == 3
    assert c.total(outcome="ok") == 2 and c.total(outcome="shed") == 1
    with pytest.raises(KeyError):
        c.labels(bad="x")
    with pytest.raises(KeyError):
        c.inc()  # labeled family has no solo series
    with pytest.raises(ValueError):
        c.labels(outcome="ok").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("reqs_total")  # kind mismatch on an existing name
    # get-or-create is idempotent: same family object by name
    assert reg.counter("reqs_total", labels=("outcome",)) is c


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("pool_free_blocks", "free blocks")
    g.set(7)
    g.dec(3)
    g.inc()
    assert g.value == 5


def test_log_buckets_span_and_monotonicity():
    b = log_buckets(1e-5, 100.0, per_decade=4)
    assert len(b) == 29
    assert math.isclose(b[0], 1e-5) and math.isclose(b[-1], 100.0)
    assert all(x < y for x, y in zip(b, b[1:]))


def test_histogram_percentiles_count_and_overflow():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", unit="s")
    for v in [0.0012] * 50 + [0.012] * 45 + [1.2] * 5:
        h.observe(v)
    assert reg.snapshot()["lat_seconds"]["series"][0]["count"] == 100
    # p50 lands in 0.0012's bucket (bounded above by the next log bound)
    assert 0.0012 <= h.percentile(0.50) <= 0.002
    assert 0.012 <= h.percentile(0.95) <= 0.02
    # p99 bucket bound exceeds the observed max, so the max wins
    assert h.percentile(0.99) == 1.2
    h.observe(1e6)  # overflow slot: above every bound
    assert h.percentile(1.0) == 1e6
    # 101 samples: the median is now the 51st value (a 0.012 sample)
    assert 0.012 <= h.percentile(0.5) <= 0.02


def test_prometheus_export_round_trips_the_validator(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve_requests_total", "outcomes",
                labels=("outcome",)).labels(outcome="ok").inc(3)
    reg.gauge("pool_used_blocks", "used").set(2)
    reg.histogram("serve_ttft_seconds", "ttft", unit="s").observe(0.05)
    reg.histogram("serve_token_latency_seconds", "tok",
                  unit="s").observe(0.002)
    text = reg.to_prometheus()
    assert "# TYPE serve_requests_total counter" in text
    assert 'serve_requests_total{outcome="ok"} 3' in text
    assert '_bucket{le="+Inf"} 1' in text
    p = tmp_path / "metrics.prom"
    p.write_text(text)
    assert validate_mod.validate_prometheus(
        str(p), require=("serve_ttft_seconds",
                         "serve_token_latency_seconds")) == []
    # a histogram that was never registered is a hard failure
    errs = validate_mod.validate_prometheus(str(p),
                                            require=("serve_step_seconds",))
    assert errs and "missing histogram" in errs[0]


def test_event_log_streams_jsonl(tmp_path):
    p = tmp_path / "events.jsonl"
    log = EventLog(str(p))
    log.emit("step_failure", step=7, restart=1)
    log.write({"step": 7, "loss": 1.5})  # verbatim metric-line mode
    log.close()
    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert lines[0]["event"] == "step_failure" and "ts" in lines[0]
    assert lines[1] == {"step": 7, "loss": 1.5}  # byte-stable: no stamps
    assert validate_mod.validate_jsonl(
        str(p), json.loads((SCHEMAS / "events.schema.json").read_text())) \
        == []


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


def test_tracer_spans_lanes_and_export():
    tr = SpanTracer()
    span = tr.begin("queued", "7", requeued=False)
    tr.end(span, outcome="ok")
    tr.complete("decode", "7", 0.004, burst=2)
    tr.instant("retire", "7", outcome="ok")
    tr.instant("submit", "8")
    out = tr.export()
    assert set(out) == {"traceEvents", "displayTimeUnit"}
    # one thread_name metadata event per lane
    meta = [e for e in out["traceEvents"] if e["ph"] == "M"]
    assert [m["args"]["name"] for m in meta] == ["7", "8"]
    assert tr.lanes() == ["7", "8"]
    qs = tr.spans(lane="7", name="queued")
    assert len(qs) == 1 and qs[0]["dur"] >= 0
    assert qs[0]["args"] == {"requeued": False, "outcome": "ok"}
    dec = tr.spans(name="decode")[0]
    assert math.isclose(dec["dur"], 4000.0)  # 0.004 s in us
    assert tr.spans(lane="8") and tr.spans(lane="8")[0]["ph"] == "i"


def test_tracer_output_passes_trace_schema(tmp_path):
    tr = SpanTracer()
    tr.complete("prefill", "0", 0.001, geometry="sfp8", downshift=False)
    p = tmp_path / "trace.json"
    tr.write(str(p))
    schema = json.loads((SCHEMAS / "trace.schema.json").read_text())
    assert validate_mod.validate(json.loads(p.read_text()), schema) == []


def test_trace_chain_checker_requires_full_chain():
    tr = SpanTracer()
    tr.instant("submit", "0")
    tr.complete("queued", "0", 0.001)
    tr.complete("prefill", "0", 0.001, downshift=False)
    # no decode span, no retire yet: chain incomplete
    assert validate_mod.check_trace_chain(tr.export())
    tr.complete("decode", "0", 0.001)
    tr.instant("retire", "0", outcome="ok")
    assert validate_mod.check_trace_chain(tr.export()) == []
    # downshift demanded but never annotated
    assert validate_mod.check_trace_chain(tr.export(),
                                          require_downshift=True)


# ---------------------------------------------------------------------------
# precision timeline
# ---------------------------------------------------------------------------


def test_timeline_round_trips_schema_and_accounting(tmp_path):
    p = tmp_path / "timeline.jsonl"
    tl = PrecisionTimeline(str(p))
    tl.record_train(40, [(3, 5), (7, 8)])
    tl.record_serve(12, geometry_blocks={"sfp-m3e5": 6},
                    geometry_bytes={"sfp-m3e5": 98304}, used_bytes=98304,
                    free_bytes=32768, capacity_bytes=131072,
                    occupancy=0.75, pressure="degraded", quarantined=0,
                    running=2)
    tl.close()
    schema = json.loads((SCHEMAS / "timeline.schema.json").read_text())
    assert validate_mod.validate_jsonl(str(p), schema) == []
    assert validate_mod.check_timeline_accounting(str(p)) == []
    train, serve = [json.loads(x) for x in p.read_text().splitlines()]
    assert train["layers"][1] == {"layer": 1, "man_bits": 7, "exp_bits": 8}
    assert serve["pressure"] == "degraded"
    # seeded disagreement: bytes that do not sum to used_bytes must fail
    bad = tmp_path / "bad.jsonl"
    serve["geometry_bytes"] = {"sfp-m3e5": 1}
    bad.write_text(json.dumps(serve) + "\n")
    errs = validate_mod.check_timeline_accounting(str(bad))
    assert errs and "used_bytes" in errs[0]


def test_validate_cli_exit_codes(tmp_path):
    good = tmp_path / "tl.jsonl"
    PrecisionTimeline(str(good)).record_train(0, [(3, 5)])
    assert validate_mod.main(["--timeline", str(good),
                              "--schemas-dir", str(SCHEMAS)]) == 0
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "train", "step": -1, "layers": []}\n')
    assert validate_mod.main(["--timeline", str(bad),
                              "--schemas-dir", str(SCHEMAS)]) == 1


# ---------------------------------------------------------------------------
# scheduler accounting consistency across chaos scenarios
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving():
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    model = DecoderModel(cfg, kv_container="sfp8")
    params = model.init(jax.random.PRNGKey(0))
    ops.force_backend("ref")
    yield cfg, model, params
    ops.force_backend(None)


def _reqs(cfg, sizes, news, seed=0, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab, size=s).astype(np.int32),
                    max_new=n, **kw)
            for i, (s, n) in enumerate(zip(sizes, news))]


def _run_scenario(name, cfg, model, params):
    eng = engine.PagedEngine(model, params, max_slots=2, max_len=128,
                             num_blocks=4)
    if name == "clean_burst":
        sched = Scheduler(eng)
        sched.run(_reqs(cfg, [4, 4, 4], [3, 3, 3]), burst=2)
    elif name == "shed":
        sched = Scheduler(eng, max_pending=2)
        sched.run(_reqs(cfg, [4] * 6, [3] * 6))
    elif name == "expire":
        sched = Scheduler(eng)
        reqs = _reqs(cfg, [4, 4], [50, 2])
        reqs[0] = dataclasses.replace(reqs[0], deadline=4.0)
        clock = {"t": 0.0}

        def now():
            clock["t"] += 1.0
            return clock["t"]

        sched.run(reqs, now_fn=now)
    elif name == "cancel":
        sched = Scheduler(eng)
        for r in _reqs(cfg, [4, 4], [10, 10]):
            sched.submit(r)
        sched.step()
        assert sched.cancel(0) and sched.cancel(1)
        sched.run()
    elif name == "bitflip_recovery":
        inj = faults.FaultInjector(eng, seed=3)

        def hook(step):
            if step == 2:
                inj.flip_random_bit(step)

        sched = Scheduler(eng)
        sched.run(_reqs(cfg, [6, 9], [6, 6]), fault_hook=hook)
    else:  # pragma: no cover
        raise AssertionError(name)
    return eng, sched


@pytest.mark.parametrize("scenario", ["clean_burst", "shed", "expire",
                                      "cancel", "bitflip_recovery"])
def test_accounting_identity_across_chaos(serving, scenario):
    """The property: registry counters, the SchedulerStats view, and the
    per-request terminal records are three readings of one ledger."""
    cfg, model, params = serving
    eng, sched = _run_scenario(scenario, cfg, model, params)
    assert sched.idle
    reg = sched.obs.registry
    s = sched.stats
    submitted = int(reg.counter("serve_submitted_total").value)
    outcomes = reg.counter("serve_requests_total", labels=("outcome",))
    # the identity: every submitted request reached exactly one outcome
    assert int(outcomes.total()) == submitted == s.submitted > 0
    assert (s.finished + s.deadline_misses + s.cancelled + s.shed
            + s.failed) == submitted
    # view == registry == results, per outcome
    by_status = {}
    for r in sched.results.values():
        by_status[r.status] = by_status.get(r.status, 0) + 1
    for attr, outcome in SchedulerStats._OUTCOMES.items():
        assert getattr(s, attr) == int(outcomes.total(outcome=outcome)) \
            == by_status.get(outcome, 0), (scenario, attr)
    # emitted tokens reconcile with the terminal records' token arrays
    assert s.emitted_tokens == sum(len(r.tokens)
                                   for r in sched.results.values())
    # instrumentation adds no executables: one decode-step trace, ever
    n = getattr(eng._step, "_cache_size", lambda: None)()
    assert n in (None, 0, 1)
    eng.pool.verify_invariants()


def test_stats_view_rejects_unknown_attr(serving):
    _, model, params = serving
    eng = engine.PagedEngine(model, params, max_slots=1, max_len=128)
    sched = Scheduler(eng)
    with pytest.raises(AttributeError):
        sched.stats.bogus_counter
    d = sched.stats.as_dict()
    assert d["submitted"] == 0 and "admitted" in d


def test_fresh_scheduler_gets_fresh_counters(serving):
    """Benches run several schedulers over one warm engine; per-run stats
    must not bleed across runs through the shared engine/pool."""
    cfg, model, params = serving
    eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
    a = Scheduler(eng)
    a.run(_reqs(cfg, [4, 4], [2, 2]))
    assert a.stats.finished == 2
    b = Scheduler(eng)
    assert b.stats.submitted == b.stats.finished == 0
    assert eng.obs is b.obs and eng.pool.obs is b.obs


# ---------------------------------------------------------------------------
# end-to-end: instrumented flood (chain + downshift + byte-agreement)
# ---------------------------------------------------------------------------


def test_instrumented_flood_end_to_end(tmp_path):
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    model = DecoderModel(cfg, kv_container="sfp-m3e5")
    params = model.init(jax.random.PRNGKey(0))
    paths = {k: tmp_path / v for k, v in
             [("metrics", "metrics.prom"), ("events", "events.jsonl"),
              ("trace", "trace.json"), ("timeline", "timeline.jsonl")]}
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=8, max_len=256,
                                 num_blocks=4,
                                 degraded_container="sfp-m1e2")
        obs = obs_mod.Obs(metrics_path=str(paths["metrics"]),
                          events_path=str(paths["events"]),
                          trace_path=str(paths["trace"]),
                          timeline_path=str(paths["timeline"]))
        sched = Scheduler(eng, obs=obs,
                          pressure=precision.PressureController(low=0.6,
                                                                high=0.85))
        out = sched.run(_reqs(cfg, [100] * 8, [10] * 8))
    finally:
        ops.force_backend(None)
    obs.close()
    s = sched.stats
    assert s.finished == 8 and s.downshifted >= 1
    assert all(len(out[u]) == 10 for u in range(8))
    # TTFT: observed once per request, on its first-ever token
    ttft = obs.registry.histogram("serve_ttft_seconds")
    assert ttft._solo().count == 8 and ttft.percentile(0.5) > 0
    # every timeline entry byte-agrees with the pool, in-stream
    assert validate_mod.check_timeline_accounting(
        str(paths["timeline"])) == []
    serve_entries = [json.loads(x) for x in
                     paths["timeline"].read_text().splitlines()]
    assert any(e["pressure"] == "degraded" for e in serve_entries)
    assert any(len(e["geometry_bytes"]) == 2 for e in serve_entries), \
        "mixed-geometry residency never captured"
    # the full CLI gate, exactly as the CI smoke invokes it
    rc = validate_mod.main([
        "--metrics", str(paths["metrics"]), "--trace", str(paths["trace"]),
        "--timeline", str(paths["timeline"]),
        "--events", str(paths["events"]),
        "--schemas-dir", str(SCHEMAS),
        "--require-chain", "--require-downshift"])
    assert rc == 0
    # and the burst/step executables stayed singular under full telemetry
    n = getattr(eng._step, "_cache_size", lambda: None)()
    assert n in (None, 0, 1)


# ---------------------------------------------------------------------------
# train loop: structured failure events share the metrics stream
# ---------------------------------------------------------------------------

_DIMS = policies.ScopeDims(n_periods=1, n_rem=0, man_bits=7, exp_bits=8)


def _mini_state():
    params = {"w": jnp.zeros((4,))}
    return TrainState(
        params=params, opt=adamw.init(params),
        pstate=policies.get("qm").init_state(_DIMS),
        step=jnp.zeros((), jnp.int32), rng=jax.random.PRNGKey(0),
        grad_residual=None)


def _mini_step(state, batch):
    new = state._replace(
        params={"w": state.params["w"] + batch["x"].mean()},
        step=state.step + 1)
    return new, {"loss": jnp.sum(new.params["w"])}


def _mini_batches(start):
    def gen():
        i = start
        while True:
            yield {"x": jnp.full((2,), float(i + 1))}
            i += 1
    return gen()


def test_loop_failure_is_a_structured_event_not_just_a_print(tmp_path):
    metrics = tmp_path / "metrics.jsonl"
    obs = obs_mod.Obs(events_path=str(tmp_path / "events.jsonl"))
    cfg = loop_mod.LoopConfig(total_steps=10, ckpt_every=2,
                              ckpt_dir=str(tmp_path / "ck"),
                              metrics_file=str(metrics), log_every=1,
                              obs=obs)
    fired = {"done": False}

    def fault(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("simulated node failure")

    res = loop_mod.run(_mini_step, _mini_state(), _mini_batches, cfg,
                       fault_hook=fault)
    assert res.restarts == 1 and int(res.state.step) == 10
    lines = [json.loads(x) for x in metrics.read_text().splitlines()]
    fails = [x for x in lines if x.get("event") == "step_failure"]
    assert len(fails) == 1
    f = fails[0]
    assert f["step"] == 7 and f["restart"] == 1
    assert f["error"] == "RuntimeError" and f["restore_step"] <= 7
    # the same event reaches the obs event stream for exporters
    assert any(e.get("event") == "step_failure"
               for e in obs.events.entries)
    assert int(obs.registry.counter("train_step_failures_total").value) == 1
    # metric lines and lifecycle events interleave in one valid stream
    schema = json.loads((SCHEMAS / "events.schema.json").read_text())
    assert validate_mod.validate_jsonl(str(metrics), schema) == []
    assert any("loss" in x and "event" not in x for x in lines)
    assert any(x.get("event") == "checkpoint" for x in lines)
    # the step-time histogram saw every executed step (incl. replays)
    h = obs.registry.histogram("train_step_seconds")
    assert h._solo().count >= 10
