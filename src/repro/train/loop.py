"""Fault-tolerant host training loop.

Responsibilities:
  * periodic async checkpoints (atomic; rollback-safe);
  * automatic restore-and-continue after a step failure (simulated node
    failure in tests): the loop re-places the last good checkpoint and
    replays the data stream from that step (deterministic corpus);
  * straggler watchdog: per-step wall-time deadline; breaches are logged
    and surfaced in metrics (on a real fleet this triggers hot-spare
    swap-in — see DESIGN.md §4);
  * telemetry: the per-step metrics stream is a ``repro.obs.EventLog``
    (JSONL; per-step metric lines keep their historical format, and
    lifecycle events — step failures, restores, checkpoints — are
    structured ``{"event": ...}`` lines in the same stream, so failed
    steps are no longer print-only); an optional ``LoopConfig.obs``
    records the step-time histogram, restart counters, and — with
    ``timeline_fn`` — the live per-layer precision timeline; and
    ``profile_steps`` brackets ``jax.profiler`` around chosen steps.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.obs import EventLog, Obs


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    metrics_file: Optional[str] = None
    step_deadline_s: Optional[float] = None  # straggler watchdog
    max_restarts: int = 3
    # JSON-able run metadata recorded in every checkpoint manifest (e.g.
    # the precision-policy name, so restores can sanity-check the state
    # tree they are about to fill). May be a callable(state) -> dict so
    # per-save dynamic metadata — the policy's *current* PrecisionDecision
    # summary, which policy-aware serving reads back — is stamped too.
    ckpt_extra: Optional[Any] = None
    # False -> append to an existing metrics file instead of truncating
    # it; segmented drivers (the per-layer-stash refresh loop) set this on
    # every segment after the first so one JSONL spans the whole run.
    metrics_truncate: bool = True
    # Telemetry (repro.obs). ``obs`` carries the registry (step-time
    # histogram, failure/straggler counters) and, when its timeline is
    # enabled, ``timeline_fn(state)`` -> [(man_bits, exp_bits), ...] is
    # sampled every ``timeline_every`` steps into the precision timeline.
    obs: Optional[Obs] = None
    timeline_fn: Optional[Callable[[Any], Any]] = None
    timeline_every: int = 10
    # (start, n): bracket ``jax.profiler`` around steps [start, start+n)
    # — the same capture idiom bench_decode_micro uses, so the profile
    # opens in Perfetto next to the serve span trace.
    profile_steps: Optional[Tuple[int, int]] = None
    profile_dir: str = "experiments/traces/train"


def _scalarize(v):
    """Metrics may be scalars or small arrays (per-scope bitlength
    trajectories); both must survive the JSONL sink."""
    a = np.asarray(v)
    return a.tolist() if a.ndim else float(a)


def _resolve_extra(extra, state):
    return extra(state) if callable(extra) else extra


@dataclasses.dataclass
class LoopResult:
    state: Any
    history: list
    restarts: int
    straggler_steps: int


class _Profiler:
    """Bracket ``jax.profiler`` around steps [start, start+n)."""

    def __init__(self, cfg: LoopConfig):
        self.span = cfg.profile_steps
        self.dir = cfg.profile_dir
        self.active = False

    def tick(self, step: int) -> None:
        if self.span is None:
            return
        start, n = self.span
        if not self.active and start <= step < start + n:
            Path(self.dir).mkdir(parents=True, exist_ok=True)
            jax.profiler.start_trace(self.dir)
            self.active = True
        elif self.active and step >= start + n:
            self.stop()

    def stop(self) -> None:
        if self.active:
            jax.profiler.stop_trace()
            self.active = False


def run(train_step: Callable, state: Any, batch_iter_factory:
        Callable[[int], Iterator[Dict[str, Any]]], cfg: LoopConfig,
        fault_hook: Optional[Callable[[int], None]] = None) -> LoopResult:
    """Run the loop. ``batch_iter_factory(start_step)`` must restart the
    stream at an arbitrary step (deterministic data). ``fault_hook`` lets
    tests inject failures at chosen steps."""
    mgr = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
           if cfg.ckpt_dir else None)
    history = []
    restarts = 0
    stragglers = 0
    sink = None
    if cfg.metrics_file:
        Path(cfg.metrics_file).parent.mkdir(parents=True, exist_ok=True)
        sink = EventLog(cfg.metrics_file, truncate=cfg.metrics_truncate)
    obs = cfg.obs
    h_step = c_fail = c_straggle = None
    if obs is not None:
        h_step = obs.registry.histogram(
            "train_step_seconds", "train step wall time", unit="s")
        c_fail = obs.registry.counter(
            "train_step_failures_total", "step failures restored from "
            "checkpoint")
        c_straggle = obs.registry.counter(
            "train_straggler_steps_total", "steps past the wall-time "
            "deadline")
    prof = _Profiler(cfg)

    def tick_timeline(step: int, force: bool = False) -> None:
        if (obs is None or obs.timeline is None
                or cfg.timeline_fn is None):
            return
        if force or step % max(1, cfg.timeline_every) == 0:
            obs.timeline.record_train(step, cfg.timeline_fn(state))

    step = int(np.asarray(state.step))
    if mgr is not None and mgr.latest_step() is not None:
        latest = mgr.latest_step()
        state = mgr.restore(latest, state)
        step = int(np.asarray(state.step))

    try:
        while step < cfg.total_steps:
            batches = batch_iter_factory(step)
            try:
                for batch in batches:
                    if step >= cfg.total_steps:
                        break
                    if fault_hook is not None:
                        fault_hook(step)
                    prof.tick(step)
                    t0 = time.time()
                    state, metrics = train_step(state, batch)
                    metrics = {k: _scalarize(v) for k, v in metrics.items()}
                    dt = time.time() - t0
                    metrics["step"] = step
                    metrics["step_time_s"] = dt
                    if h_step is not None:
                        h_step.observe(dt)
                    if obs is not None and obs.tracer is not None:
                        obs.tracer.complete("train_step", "train", dt,
                                            step=step)
                    if cfg.step_deadline_s and dt > cfg.step_deadline_s:
                        stragglers += 1
                        metrics["straggler"] = True
                        if c_straggle is not None:
                            c_straggle.inc()
                    history.append(metrics)
                    if sink and (step % cfg.log_every == 0
                                 or step == cfg.total_steps - 1):
                        sink.write(metrics)
                    tick_timeline(step)
                    step += 1
                    if mgr is not None and step % cfg.ckpt_every == 0:
                        mgr.save(step, state, blocking=False,
                                 extra=_resolve_extra(cfg.ckpt_extra,
                                                      state))
                        if sink:
                            sink.emit("checkpoint", step=step)
            except KeyboardInterrupt:
                raise
            except Exception as e:
                restarts += 1
                if c_fail is not None:
                    c_fail.inc()
                if mgr is None or restarts > cfg.max_restarts:
                    raise
                mgr.wait()
                latest = mgr.latest_step()
                if latest is None:
                    raise RuntimeError(
                        "step failed before first checkpoint") from e
                # Structured twin of the console message: downstream
                # tooling reads failures from the JSONL stream, not
                # stdout.
                for dst in (sink, None if obs is None else obs.events):
                    if dst is not None:
                        dst.emit("step_failure", step=step,
                                 error=type(e).__name__, message=str(e),
                                 restore_step=int(latest),
                                 restart=restarts)
                print(f"[loop] step {step} failed "
                      f"({type(e).__name__}: {e}); "
                      f"restoring step {latest} (restart {restarts})")
                state = mgr.restore(latest, state)
                step = int(np.asarray(state.step))
                continue
    finally:
        prof.stop()
        tick_timeline(step, force=True)
        if sink:
            sink.close()

    if mgr is not None:
        mgr.save(step, state, blocking=True,
                 extra=_resolve_extra(cfg.ckpt_extra, state))
    return LoopResult(state=state, history=history, restarts=restarts,
                      straggler_steps=stragglers)
