"""Codec-compressed KV cache (beyond-paper application of the containers).

Decode is memory-bandwidth-bound by the KV cache read — exactly the regime
the paper targets at the DRAM interface. The cache stores the packed
representation of whichever registry codec the caller picks (default: the
paper's sfp8 container — 1 sign + 4 delta-exp + 3 mantissa per value, one
shared base exponent per 128 lanes); each decode step packs only the new
token's K/V row. Cache bytes drop ~2x vs bf16 at <= 3 mantissa bits of
precision, matching where Quantum Mantissa lands (paper Fig 4).

Decompression lives at the consumer: for SFP codecs on the pallas or
interpret backends, attention reads the packed (payload, bases) pair
directly through the fused decompress-attend kernel
(kernels/packed_flash_decode.py) — the bf16 cache never materializes in
HBM, so the byte win is also an HBM-traffic win per step. Codecs without a
fixed-width payload geometry (bit_exact, gecko8) and the ref backend fall
back to decompressing the whole cache and attending over it.

All container specifics live behind repro.codecs: this module only splices
packed parts along the sequence axis, so any codec whose parts carry
(batch, seq, ...) leading dims — every fixed-width registry codec — works
unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.configs.base import ArchConfig, LOCAL
from repro.distributed import sharding as shd
from repro.kernels import ops
from repro.models import attention


class PackedKV(NamedTuple):
    k: codecs.PackedTensor  # parts shaped (B, L, ...), D = KH * head_dim
    v: codecs.PackedTensor


def cache_len(cfg: ArchConfig, kind: str, max_len: int) -> int:
    """Packed-cache sequence allocation for a logical budget ``max_len``.

    Lengths past one kernel block round up to a block multiple so the
    fused flash-decode grid always gets full blocks (its no-pad blocking
    shrinks to a divisor of L otherwise — pathological for awkward L).
    Extra slots are dead weight only: masked out when unwritten (global)
    or ring slack beyond the window (local; the modulus is the allocated
    length everywhere, so splice and validity stay consistent).
    """
    L = min(max_len, cfg.window) if kind == LOCAL else max_len
    block = ops.DECODE_BLOCK_L
    if L > block:
        L = -(-L // block) * block
    return L


def _dims(cfg: ArchConfig, kind: str, max_len: int):
    D = cfg.n_kv_heads * cfg.head_dim_
    assert D % 128 == 0, (D, "KV feature dim must align to 128 lanes")
    return D, cache_len(cfg, kind, max_len)


def _codec(container: Optional[str]) -> codecs.Codec:
    return codecs.get(container or codecs.DEFAULT_CONTAINER)


def packed_cache_spec(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: Optional[str] = None) -> PackedKV:
    D, L = _dims(cfg, kind, max_len)
    spec = _codec(container).packed_spec((batch, L, D), cfg.compute_dtype)
    return PackedKV(k=spec, v=spec)


def packed_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: Optional[str] = None) -> PackedKV:
    spec = packed_cache_spec(cfg, kind, batch, max_len, container)
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    return zeros


def packed_cache_axes(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      container: Optional[str] = None) -> PackedKV:
    """Logical sharding axes: every packed part is (batch, seq, ...)."""
    spec = packed_cache_spec(cfg, kind, batch, max_len, container)
    return jax.tree.map(
        lambda s: ("batch", "cache_seq") + (None,) * (len(s.shape) - 2), spec)


def _splice(cache_pt: codecs.PackedTensor, new_pt: codecs.PackedTensor,
            slot) -> codecs.PackedTensor:
    """Write one packed token row into the ring buffer (every part shares
    the sequence axis at dim 1).

    ``slot`` is a scalar (whole batch writes one slot) or (B,) — one slot
    per batch row (continuous-batching decode, rows at distinct
    positions).
    """
    if jnp.ndim(slot) == 0:
        data = {
            k: jax.lax.dynamic_update_slice_in_dim(
                cache_pt.data[k], new_pt.data[k], slot, axis=1)
            for k in cache_pt.data
        }
    else:
        rows = jnp.arange(slot.shape[0])
        data = {k: cache_pt.data[k].at[rows, slot].set(new_pt.data[k][:, 0])
                for k in cache_pt.data}
    return codecs.PackedTensor(cache_pt.codec, cache_pt.shape,
                               cache_pt.dtype, data)


def attention_decode_packed(params, h_tok: jax.Array, cache: PackedKV,
                            pos: jax.Array, cfg: ArchConfig, *, kind: str,
                            container: Optional[str] = None,
                            prefix_planes: Optional[int] = None
                            ) -> Tuple[jax.Array, PackedKV]:
    """One-token decode over the compressed cache.

    Fusion applies when the codec exposes a fixed-width payload geometry
    (``pack_fields`` — the SFP containers) and the backend runs Pallas
    kernels (pallas on TPU, interpret in tests): attention then consumes
    the packed (payload, bases) pair directly and the decompressed cache
    never exists in HBM. Otherwise — bit_exact/gecko8, or the ref
    backend — the whole cache is decompressed first and attended with
    ``decode_attend`` (both paths share the ring-slot semantics of
    ``ops.decode_kv_mask``).

    ``prefix_planes`` (speculative draft steps) makes the attention *read*
    expand only the leading P' payload bits of the packed cache
    (``ops.prefix_fields``); the write path is unchanged — drafts append
    full-width rows, so the cache bytes a later verify reads are identical.
    Requires a fixed-width geometry (``pack_fields``).
    """
    codec = _codec(container)
    B = h_tok.shape[0]
    hd, H, KH = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    D = KH * hd
    L = cache.k.shape[1]
    dtype = h_tok.dtype

    # pos: scalar (shared decode position) or (B,) per-row positions
    # (continuous-batching slots).
    positions = (jnp.full((1,), pos, jnp.int32) if jnp.ndim(pos) == 0
                 else jnp.asarray(pos, jnp.int32)[:, None])
    q, k_new, v_new = attention._project_qkv(params, h_tok, cfg, positions)
    # As in attention_decode: the new token's K/V must arrive replicated
    # over `model` (the packed cache shards its L dim there), or GSPMD
    # reshards the whole ring buffer on every splice.
    if shd.active_mesh() is not None:
        b = shd.batch_axis_for(shd.active_mesh(), B)
        k_new = shd.hint(k_new, b, None, None, None)
        v_new = shd.hint(v_new, b, None, None, None)
        q = shd.hint(q, b, None, None, None)
    slot = attention.decode_slot_index(pos, L, kind)

    # Pack only the new token's row and splice it in.
    k_pt = _splice(cache.k, codec.pack(k_new.reshape(B, 1, D).astype(dtype)),
                   slot)
    v_pt = _splice(cache.v, codec.pack(v_new.reshape(B, 1, D).astype(dtype)),
                   slot)

    fields = codec.pack_fields(dtype)
    if prefix_planes is not None and fields is None:
        raise ValueError(f"prefix_planes needs a fixed-width payload "
                         f"geometry; codec {codec.name!r} has none")
    if fields is not None and (prefix_planes is not None
                               or ops.backend() in ("pallas", "interpret")):
        # Fused decompress-attend: the packed pair is the attention input.
        # Draft (prefix) reads take this path on every backend — the ref
        # oracle implements the same truncated-geometry expansion.
        window = cfg.window if kind == LOCAL else None
        o = ops.packed_flash_decode(
            q.astype(dtype),
            ops.Packed(payload=k_pt.data["payload"],
                       bases=k_pt.data["bases"]),
            ops.Packed(payload=v_pt.data["payload"],
                       bases=v_pt.data["bases"]),
            pos, fields=fields, window=window, softcap=cfg.attn_softcap,
            prefix_planes=prefix_planes)
    else:
        # Fallback: decompress the whole cache, then attend over it.
        k_c = codec.unpack(k_pt).reshape(B, L, KH, hd)
        v_c = codec.unpack(v_pt).reshape(B, L, KH, hd)
        o = attention.decode_attend(q, k_c, v_c, pos, cfg, kind)
    out = o.reshape(B, 1, H * hd) @ params["wo"]
    return out, PackedKV(k=k_pt, v=v_pt)


def pack_prefill_cache(cache_kv: attention.KVCache,
                       container: Optional[str] = None) -> PackedKV:
    """Compress a prefill-produced bf16 cache in one shot."""
    codec = _codec(container)
    B, L, KH, hd = cache_kv.k.shape
    return PackedKV(k=codec.pack(cache_kv.k.reshape(B, L, KH * hd)),
                    v=codec.pack(cache_kv.v.reshape(B, L, KH * hd)))


# ---------------------------------------------------------------------------
# Paged pool attention (continuous-batching serving engine)
# ---------------------------------------------------------------------------


class PagedKV(NamedTuple):
    """One global-attention layer's slice of the packed block pool.

    Physical blocks shared by every request: payload
    (P_blocks, block_l, fields.nd_payload_cols(D)) — 8/16-bit words, or
    uint8 bit planes for dense sub-byte geometries — and bases
    (P_blocks, block_l, D // 128) uint8 in the ``sfp_pack_nd`` /
    ``bitplane_pack_nd`` layout. Which blocks belong to which request
    lives outside, in the engine's block tables — the pool itself is
    request-agnostic, which is what lets freed blocks recycle instantly.
    """

    k_payload: jax.Array
    k_bases: jax.Array
    v_payload: jax.Array
    v_bases: jax.Array


def _paged_fields(cfg: ArchConfig, container: Optional[str]):
    codec = _codec(container)
    fields = codec.pack_fields(cfg.compute_dtype)
    if fields is None:
        raise ValueError(
            f"paged KV pools need a fixed-width payload geometry; codec "
            f"{codec.name!r} has none (pack_fields() is None)")
    return fields


def paged_block_bytes(cfg: ArchConfig, block_l: int,
                      container: Optional[str] = None) -> int:
    """Dense-packed bytes one physical block occupies for *one* layer:
    K + V payload (words or bit planes) plus the shared group bases.
    This is the unit the pool's admission accounting is measured in."""
    fields = _paged_fields(cfg, container)
    D = cfg.n_kv_heads * cfg.head_dim_
    row = (fields.nd_payload_cols(D)
           * jnp.dtype(fields.payload_dtype).itemsize + D // 128)
    return 2 * block_l * row


def paged_block_spec(cfg: ArchConfig, num_blocks: int, block_l: int,
                     container: Optional[str] = None) -> PagedKV:
    """ShapeDtypeStruct skeleton of one layer's pool slice."""
    D = cfg.n_kv_heads * cfg.head_dim_
    assert D % 128 == 0, (D, "KV feature dim must align to 128 lanes")
    fields = _paged_fields(cfg, container)
    pd = jnp.dtype(fields.payload_dtype)
    payload = jax.ShapeDtypeStruct(
        (num_blocks, block_l, fields.nd_payload_cols(D)), pd)
    bases = jax.ShapeDtypeStruct((num_blocks, block_l, D // 128), jnp.uint8)
    return PagedKV(k_payload=payload, k_bases=bases,
                   v_payload=payload, v_bases=bases)


def paged_block_init(cfg: ArchConfig, num_blocks: int, block_l: int,
                     container: Optional[str] = None) -> PagedKV:
    spec = paged_block_spec(cfg, num_blocks, block_l, container)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def paged_block_checksums(paged: PagedKV, salt: int = 0) -> jax.Array:
    """Cheap per-physical-block integrity checksum over the packed planes.

    Returns (P,) uint32 — one checksum per physical block, covering K and
    V payload (words or bit planes) and the shared group bases. The hash
    is a position-weighted wraparound sum: each flattened element is
    multiplied by an odd per-position constant (Knuth multiplicative
    step), so any single bit flip changes the block's sum (odd weight
    times a power of two is never 0 mod 2^32), and swapped rows/columns
    do not cancel. ``salt`` decorrelates the K/V/payload/bases streams
    and the per-layer contributions summed by the engine.

    Arrays may carry a leading layer dim ((n_periods, P, ...)): layer
    contributions fold into the same per-block sum. This is the
    "computed at pack/insert, verified on gather" primitive behind the
    serving engine's block quarantine (see serve/faults.py).
    """

    def one(arr: jax.Array, s: int) -> jax.Array:
        a = arr.astype(jnp.uint32)
        if a.ndim == 4:                      # (layers, P, block_l, cols)
            a = jnp.moveaxis(a, 1, 0)
        a = a.reshape(a.shape[0], -1)        # (P, flat)
        n = a.shape[1]
        w = (jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)
             + jnp.uint32(s & 0xFFFFFFFF)) | jnp.uint32(1)
        return jnp.sum(a * w[None, :], axis=1, dtype=jnp.uint32)

    total = jnp.uint32(0)
    for i, arr in enumerate(paged):
        total = total + one(arr, salt + 0x9E3779B9 * (i + 1))
    return total


def attention_decode_paged(params, h_tok: jax.Array, paged: PagedKV,
                           tables: jax.Array, pos: jax.Array,
                           cfg: ArchConfig, *,
                           container: Optional[str] = None,
                           prefix_planes: Optional[int] = None
                           ) -> Tuple[jax.Array, PagedKV]:
    """One continuous-batching decode step over the paged block pool.

    ``tables`` (B, nb) int32 maps each batch row's logical KV blocks to
    physical pool blocks; ``pos`` (B,) is each row's absolute decode
    position. The new token's K/V row is packed and scattered into the
    row's current block (idle rows must point at the reserved trash
    block), then attention reads the pool directly through the paged
    flash-decode kernel — the gather happens inside the kernel grid via
    the scalar-prefetched block table. Global attention only (local ring
    buffers are window-bounded and stay per-slot contiguous). The pool is
    a single-host structure; multi-host pool sharding is future work.
    ``prefix_planes`` (speculative draft steps) expands only the leading
    P' payload bits on the read side; writes stay full width.
    """
    codec = _codec(container)
    B = h_tok.shape[0]
    hd, H, KH = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    D = KH * hd
    block_l = paged.k_payload.shape[1]
    dtype = h_tok.dtype
    fields = codec.pack_fields(dtype)
    assert fields is not None, codec.name

    pos = jnp.asarray(pos, jnp.int32)
    q, k_new, v_new = attention._project_qkv(params, h_tok, cfg,
                                             pos[:, None])

    # Pack only the new rows, then scatter each into its block slot.
    k_pt = codec.pack(k_new.reshape(B, 1, D).astype(dtype))
    v_pt = codec.pack(v_new.reshape(B, 1, D).astype(dtype))
    rows = jnp.arange(B)
    phys = tables[rows, pos // block_l]
    off = pos % block_l
    paged = PagedKV(
        k_payload=paged.k_payload.at[phys, off].set(
            k_pt.data["payload"][:, 0]),
        k_bases=paged.k_bases.at[phys, off].set(k_pt.data["bases"][:, 0]),
        v_payload=paged.v_payload.at[phys, off].set(
            v_pt.data["payload"][:, 0]),
        v_bases=paged.v_bases.at[phys, off].set(v_pt.data["bases"][:, 0]))

    o = ops.paged_flash_decode(
        q.astype(dtype),
        ops.Packed(payload=paged.k_payload, bases=paged.k_bases),
        ops.Packed(payload=paged.v_payload, bases=paged.v_bases),
        tables, pos, fields=fields, softcap=cfg.attn_softcap,
        prefix_planes=prefix_planes)
    out = o.reshape(B, 1, H * hd) @ params["wo"]
    return out, paged
