"""SFP container codecs: fixed-lane words and dense bit-plane payloads.

Owns the container-name -> payload-geometry mapping (kernels are
format-agnostic bit machines taking a ``PackFields``):

  sfp8       byte = sign<<7 | dexp4<<3 | man3           (bf16-range payload)
  sfp16      word = sign<<15 | dexp5<<10 | manK<<(10-K) (K=10 fp32 / 7 bf16)
  sfp-m{K}e{E}  *dense* payload: P = 1 + E + K bits/value (any width 3..16)
             stored as P byte-aligned bit planes per 128-lane group
             (kernels/bitplane_pack.py) — the learned bitlengths become
             real bytes instead of rounding up to an 8/16-bit lane.

One shared 8-bit base exponent per 128-lane group (a Gecko column base).
``pack(x, bits)`` uses the *fused* quantize+pack kernel — the Quantum
Mantissa / BitChop truncation and the container encoding happen in a
single pass over the tensor (one HBM read instead of the old
mantissa_quantize -> sfp_compress two-kernel sequence).

Parametric names realize *policy-learned* geometries (deployment mode,
paper §IV-A4) through the codec factory hook, so a serving pool can derive
its container from a trained checkpoint's PrecisionDecision without
pre-registering every geometry. Dense names whose payload lands exactly on
a lane width (P == 8 or 16) resolve to the fixed-lane word layout — same
bits per value, simpler kernel — so sfp8/sfp16 survive as the fast path.
The legacy fixed-lane family ``sfp{8|16}-m{K}e{E}`` stays resolvable for
old checkpoints.
"""
from __future__ import annotations

import math
import re

import jax
import jax.numpy as jnp

from repro.core import containers
from repro.codecs import base
from repro.kernels import ops
from repro.kernels.ref import GROUP, PackFields

SFP8 = "sfp8"
SFP16 = "sfp16"

_PARAM_NAME = re.compile(r"sfp(8|16)-m(\d+)e(\d+)$")
_DENSE_NAME = re.compile(r"sfp-m(\d+)e(\d+)$")

MIN_PAYLOAD_BITS = 3   # sign + 1 dexp + 1 mantissa
MAX_PAYLOAD_BITS = 16


def dense_fields(man: int, dexp: int, spec: containers.FloatSpec
                 ) -> PackFields:
    """Dense geometry for a (mantissa, delta-exponent) bit budget.

    The realized widths are clamped to what a <=16-bit payload and the
    source dtype can hold; the payload is exactly 1 + dexp + man bits. A
    budget landing on a lane width (8/16) keeps the fixed-lane word layout
    — identical bits per value, cheaper unpack.
    """
    dexp = max(1, min(int(dexp), 8))
    man = max(1, min(int(man), spec.man_bits, MAX_PAYLOAD_BITS - 1 - dexp))
    payload = 1 + dexp + man
    assert MIN_PAYLOAD_BITS <= payload <= MAX_PAYLOAD_BITS, payload
    return PackFields(man_keep=man, dexp_bits=dexp, payload_bits=payload,
                      dense=payload not in (8, 16))


def dense_name(man_bits: float, exp_bits: float) -> str:
    """Map a (possibly fractional) learned decision to a dense container.

    Learned bitlengths are deployed rounded up (a fractional bit cannot be
    stored); the delta-exponent field gets the learned exponent bitlength
    clamped to [2, 7] (the shared 128-lane base absorbs the rest of the
    range, and deltas below 2 bits cannot distinguish zero from
    saturation). The payload is 1 + dexp + man bits — dense bit planes
    unless it lands exactly on a lane width.
    """
    man = max(1, int(math.ceil(man_bits - 1e-9)))
    dexp = max(2, min(7, int(math.ceil(exp_bits - 1e-9))))
    man = min(man, MAX_PAYLOAD_BITS - 1 - dexp)
    return f"sfp-m{man}e{dexp}"


def fields_for(name: str, dtype_or_spec) -> PackFields:
    """Resolve a container name + source dtype to its payload geometry."""
    spec = (dtype_or_spec if isinstance(dtype_or_spec, containers.FloatSpec)
            else containers.spec_for(jnp.dtype(dtype_or_spec)))
    if name == SFP8:
        return PackFields(man_keep=3, dexp_bits=4, payload_bits=8)
    if name == SFP16:
        man_keep = 10 if spec.man_bits == 23 else 7
        return PackFields(man_keep=man_keep, dexp_bits=5, payload_bits=16)
    m = _DENSE_NAME.match(name)
    if m:
        man, dexp = (int(g) for g in m.groups())
        return dense_fields(man, dexp, spec)
    m = _PARAM_NAME.match(name)
    if m:
        payload, man, dexp = (int(g) for g in m.groups())
        # Clamp to what the word and the source dtype can actually hold —
        # the *name* records the learned decision; the realized geometry
        # never exceeds the payload (sign + dexp + man <= word) or keeps
        # more mantissa bits than the source has.
        dexp = max(1, min(dexp, payload - 2))
        man = max(1, min(man, payload - 1 - dexp, spec.man_bits))
        return PackFields(man_keep=man, dexp_bits=dexp, payload_bits=payload)
    raise ValueError(f"not an SFP container: {name!r}")


def maybe_codec(name: str):
    """Codec factory for parametric SFP names: the dense ``sfp-m{K}e{E}``
    family and the legacy fixed-lane ``sfp{8|16}-m{K}e{E}`` family."""
    if _DENSE_NAME.match(name) or _PARAM_NAME.match(name):
        return SFPCodec(name)
    return None


def _nd_layout(shape) -> bool:
    """Rank-preserving (sharding-friendly) layout when lanes align."""
    return len(shape) >= 1 and shape[-1] % GROUP == 0 and shape[-1] > 0


class SFPCodec(base.Codec):
    def __init__(self, name: str):
        self.name = name

    def _fields(self, dtype) -> PackFields:
        return fields_for(self.name, dtype)

    def pack_fields(self, dtype) -> PackFields:
        """SFP payloads have a fixed geometry per dtype — consumers (the
        packed flash-decode kernel) may decompress them inline, words and
        bit planes alike."""
        return self._fields(dtype)

    def pack(self, x: jax.Array, bits=None) -> base.PackedTensor:
        f = self._fields(x.dtype)
        if _nd_layout(x.shape):
            packed = ops.sfp_compress_nd(x, f, n=bits)
        elif bits is not None:
            packed = ops.sfp_quantize_compress(x, bits, f)
        else:
            packed = ops.sfp_compress(x, f)
        return base.PackedTensor(self.name, x.shape, x.dtype,
                                 {"payload": packed.payload,
                                  "bases": packed.bases})

    def unpack(self, packed: base.PackedTensor) -> jax.Array:
        f = self._fields(packed.dtype)
        raw = ops.Packed(payload=packed.data["payload"],
                         bases=packed.data["bases"])
        if _nd_layout(packed.shape):
            return ops.sfp_decompress_nd(raw, packed.dtype, f)
        return ops.sfp_decompress(raw, packed.shape, packed.dtype, f)

    def packed_bits(self, x: jax.Array, bits=None) -> float:
        """Realized byte-aligned footprint; fixed-width, so independent of
        the quantization signal ``bits`` (that's what makes SFP a
        *container*: the mantissa signal changes accuracy, not bytes).

        Matches pack()'s materialized arrays exactly: the flat layout
        zero-pads the tail to a full 128-lane row, and those pad lanes
        occupy real payload bits (plane bytes for dense geometries, lane
        words for fixed ones — ``payload_bits`` is the realized width in
        both layouts).
        """
        f = self._fields(x.dtype)
        n = int(math.prod(x.shape)) if x.shape else 1
        if _nd_layout(x.shape):
            groups = n // GROUP
            payload_vals = n
        else:
            groups = -(-n // GROUP)
            payload_vals = groups * GROUP  # tail row padded to 128 lanes
        return float(payload_vals * f.payload_bits + groups * 8)
