"""Layer 1: AST lints over ``src/repro``.

Pure-syntax rules that catch precision/kernel contract violations before
anything is traced:

  host-sync-in-jit     .item()/.tolist()/.block_until_ready()/
                       jax.device_get/np.asarray — and float()/int()/bool()
                       around a jnp/jax call — inside a traced scope (a
                       function passed to jit/scan/pallas_call/... or
                       decorated with one). Each is a device->host sync
                       that serializes the step it hides in.
  stale-interpret-flag hard-coded ``interpret=True`` (def default or call
                       keyword). Kernels must auto-resolve via
                       ``kernels.ref.default_interpret`` so the same call
                       compiles for real on TPU.
  force-backend-leak   ``force_backend(...)`` outside its def site — a
                       test hook; production code must not pin a backend.
  traced-truthiness    Python ``if``/``while``/``assert`` on a jnp/jax
                       expression in a traced scope (TracerBoolConversion
                       at runtime, or a silent trace-time specialization).
  container-name       container-name string literals in registry calls /
                       known keywords / argparse defaults that the codec
                       registry cannot resolve (with did-you-mean).
  policy-name          same for precision-policy names ('+'-composition
                       validated without construction).
  float64              jnp.float64 / astype("float64") / jax_enable_x64 —
                       this codebase's containers assume <= 32-bit floats.
  obs-no-hot-path-sync telemetry mutation (obs/tracer/timeline .inc/
                       .observe/.emit/...) inside a traced scope. The
                       repro.obs API is host-side Python: calling it from
                       jitted code either burns a trace-time constant or
                       forces a host callback. Record at the host
                       boundary after the step returns.

Two passes per module: collect the names of functions that enter a traced
context (arguments to jit-like wrappers, including through
``functools.partial`` and bound-method references; jit-decorated defs),
then visit with a scope stack so nested defs inherit tracedness.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterable, List, Optional, Set

from repro.analysis.findings import Finding

# Wrappers whose function-valued arguments run traced.
_TRACE_WRAPPERS = {
    "jit", "pmap", "vmap", "grad", "value_and_grad", "checkpoint", "remat",
    "scan", "while_loop", "fori_loop", "cond", "switch", "associated_scan",
    "pallas_call", "custom_vjp", "custom_jvp", "shard_map", "eval_shape",
    "make_jaxpr",
}

# jnp/jax attributes that are static (shape-level) despite the module root.
_STATIC_ATTRS = {"ndim", "shape", "size", "issubdtype", "dtype",
                 "result_type", "isdtype", "iinfo", "finfo"}

_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_NUMPY_ROOTS = {"np", "numpy", "onp"}
_JAX_ROOTS = {"jax", "jnp", "lax", "pl", "pltpu"}

_CONTAINER_KWARGS = {"container", "kv_container", "degraded_container",
                     "grad_codec", "stash_container", "ckpt_container"}
_CONTAINER_RE = r"(sfp|gecko|bit_?exact)[\w+-]*"

# Telemetry surface (repro.obs). Any of these methods invoked on a
# receiver whose attribute chain passes through an obs handle is a
# host-side mutation — illegal inside a traced scope.
_OBS_MUTATORS = {"inc", "dec", "set", "observe", "emit", "event",
                 "instant", "begin", "end", "complete", "record_train",
                 "record_serve", "write"}
_OBS_RECEIVERS = {"obs", "tracer", "timeline", "registry", "events"}


def _dotted(node) -> str:
    """Best-effort dotted name of an expression ('jax.lax.scan', 'f')."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _last(dotted: str) -> str:
    return dotted.rsplit(".", 1)[-1]


def _root(dotted: str) -> str:
    return dotted.split(".", 1)[0]


def _chain_parts(node) -> Set[str]:
    """Every identifier on a receiver chain, walking through attribute
    access, calls, and subscripts: ``self.obs.tracer``,
    ``obs.registry.counter(...).labels(...)``, ``handles["ttft"]`` all
    surface their intermediate names."""
    parts: Set[str] = set()
    while True:
        if isinstance(node, ast.Attribute):
            parts.add(node.attr)
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Name):
            parts.add(node.id)
            return parts
        else:
            return parts


def _callable_names(node) -> Iterable[str]:
    """Function identifiers an argument expression refers to: a bare name,
    a bound-method attr (self._step_fn -> _step_fn), or either wrapped in
    functools.partial(f, ...)."""
    if isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, ast.Attribute):
        yield node.attr
    elif isinstance(node, ast.Call) and _last(_dotted(node.func)) == \
            "partial" and node.args:
        yield from _callable_names(node.args[0])


def _is_jit_decorator(dec) -> bool:
    if isinstance(dec, ast.Call):
        if _last(_dotted(dec.func)) == "partial" and dec.args:
            return _last(_dotted(dec.args[0])) in _TRACE_WRAPPERS
        return _last(_dotted(dec.func)) in _TRACE_WRAPPERS
    return _last(_dotted(dec)) in _TRACE_WRAPPERS


class _TracedCollector(ast.NodeVisitor):
    """Pass 1: names of functions handed to a traced context anywhere in
    the module (scope-insensitive on purpose — conservative)."""

    def __init__(self):
        self.traced: Set[str] = set()

    def visit_Call(self, node):
        if _last(_dotted(node.func)) in _TRACE_WRAPPERS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                self.traced.update(_callable_names(arg))
        self.generic_visit(node)


def _docstring_linenos(tree) -> Set[int]:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.FunctionDef,
                             ast.AsyncFunctionDef, ast.ClassDef)):
            body = getattr(node, "body", [])
            if body and isinstance(body[0], ast.Expr) and isinstance(
                    body[0].value, ast.Constant) and isinstance(
                        body[0].value.value, str):
                c = body[0].value
                out.update(range(c.lineno, c.end_lineno + 1))
    return out


def _contains_jax_call(expr, *, skip_static=True) -> Optional[str]:
    """Dotted name of the first jnp/jax-rooted call inside ``expr``."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if _root(d) in _JAX_ROOTS and "." in d:
                if skip_static and _last(d) in _STATIC_ATTRS:
                    continue
                return d
    return None


class _Lint(ast.NodeVisitor):
    def __init__(self, path: str, traced: Set[str], docstrings: Set[int],
                 findings: List[Finding]):
        self.path = path
        self.traced_names = traced
        self.docstrings = docstrings
        self.findings = findings
        self.scopes: List[tuple] = []  # (name, traced)

    # -- helpers -----------------------------------------------------------

    def _emit(self, rule: str, node, message: str, scope: str = ""):
        scope = scope or (self.scopes[-1][0] if self.scopes else "<module>")
        self.findings.append(Finding(rule=rule, path=self.path,
                                     line=node.lineno, scope=scope,
                                     message=message))

    def _in_traced(self) -> bool:
        return any(traced for _, traced in self.scopes)

    # -- scope tracking ----------------------------------------------------

    def visit_FunctionDef(self, node):
        traced = (node.name in self.traced_names
                  or any(_is_jit_decorator(d) for d in node.decorator_list)
                  or self._in_traced())
        for arg, default in zip(reversed(node.args.args + node.args
                                         .kwonlyargs),
                                reversed((node.args.defaults or [])
                                         + (node.args.kw_defaults or []))):
            if (arg.arg == "interpret" and isinstance(default, ast.Constant)
                    and default.value is True):
                self._emit("stale-interpret-flag", default,
                           f"def {node.name} defaults interpret=True; "
                           "default to None and resolve via "
                           "kernels.ref.default_interpret", scope=node.name)
        self.scopes.append((node.name, traced))
        self.generic_visit(node)
        self.scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- rules -------------------------------------------------------------

    def visit_Call(self, node):
        d = _dotted(node.func)
        last = _last(d)

        if last == "force_backend" and not self.path.endswith(
                "kernels/ops.py"):
            self._emit("force-backend-leak", node,
                       "force_backend() is a test hook; production code "
                       "must not pin a kernel backend")

        for kw in node.keywords:
            if (kw.arg == "interpret" and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                self._emit("stale-interpret-flag", node,
                           f"call {d or '<lambda>'}(..., interpret=True) "
                           "hard-codes interpret mode; pass the resolved "
                           "backend or leave the default")

        if self._in_traced():
            if last in _HOST_SYNC_METHODS and isinstance(node.func,
                                                         ast.Attribute):
                self._emit("host-sync-in-jit", node,
                           f".{last}() forces a device->host sync inside a "
                           "traced function")
            elif last == "device_get" and _root(d) == "jax":
                self._emit("host-sync-in-jit", node,
                           "jax.device_get inside a traced function")
            elif (_root(d) in _NUMPY_ROOTS and last in ("asarray", "array")
                  and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                self._emit("host-sync-in-jit", node,
                           f"{d}() materializes on host inside a traced "
                           "function (use jnp)")
            elif d in ("float", "int", "bool") and node.args:
                inner = _contains_jax_call(node.args[0])
                if inner:
                    self._emit("host-sync-in-jit", node,
                               f"{d}({inner}(...)) concretizes a traced "
                               "value (device->host sync)")
            if (last in _OBS_MUTATORS
                    and isinstance(node.func, ast.Attribute)
                    and _chain_parts(node.func.value) & _OBS_RECEIVERS):
                self._emit("obs-no-hot-path-sync", node,
                           f"telemetry mutation .{last}() inside a traced "
                           "function records a trace-time constant (or "
                           "forces a host callback); record at the host "
                           "boundary after the step returns")

        self._check_names_in_call(node, d, last)
        self.generic_visit(node)

    def _check_names_in_call(self, node, d: str, last: str):
        from repro.analysis import names as _names

        root = _root(d)
        # registry calls: codecs.get("..."), policies.get("...")
        if last in ("get", "validate_name") and node.args and isinstance(
                node.args[0], ast.Constant) and isinstance(
                    node.args[0].value, str):
            if root == "codecs":
                self._name_finding("container-name", node.args[0],
                                   _names.check_container(
                                       node.args[0].value))
            elif root == "policies":
                self._name_finding("policy-name", node.args[0],
                                   _names.check_policy(node.args[0].value))
        # known keywords anywhere: container=..., policy=...
        for kw in node.keywords:
            if not (isinstance(kw.value, ast.Constant)
                    and isinstance(kw.value.value, str)):
                continue
            if kw.arg in _CONTAINER_KWARGS:
                self._name_finding("container-name", kw.value,
                                   _names.check_container(kw.value.value))
            elif kw.arg == "policy":
                self._name_finding("policy-name", kw.value,
                                   _names.check_policy(kw.value.value))
        # argparse: add_argument("--kv-container", default="...")
        if last == "add_argument":
            flags = [a.value for a in node.args
                     if isinstance(a, ast.Constant)
                     and isinstance(a.value, str)]
            is_container = any("container" in f or f.endswith("-codec")
                               for f in flags)
            is_policy = any("policy" in f for f in flags)
            for kw in node.keywords:
                if kw.arg not in ("default", "const"):
                    continue
                if not (isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, str)):
                    continue
                if is_container:
                    self._name_finding(
                        "container-name", kw.value,
                        _names.check_container(kw.value.value))
                elif is_policy:
                    self._name_finding(
                        "policy-name", kw.value,
                        _names.check_policy(kw.value.value))

    def _name_finding(self, rule: str, node, error: Optional[str]):
        if error:
            self._emit(rule, node, error)

    def visit_Assign(self, node):
        targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
        self._check_name_assign(targets, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        if isinstance(node.target, ast.Name) and node.value is not None:
            self._check_name_assign([node.target.id], node.value)
        self.generic_visit(node)

    def _check_name_assign(self, targets: List[str], value):
        if not (isinstance(value, ast.Constant)
                and isinstance(value.value, str)):
            return
        from repro.analysis import names as _names
        for t in targets:
            tl = t.lower()
            if tl in _CONTAINER_KWARGS or tl.endswith("_container"):
                self._name_finding("container-name", value,
                                   _names.check_container(value.value))
            elif tl == "policy" or tl.endswith("_policy"):
                self._name_finding("policy-name", value,
                                   _names.check_policy(value.value))

    def _check_truthiness(self, test, kind: str):
        if not self._in_traced():
            return
        inner = _contains_jax_call(test)
        if inner:
            self._emit("traced-truthiness", test,
                       f"Python {kind} on traced expression {inner}(...) — "
                       "use lax.cond/jnp.where (or checkify for asserts)")

    def visit_If(self, node):
        self._check_truthiness(node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_truthiness(node.test, "while")
        self.generic_visit(node)

    def visit_Assert(self, node):
        self._check_truthiness(node.test, "assert")
        self.generic_visit(node)

    def visit_Attribute(self, node):
        if node.attr == "float64" and _root(_dotted(node)) in (
                _JAX_ROOTS | _NUMPY_ROOTS) - {"np", "numpy", "onp"}:
            self._emit("float64", node,
                       f"{_dotted(node)}: 64-bit floats are outside every "
                       "container geometry here (and silently downcast "
                       "without x64)")
        self.generic_visit(node)

    def visit_Constant(self, node):
        if node.value == "jax_enable_x64" and node.lineno not in \
                self.docstrings:
            self._emit("float64", node,
                       "enabling x64 flips global dtype semantics; "
                       "containers assume <= 32-bit floats")
        self.generic_visit(node)


def lint_source(source: str, path: str) -> List[Finding]:
    """Run every AST rule over one module's source."""
    tree = ast.parse(source, filename=path)
    collector = _TracedCollector()
    collector.visit(tree)
    findings: List[Finding] = []
    _Lint(path, collector.traced, _docstring_linenos(tree),
          findings).visit(tree)
    # astype("float64") / dtype="float64" string form.
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            args = list(node.args) + [kw.value for kw in node.keywords
                                      if kw.arg in ("dtype", None)]
            if _last(d) in ("astype", "asarray", "zeros", "ones", "full",
                            "array", "dtype", "convert_element_type"):
                for a in args:
                    if isinstance(a, ast.Constant) and a.value == "float64":
                        findings.append(Finding(
                            rule="float64", path=path, line=a.lineno,
                            scope=_last(d),
                            message=f'{d}(..., "float64") introduces '
                                    "64-bit floats"))
    return findings


def run_lints(roots: List[pathlib.Path],
              repo_root: pathlib.Path) -> List[Finding]:
    findings: List[Finding] = []
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for py in files:
            rel = py.relative_to(repo_root).as_posix()
            # The analyzer necessarily embeds the very patterns it hunts
            # (rule-trigger strings, force_backend sweeps) — never self-lint.
            if rel.startswith("src/repro/analysis/"):
                continue
            findings.extend(lint_source(py.read_text(), rel))
    return findings
