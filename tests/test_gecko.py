import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import containers as C, gecko


def _rand_exponents(n, seed=0, spread=3):
    rng = np.random.RandomState(seed)
    return jnp.asarray(
        np.clip(rng.normal(127, spread, n).round(), 0, 255).astype(np.uint8))


@pytest.mark.parametrize("n", [1, 7, 8, 63, 64, 65, 1000])
def test_delta_roundtrip_exact(n):
    e = _rand_exponents(n)
    enc = gecko.encode_delta(e)
    np.testing.assert_array_equal(np.asarray(gecko.decode_delta(enc)),
                                  np.asarray(e))


@pytest.mark.parametrize("n", [1, 8, 9, 801])
def test_bias_roundtrip_exact(n):
    e = _rand_exponents(n, seed=1)
    enc = gecko.encode_bias(e)
    np.testing.assert_array_equal(np.asarray(gecko.decode_bias(enc)),
                                  np.asarray(e))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 255), min_size=1, max_size=300))
def test_delta_roundtrip_lossless_property(vals):
    e = jnp.asarray(np.asarray(vals, np.uint8))
    enc = gecko.encode_delta(e)
    np.testing.assert_array_equal(np.asarray(gecko.decode_delta(enc)),
                                  np.asarray(e))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 255), min_size=1, max_size=300))
def test_bias_roundtrip_lossless_property(vals):
    e = jnp.asarray(np.asarray(vals, np.uint8))
    enc = gecko.encode_bias(e)
    np.testing.assert_array_equal(np.asarray(gecko.decode_bias(enc)),
                                  np.asarray(e))


def test_constant_stream_compresses_hard():
    e = jnp.full((64 * 16,), 127, jnp.uint8)
    r = float(gecko.compression_ratio(e, "delta"))
    # per group: 64 bases bits + 7 rows x 3b = 85 bits vs 512 original
    assert r < 0.2


def test_uniform_random_does_not_win():
    rng = np.random.RandomState(3)
    e = jnp.asarray(rng.randint(0, 256, 4096).astype(np.uint8))
    assert float(gecko.compression_ratio(e, "delta")) > 0.9


def test_trained_like_distribution_hits_paper_range():
    """Paper: ~0.52-0.56 ratio on training exponent streams."""
    e = _rand_exponents(1 << 16, seed=4, spread=4)
    r = float(gecko.compression_ratio(e, "delta"))
    assert 0.3 < r < 0.75


def test_ratio_bits_consistency():
    e = _rand_exponents(4096, seed=5)
    bits = float(gecko.compressed_bits(e, "delta"))
    r = float(gecko.compression_ratio(e, "delta"))
    assert abs(bits / (e.size * 8) - r) < 1e-6


def test_per_value_bits_delta():
    e = _rand_exponents(256, seed=6)
    pv = gecko.per_value_bits(e, "delta")
    assert pv.shape == (256,)
    # row-0 bases are always 8 bits
    assert all(int(b) == 8 for b in np.asarray(pv).reshape(-1, 8, 8)[:, 0, :]
               .reshape(-1))
    assert int(jnp.max(pv)) <= 9  # sign + <=8 magnitude bits


def test_real_tensor_exponents():
    import jax
    x = jax.random.normal(jax.random.PRNGKey(0), (1 << 14,), jnp.float32)
    e = C.exponent_field(x)
    enc = gecko.encode_delta(e)
    np.testing.assert_array_equal(np.asarray(gecko.decode_delta(enc)),
                                  np.asarray(e))
    assert float(gecko.compression_ratio(e, "delta")) < 1.0
