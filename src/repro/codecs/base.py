"""Container-codec registry: the one place container names mean something.

The paper's central mechanism is a single adaptive container pipeline that
serves every tensor crossing the memory boundary. This module is that
mechanism as a subsystem: a ``Codec`` packs a float tensor into a
``PackedTensor`` (a scan/jit-friendly pytree of payload arrays plus static
metadata), unpacks it back, and accounts for its exact compressed
footprint. All compressed-tensor paths — the activation stash
(models/model.py), the compressed KV cache (serve/kvcache.py), gradient
compression (train/grad_compress.py), and checkpoint compression
(checkpoint/manager.py) — resolve their container through ``get()``;
nothing outside this package dispatches on container strings.

Backends follow the existing ``kernels.ops.force_backend`` mechanism:
codecs call through ops wrappers, which pick the Pallas kernel on TPU (or
in interpret mode) and the jnp oracle elsewhere.
"""
from __future__ import annotations

import abc
import difflib
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class PackedTensor:
    """A compressed tensor: named payload arrays + static reconstruction meta.

    ``data`` maps part names (e.g. "payload", "bases") to arrays; ``codec``,
    ``shape`` and ``dtype`` ride along as static pytree aux data, so a
    PackedTensor flows through jit/scan/vmap and ``unpack`` needs no side
    channel to reconstruct the original tensor.
    """

    __slots__ = ("codec", "shape", "dtype", "data")

    def __init__(self, codec: str, shape: Tuple[int, ...], dtype,
                 data: Dict[str, Any]):
        self.codec = codec
        self.shape = tuple(shape)
        self.dtype = jnp.dtype(dtype)
        self.data = dict(data)

    def tree_flatten(self):
        keys = tuple(sorted(self.data))
        children = tuple(self.data[k] for k in keys)
        return children, (self.codec, self.shape, str(self.dtype), keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codec, shape, dtype, keys = aux
        return cls(codec, shape, dtype, dict(zip(keys, children)))

    def __repr__(self):
        parts = ", ".join(f"{k}:{getattr(v, 'shape', '?')}"
                          for k, v in sorted(self.data.items()))
        return (f"PackedTensor({self.codec}, shape={self.shape}, "
                f"dtype={self.dtype}, {parts})")


class Codec(abc.ABC):
    """Uniform interface for every compressed-tensor representation.

    ``bits`` is the mantissa bitlength signal from Quantum Mantissa /
    BitChop / a static policy — a possibly-traced int32 scalar, or None for
    the codec's full native precision.
    """

    name: str = "?"

    @abc.abstractmethod
    def pack(self, x: jax.Array, bits=None) -> PackedTensor:
        """Compress ``x`` (optionally quantizing mantissas to ``bits``)."""

    @abc.abstractmethod
    def unpack(self, packed: PackedTensor) -> jax.Array:
        """Reconstruct the tensor (shape/dtype from the packed metadata)."""

    @abc.abstractmethod
    def packed_bits(self, x: jax.Array, bits=None) -> float:
        """Exact realized footprint of pack(x, bits), in bits."""

    def pack_fields(self, dtype):
        """Payload-word geometry (a ``kernels.ref.PackFields``) of this
        codec's packed representation for ``dtype`` sources, or None when
        the codec is not a fixed-width SFP container (bit_exact, gecko8).

        Consumers that can fuse decompression into their own kernels —
        the packed flash-decode attention — use this to obtain the bit
        layout without going through container names; None means "no
        fused path, decompress via unpack() instead".
        """
        del dtype
        return None

    def packed_spec(self, shape: Tuple[int, ...], dtype) -> PackedTensor:
        """ShapeDtypeStruct skeleton of pack()'s output — for cache/buffer
        init and checkpoint planning without materializing anything."""
        spec = jax.eval_shape(
            lambda: self.pack(jnp.zeros(shape, dtype)))
        return spec

    def roundtrip(self, x: jax.Array, bits=None) -> jax.Array:
        """pack->unpack: the fake-quant view of the realized container."""
        return self.unpack(self.pack(x, bits))

    def lossless_for(self, dtype) -> bool:
        """True iff pack(x)->unpack is bit-exact for every ``dtype`` tensor
        (with bits=None). Consumers that must not silently degrade data
        (checkpoint compression) gate on this when no quantization was
        explicitly requested."""
        return False

    # -- host-side serialization (checkpoint compression) ------------------

    def encode_host(self, arr: np.ndarray, bits: Optional[int] = None
                    ) -> Tuple[np.ndarray, Dict[str, Any]]:
        """Serialize ``arr`` into a flat uint8 stream + JSON-able meta.

        Default: concatenate the packed parts' raw bytes in sorted-name
        order (fixed-width codecs). Variable-length codecs override.
        """
        packed = self.pack(jnp.asarray(arr), bits)
        parts = {k: np.asarray(v) for k, v in sorted(packed.data.items())}
        stream = np.concatenate([p.reshape(-1).view(np.uint8) for p in
                                 parts.values()]) if parts else np.zeros(
                                     0, np.uint8)
        meta = {
            "parts": {k: {"shape": list(p.shape), "dtype": p.dtype.name,
                          "nbytes": int(p.nbytes)}
                      for k, p in parts.items()},
        }
        if bits is not None:
            meta["bits"] = int(bits)
        return stream, meta

    def decode_host(self, stream: np.ndarray, meta: Dict[str, Any],
                    shape: Tuple[int, ...], dtype) -> np.ndarray:
        data = {}
        off = 0
        for k, p in meta["parts"].items():
            nb = int(p["nbytes"])
            data[k] = (stream[off: off + nb].view(np.dtype(p["dtype"]))
                       .reshape(p["shape"]))
            off += nb
        packed = PackedTensor(self.name, shape, dtype,
                              {k: jnp.asarray(v) for k, v in data.items()})
        return np.asarray(self.unpack(packed))


_REGISTRY: Dict[str, Codec] = {}
_FACTORIES = []


def register(codec: Codec) -> Codec:
    """Register a codec instance under its name (last registration wins)."""
    _REGISTRY[codec.name] = codec
    return codec


def register_factory(factory) -> None:
    """Register a name -> Codec-or-None resolver for parametric families.

    Families with unbounded name spaces (the policy-derived ``sfp*-m*e*``
    containers) cannot pre-register every instance; ``get`` consults
    factories for unknown names and caches the constructed codec, so a
    parametric container behaves exactly like a registered one from the
    first use on.
    """
    _FACTORIES.append(factory)


def get(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        pass
    for factory in _FACTORIES:
        codec = factory(name)
        if codec is not None:
            return register(codec)
    raise KeyError(
        f"unknown container codec {name!r}; registered: {names()}")


def names():
    return sorted(_REGISTRY)


# Canonical shapes of the parametric families, shown in validation errors.
PARAMETRIC_GRAMMAR = "sfp-m{K}e{E} (dense), sfp{8|16}-m{K}e{E} (fixed-lane)"


def _resolvable(name: str) -> bool:
    try:
        get(name)
        return True
    except Exception:
        return False


def suggest_name(name: str) -> Optional[str]:
    """Best-effort did-you-mean for an unresolvable container name.

    Candidates are the registered names plus parametric names rebuilt from
    the digits of the input (so ``sfp-2me4``/``sfpm2e4``-style typos map
    back to ``sfp-m2e4``); every candidate is validated through the real
    registry/factory path before being offered.
    """
    cands = list(names())
    digits = re.findall(r"\d+", name)
    if "sfp" in name:
        if len(digits) == 2:
            cands.append(f"sfp-m{digits[0]}e{digits[1]}")
        if len(digits) == 3 and digits[0] in ("8", "16"):
            cands.append(f"sfp{digits[0]}-m{digits[1]}e{digits[2]}")
    good = [c for c in cands if _resolvable(c)]
    best = difflib.get_close_matches(name, good, n=1, cutoff=0.55)
    return best[0] if best else None


def validate_name(name: str, *, what: str = "container codec") -> Codec:
    """Resolve ``name`` through the registry + parametric factories,
    raising ``ValueError`` with a did-you-mean suggestion on failure.

    This is the one grammar check shared by the static-analysis lint rule
    (``repro.analysis``), the launchers' argparse validators, and anything
    else that wants container typos to fail fast instead of at trace time.
    """
    try:
        return get(name)
    except KeyError:
        pass
    hint = suggest_name(name)
    msg = f"unknown {what} {name!r}"
    if hint:
        msg += f"; did you mean {hint!r}?"
    msg += (f" (registered: {names()}; parametric: {PARAMETRIC_GRAMMAR})")
    raise ValueError(msg)


def unpack(packed: PackedTensor) -> jax.Array:
    """Module-level convenience: dispatch unpack on the packed metadata."""
    return get(packed.codec).unpack(packed)
