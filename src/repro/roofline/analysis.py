"""Roofline aggregation: dry-run artifacts -> EXPERIMENTS.md §Roofline table.

Three terms per (arch x shape), single-pod mesh (256 chips):

  compute    = jaxpr_FLOPs / (chips * 197 TF/s)
  memory     = jaxpr_HBM_bytes / (chips * 819 GB/s)
  collective = trip-weighted per-device collective bytes / 50 GB/s/link

FLOPs/bytes come from the jaxpr cost model (repro.roofline.jaxpr_cost):
the CPU backend's compiled.cost_analysis() counts scan bodies once
(validated in tests/test_roofline.py), so it cannot see >90% of the work
in a scan-based program; both numbers are recorded in the dry-run JSON.

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill) /
2*N_active*B (decode), plus the attention window/context term; the ratio
MODEL_FLOPS / jaxpr_FLOPs exposes remat & bookkeeping waste.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro import configs
from repro.configs.base import SHAPES, ArchConfig, GLOBAL, LOCAL, ShapeConfig
from repro.roofline import hw

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def attention_flops(cfg: ArchConfig, shape: ShapeConfig, fwd_mult: float
                    ) -> float:
    """Score/value matmul FLOPs (4*B*H*hd per q-k pair), window-aware."""
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.n_heads, cfg.head_dim_
    if H == 0:
        return 0.0
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.period[i % len(cfg.period)]
        if kind == GLOBAL:
            pairs = (S * (S + 1) / 2 if shape.kind != "decode" else S)
        elif kind == LOCAL:
            w = min(cfg.window, S)
            pairs = (S * w - w * w / 2 if shape.kind != "decode"
                     else min(cfg.window, S))
        else:
            continue
        total += 4.0 * B * H * hd * pairs
    return total * fwd_mult


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    n = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * B * S + attention_flops(cfg, shape, 3.0)
    if shape.kind == "prefill":
        return 2.0 * n * B * S + attention_flops(cfg, shape, 1.0)
    return 2.0 * n * B + attention_flops(cfg, shape, 1.0)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    policy: str
    ok: bool
    layout: str = "tp"
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_flops: float = 0.0
    jaxpr_flops: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    dominant: str = ""
    hbm_gb_per_dev: float = 0.0
    temp_gb_per_dev: float = 0.0
    args_gb_per_dev: float = 0.0
    collective_breakdown: Optional[Dict] = None
    compile_s: float = 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def load_cell(path: Path) -> Optional[Cell]:
    d = json.loads(path.read_text())
    cell = Cell(arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                policy=d["policy"], ok=d.get("ok", False),
                layout=d.get("layout", "tp"))
    if not cell.ok:
        return cell
    chips = d.get("n_devices", 256)
    jc = d.get("jaxpr_cost", {})
    cw = d.get("collectives_trip_weighted", d.get("collectives", {}))
    cell.jaxpr_flops = jc.get("flops", 0.0)
    cell.compute_s = cell.jaxpr_flops / chips / hw.PEAK_FLOPS_BF16
    cell.memory_s = jc.get("hbm_bytes", 0.0) / chips / hw.HBM_BW
    cell.collective_s = cw.get("total_bytes", 0.0) / hw.ICI_BW_PER_LINK
    cell.collective_breakdown = {
        k: v.get("bytes", 0.0) for k, v in cw.items() if isinstance(v, dict)}
    cfg = configs.get(d["arch"])
    cell.model_flops = model_flops(cfg, SHAPES[d["shape"]])
    cell.useful_ratio = (cell.model_flops / cell.jaxpr_flops
                         if cell.jaxpr_flops else 0.0)
    terms = {"compute": cell.compute_s, "memory": cell.memory_s,
             "collective": cell.collective_s}
    cell.dominant = max(terms, key=terms.get)
    ideal = cell.model_flops / chips / hw.PEAK_FLOPS_BF16
    cell.roofline_fraction = ideal / cell.bound_s if cell.bound_s else 0.0
    ma = d.get("memory_analysis", {})
    cell.temp_gb_per_dev = ma.get("temp_size_in_bytes", 0) / 1e9
    cell.args_gb_per_dev = ma.get("argument_size_in_bytes", 0) / 1e9
    cell.hbm_gb_per_dev = cell.temp_gb_per_dev + cell.args_gb_per_dev
    cell.compile_s = d.get("compile_s", 0.0)
    return cell


def load_all(dry_dir: Path = DRYRUN_DIR, mesh: str = "single",
             policy: Optional[str] = None) -> List[Cell]:
    cells = []
    for p in sorted(dry_dir.glob("*.json")):
        c = load_cell(p)
        if c is None or c.mesh != mesh:
            continue
        if policy and c.policy != policy:
            continue
        cells.append(c)
    return cells


def markdown_table(cells: List[Cell]) -> str:
    rows = ["| arch | shape | layout | compute s | memory s | collective s |"
            " dominant | MODEL/HLO flops | roofline frac | HBM GB/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c.arch, c.shape, c.layout)):
        if not c.ok:
            rows.append(f"| {c.arch} | {c.shape} | {c.layout} | FAILED |"
                        " | | | | | |")
            continue
        rows.append(
            f"| {c.arch} | {c.shape} | {c.layout} | {c.compute_s:.4f} |"
            f" {c.memory_s:.4f} | {c.collective_s:.4f} | {c.dominant} |"
            f" {c.useful_ratio:.2f} | {c.roofline_fraction:.3f} |"
            f" {c.hbm_gb_per_dev:.1f} |")
    return "\n".join(rows)


def pick_hillclimb(cells: List[Cell]) -> Dict[str, Cell]:
    """Worst roofline fraction, most collective-bound, most representative
    of the paper's technique (the train cell with the largest stash =
    largest memory term among train shapes)."""
    ok = [c for c in cells if c.ok]
    worst = min(ok, key=lambda c: c.roofline_fraction)
    coll = max(ok, key=lambda c: c.collective_s / max(c.bound_s, 1e-12))
    train = [c for c in ok if SHAPES[c.shape].kind == "train"]
    rep = max(train, key=lambda c: c.memory_s)
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--dir", default=str(DRYRUN_DIR))
    args = ap.parse_args()
    cells = load_all(Path(args.dir), args.mesh, args.policy)
    print(markdown_table(cells))
    ok = [c for c in cells if c.ok]
    if ok:
        picks = pick_hillclimb(cells)
        print("\nHillclimb candidates:")
        for label, c in picks.items():
            print(f"  {label}: {c.arch} x {c.shape} "
                  f"(frac={c.roofline_fraction:.3f}, dom={c.dominant})")


if __name__ == "__main__":
    main()
