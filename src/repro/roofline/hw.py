"""Target hardware constants: TPU v5e (per assignment)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
HBM_PER_CHIP = 16 * 2 ** 30   # 16 GiB

# Per-TensorCore VMEM. ~16 MiB on v4/v5e-class parts; kernels must fit
# their double-buffered block windows + scratch well under this.
VMEM_PER_CORE = 16 * 2 ** 20
# Static-analysis budget: leave headroom for the compiler's own spills,
# semaphores, and anything the estimator's materialization model misses.
VMEM_BUDGET_FRACTION = 0.9
