"""Data pipeline: synthetic corpora, sharded batching, prefetch."""
