"""Lock-cheap metrics registry: counters, gauges, log-bucket histograms.

One process, one registry, many labeled series. The design constraints
come from the serving hot loop: recording a sample must not allocate
(histograms pre-compute their bucket bounds and keep plain int arrays),
must not synchronize with the device (callers pass host floats/ints that
were already materialized at a host boundary — never traced values), and
must be safe to call at step frequency. Export is the slow path:
``to_prometheus()`` renders the standard text exposition format and
``snapshot()`` returns a JSON-able dict for the JSONL event stream.

Labeled series follow the prometheus-client idiom::

    reqs = reg.counter("serve_requests_total", "terminal outcomes",
                       labels=("outcome",))
    reqs.labels(outcome="ok").inc()

``labels()`` returns a bound series; binding is a dict lookup plus (on
first use) one tuple allocation, so hot paths should bind once and hold
the handle where possible. Unlabeled metrics skip even that:
``reg.counter("serve_decode_steps_total", ...).inc(k)`` mutates a single
slot.
"""
from __future__ import annotations

import json
import math
import threading
import time
from dataclasses import dataclass, field
from typing import IO, Any


def _fmt(v: float) -> str:
    """Prometheus-style number: integers stay integral, no exponent noise."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(names: tuple[str, ...], values: tuple[str, ...]) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{v}"' for n, v in zip(names, values))
    return "{" + inner + "}"


def log_buckets(lo: float = 1e-5, hi: float = 100.0,
                per_decade: int = 4) -> tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds, ``lo``..``hi`` inclusive.

    The default (1e-5s .. 100s, 4/decade = 29 bounds) spans everything we
    time — sub-ms decode steps through multi-second floods — with ~78%
    worst-case relative quantization per bucket step, good enough for
    p50/p95/p99 reporting. Fixed at construction so `observe` is a binary
    search over a tuple: no allocation, no rehash.
    """
    n_dec = round(math.log10(hi / lo))
    n = n_dec * per_decade
    return tuple(lo * (10 ** (i / per_decade)) for i in range(n + 1))


class _CounterSeries:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount


class _GaugeSeries:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class _HistogramSeries:
    __slots__ = ("bounds", "counts", "sum", "count", "min", "max")

    def __init__(self, bounds: tuple[float, ...]) -> None:
        self.bounds = bounds
        # +1 overflow slot for samples above the last bound.
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # first bound >= v (upper-inclusive buckets)
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Bucket-resolution quantile, ``q`` in [0, 1].

        Returns the upper bound of the bucket containing the q-th sample
        (the max observed value for the overflow bucket); 0.0 when empty.
        Allocation-free: one pass over the fixed count array.
        """
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i == len(self.bounds):
                    return self.max
                return min(self.bounds[i], self.max)
        return self.max


@dataclass
class _Family:
    name: str
    kind: str  # counter | gauge | histogram
    help: str
    unit: str
    label_names: tuple[str, ...]
    bounds: tuple[float, ...] | None = None
    series: dict[tuple[str, ...], Any] = field(default_factory=dict)

    def _make(self):
        if self.kind == "counter":
            return _CounterSeries()
        if self.kind == "gauge":
            return _GaugeSeries()
        return _HistogramSeries(self.bounds or log_buckets())

    def labels(self, **kv: Any):
        if set(kv) != set(self.label_names):
            raise KeyError(f"{self.name}: expected labels "
                           f"{self.label_names}, got {tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.label_names)
        s = self.series.get(key)
        if s is None:
            s = self.series[key] = self._make()
        return s

    # Unlabeled convenience: family acts as its own single series.
    def _solo(self):
        if self.label_names:
            raise KeyError(f"{self.name} is labeled {self.label_names}; "
                           "use .labels(...)")
        s = self.series.get(())
        if s is None:
            s = self.series[()] = self._make()
        return s

    def inc(self, amount: float = 1.0) -> None:
        self._solo().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._solo().dec(amount)

    def set(self, value: float) -> None:
        self._solo().set(value)

    def observe(self, value: float) -> None:
        self._solo().observe(value)

    def percentile(self, q: float) -> float:
        return self._solo().percentile(q)

    @property
    def value(self) -> float:
        return self._solo().value

    def total(self, **fixed: Any) -> float:
        """Sum a counter/gauge family across series matching ``fixed``."""
        idx = {n: i for i, n in enumerate(self.label_names)}
        out = 0.0
        for key, s in self.series.items():
            if all(key[idx[n]] == str(v) for n, v in fixed.items()):
                out += s.value
        return out


class MetricsRegistry:
    """Get-or-create registry of metric families.

    Creation is idempotent: asking for an existing name returns the same
    family (kind must match), so callers can look metrics up by name at
    any layer without threading handles around. A single lock guards
    family creation only — sample recording is plain Python mutation,
    which is atomic enough under the GIL for our single-writer loops.
    """

    def __init__(self) -> None:
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str, unit: str,
             labels: tuple[str, ...],
             bounds: tuple[float, ...] | None = None) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind:
                raise TypeError(f"{name} already registered as {fam.kind}")
            return fam
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name=name, kind=kind, help=help, unit=unit,
                              label_names=tuple(labels), bounds=bounds)
                self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", unit: str = "",
                labels: tuple[str, ...] = ()) -> _Family:
        return self._get(name, "counter", help, unit, labels)

    def gauge(self, name: str, help: str = "", unit: str = "",
              labels: tuple[str, ...] = ()) -> _Family:
        return self._get(name, "gauge", help, unit, labels)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  labels: tuple[str, ...] = (),
                  bounds: tuple[float, ...] | None = None) -> _Family:
        return self._get(name, "histogram", help, unit, labels,
                         bounds or log_buckets())

    # ---- export ---------------------------------------------------------

    def to_prometheus(self) -> str:
        """Standard text exposition format (one family per HELP/TYPE)."""
        out: list[str] = []
        for fam in self._families.values():
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} "
                       f"{'histogram' if fam.kind == 'histogram' else fam.kind}")
            for key in sorted(fam.series):
                s = fam.series[key]
                lbl = _label_str(fam.label_names, key)
                if fam.kind in ("counter", "gauge"):
                    out.append(f"{fam.name}{lbl} {_fmt(s.value)}")
                    continue
                cum = 0
                for bound, c in zip(s.bounds, s.counts):
                    cum += c
                    le = _label_str(fam.label_names + ("le",),
                                    key + (_fmt(bound),))
                    out.append(f"{fam.name}_bucket{le} {cum}")
                le = _label_str(fam.label_names + ("le",),
                                key + ("+Inf",))
                out.append(f"{fam.name}_bucket{le} {s.count}")
                out.append(f"{fam.name}_sum{lbl} {_fmt(s.sum)}")
                out.append(f"{fam.name}_count{lbl} {s.count}")
        return "\n".join(out) + ("\n" if out else "")

    def snapshot(self) -> dict[str, Any]:
        """JSON-able dump: one entry per family, series keyed by labels."""
        out: dict[str, Any] = {}
        for fam in self._families.values():
            series = []
            for key in sorted(fam.series):
                s = fam.series[key]
                entry: dict[str, Any] = {
                    "labels": dict(zip(fam.label_names, key))}
                if fam.kind == "histogram":
                    entry.update(count=s.count, sum=s.sum,
                                 min=(None if s.count == 0 else s.min),
                                 max=(None if s.count == 0 else s.max),
                                 p50=s.percentile(0.50),
                                 p95=s.percentile(0.95),
                                 p99=s.percentile(0.99))
                else:
                    entry["value"] = s.value
                series.append(entry)
            out[fam.name] = {"kind": fam.kind, "unit": fam.unit,
                             "series": series}
        return out


class EventLog:
    """Append-only JSONL sink shared by metrics, events, and the loop.

    Two write modes: ``emit(name, **fields)`` stamps a wall-clock ``ts``
    and an ``event`` discriminator key; ``write(obj)`` dumps the dict
    verbatim — that is the byte-compatible path for `train/loop.py`'s
    existing per-step metric lines, whose format downstream notebooks
    already parse.
    """

    def __init__(self, path: str | None, truncate: bool = True) -> None:
        self.path = path
        self._fh: IO[str] | None = None
        self.entries: list[dict[str, Any]] = []
        if path:
            self._fh = open(path, "w" if truncate else "a")

    def write(self, obj: dict[str, Any]) -> None:
        self.entries.append(obj)
        if self._fh is not None:
            self._fh.write(json.dumps(obj) + "\n")
            self._fh.flush()

    def emit(self, event: str, **fields: Any) -> None:
        self.write({"event": event, "ts": time.time(), **fields})

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
