"""Logical-axis sharding rules: one place that decides how everything shards.

Parameters carry *logical* axis names (from common.ParamFactory axes mode);
this module maps them onto mesh axes:

  embed    -> data   (FSDP / ZeRO-3: weights shard their non-TP dim over the
                      data axis; XLA all-gathers per scan step and
                      reduce-scatters gradients)
  heads/ff/vocab/experts/lru/ssm_inner -> model   (tensor parallelism;
                      experts over model = expert parallelism)
  batch    -> (pod, data)
  cache_seq-> model  (decode KV cache shards its sequence dim — the softmax
                      reductions become exact XLA all-reduces, flash-decoding
                      style)

Anything unlisted is replicated. Divisibility is not required (GSPMD pads
uneven shards); rules only choose *where* things live.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5 ships it under experimental, with check_vma as check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_compat

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map_compat(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


def rules_for(mesh: Mesh, *, fsdp: bool = True, layout: str = "tp"
              ) -> Dict[str, Optional[Tuple[str, ...]]]:
    """Logical->mesh mapping.

    layout='tp'   : TP over `model` (heads/ff/vocab/experts) + FSDP over
                    `data` — the default; right when per-device batch is
                    large enough to amortize the 2-per-layer activation
                    all-reduces.
    layout='fsdp' : ZeRO-3 over BOTH axes — weights and batch shard over
                    (data x model); no tensor parallelism, so the only
                    collectives are per-layer parameter all-gathers (bf16)
                    and gradient reduce-scatters. Wins when activation
                    all-reduce traffic dominates (large d_model, small
                    per-device batch) — see EXPERIMENTS.md §Perf.
    """
    multi_pod = "pod" in mesh.axis_names
    if layout == "fsdp":
        batch_axes = (("pod", "data", "model") if multi_pod
                      else ("data", "model"))
        return {
            "embed": batch_axes,
            "embed_r": None,
            "heads": None, "ff": None, "expert_ff": None, "vocab": None,
            "experts": ("model",),  # EP still pays off for MoE
            "lru": None, "ssm_inner": None, "state": None,
            "conv": None, "norm": None, "layers": None,
            "batch": batch_axes,
            "seq": None,
            "cache_seq": ("model",),
            "kv": None,
        }
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return {
        # weights
        "embed": ("data",) if fsdp else None,
        "embed_r": None,  # embedding/head model dim (lookup shards vocab)
        "heads": ("model",),
        "ff": ("model",),
        "expert_ff": None,
        "vocab": ("model",),
        "experts": ("model",),
        "lru": ("model",),
        "ssm_inner": ("model",),
        "state": None,
        "conv": None,
        "norm": None,
        "layers": None,
        # activations / caches
        "batch": batch_axes,
        "seq": None,
        "cache_seq": ("model",),
        "kv": None,
    }


def spec_from_axes(axes: Tuple[Optional[str], ...],
                   rules: Dict[str, Optional[Tuple[str, ...]]]) -> P:
    parts = []
    used = set()
    for ax in axes:
        target = rules.get(ax) if ax is not None else None
        if target is None:
            parts.append(None)
            continue
        # A mesh axis may appear only once per spec; later dims replicate.
        target = tuple(t for t in target if t not in used)
        if not target:
            parts.append(None)
            continue
        used.update(target)
        parts.append(target if len(target) > 1 else target[0])
    return P(*parts)


def tree_specs(axes_tree: Any, rules) -> Any:
    """Map a tree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda a: spec_from_axes(a, rules), axes_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            x is None or isinstance(x, str) for x in a))


def tree_shardings(mesh: Mesh, axes_tree: Any, rules=None) -> Any:
    rules = rules or rules_for(mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(axes_tree, rules),
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(rules, kind: str, has_cond: bool) -> Dict[str, P]:
    b = rules["batch"]
    b = b if not isinstance(b, tuple) or len(b) > 1 else b[0]
    specs = {"tokens": P(b, None)}
    if kind == "train":
        specs["labels"] = P(b, None)
    if has_cond and kind != "decode":
        specs["cond_embeddings"] = P(b, None, None)
    return specs


def refine_shardings(shapes_tree: Any, shardings_tree: Any, mesh: Mesh) -> Any:
    """Drop sharding on dims the mesh axes don't divide (e.g. batch=1 cells).

    GSPMD pads uneven shardings for intermediates, but jit in_shardings
    require exact divisibility — this filters per-leaf against the actual
    ShapeDtypeStruct.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def refine(shape_leaf, sh):
        if not isinstance(sh, NamedSharding):
            return sh
        spec = sh.spec
        parts = []
        for i, ax in enumerate(spec):
            if ax is None or i >= len(shape_leaf.shape):
                parts.append(ax)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = 1
            for a in axes:
                prod *= sizes[a]
            parts.append(ax if shape_leaf.shape[i] % prod == 0 else None)
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(refine, shapes_tree, shardings_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# --- trace-time sharding hints -------------------------------------------
# GSPMD propagation sometimes resolves conflicting uses by replicating a
# big tensor ("involuntary full rematerialization", e.g. a KV-cache update
# whose new token arrives heads-sharded). Models set the active mesh once;
# hint() places with_sharding_constraint only when a mesh is active.

_ACTIVE_MESH: list = [None]
_ACTIVE_RULES: list = [None]


def set_active_mesh(mesh: Optional[Mesh], rules=None) -> None:
    _ACTIVE_MESH[0] = mesh
    _ACTIVE_RULES[0] = rules if rules is not None else (
        rules_for(mesh) if mesh is not None else None)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH[0]


def active_rules():
    return _ACTIVE_RULES[0]


def hint(x, *spec):
    mesh = _ACTIVE_MESH[0]
    if mesh is None:
        return x
    spec = spec + (None,) * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def batch_axis_for(mesh: Mesh, size: int):
    rules = _ACTIVE_RULES[0] or rules_for(mesh)
    axes = rules["batch"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    if size % n != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def heads_target() -> Optional[str]:
    """Mesh axis for attention heads under the active rules (None = don't
    shard heads; e.g. the fsdp layout keeps them replicated)."""
    rules = _ACTIVE_RULES[0]
    if rules is None:
        return "model"
    t = rules.get("heads")
    return t[0] if t else None


def model_axis_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1)
