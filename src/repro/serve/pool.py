"""Paged packed-KV block pool: host-side allocator for the serving engine.

The serving analogue of the paper's containers-at-the-memory-interface: KV
bytes live *packed* in fixed-size physical blocks (one block = the packed
flash-decode kernel's KV block — ``ops.DECODE_BLOCK_L`` token rows), and a
request owns blocks, not a contiguous region. Device memory is one
request-agnostic pool slice per global-attention layer
(``kvcache.PagedKV``); this module owns everything host-side: the free
list, per-slot block tables, admission accounting, eviction, and the
block *quarantine* (integrity-failed blocks held out of circulation until
scrubbed — see serve/faults.py and the scheduler's recovery path).

Because blocks are codec-packed, pool capacity is measured in *compressed*
bytes — an sfp8 pool holds ~2x the tokens of a raw bf16 cache in the same
HBM footprint, which is exactly the admission-throughput win the scheduler
converts into tok/s. A *dense* policy-derived geometry (``sfp-m{K}e{E}``,
bit-plane payloads) pushes the same lever further: a 7-bit ``sfp-m2e4``
pool holds ~2.27x the tokens of raw bf16 where fixed-lane sfp8 stops at
~1.98x.

Admission can additionally be gated on a **byte budget** that is decoupled
from the physical block count: each slot registers the dense-packed bytes
*its* geometry makes one block cost, so requests admitted at a narrower
container (the pressure controller's graceful-degradation downshift,
serve/precision.py) are priced at their narrower geometry and more of
them fit the same modeled HBM budget. The device arrays stay sized for
the widest geometry (fixed shapes keep the decode step jittable); the
byte accounting models what the blocks would occupy repacked dense.

Physical block 0 is reserved as the *trash block*: idle engine slots (and
logical blocks past a row's allocation) point their table entries at it,
so the jitted fixed-shape decode step can always scatter/gather without
branching — writes to block 0 are garbage by construction and never read
through a valid position mask.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

TRASH_BLOCK = 0


def blocks_for(n_tokens: int, block_l: int) -> int:
    """Physical blocks needed to hold ``n_tokens`` KV rows."""
    return max(0, -(-int(n_tokens) // block_l))


@dataclasses.dataclass
class PoolStats:
    num_blocks: int      # allocatable blocks (trash block excluded)
    free_blocks: int
    used_blocks: int
    peak_used: int
    quarantined: int = 0
    block_bytes: int = 0   # dense-packed bytes per block at the pool's
    #                        configured (widest) geometry; 0 = not priced
    capacity_bytes: int = 0
    used_bytes: int = 0
    free_bytes: int = 0
    peak_bytes: int = 0
    budget_bytes: Optional[int] = None


class BlockPool:
    """Free list + per-slot block tables over ``num_blocks`` physical blocks.

    ``num_blocks`` counts *allocatable* blocks; one extra trash block is
    implicit (physical id 0), so device pool arrays must be sized
    ``num_blocks + 1``. Tables are dense numpy (max_slots, max_logical)
    int32 handed to the jitted step each call; unallocated entries point
    at the trash block.

    Admission accounting is measured in *dense-packed bytes*:
    ``block_bytes`` is what one physical block really occupies under the
    pool's configured codec geometry (payload words or bit planes + group
    bases, summed over the layers sharing this pool — see
    ``kvcache.paged_block_bytes``). A slot may register a different
    per-block rate at allocation time (``alloc_upto(block_bytes=...)``):
    that is the graceful-degradation path, where admissions downshifted
    to a narrower dense geometry are priced at the narrower rate. When a
    ``budget_bytes`` cap is set, admission is gated on the byte budget as
    well as the physical free list, so cheaper (narrower) blocks admit
    proportionally more tokens into the same modeled HBM budget.

    Blocks that fail integrity verification are **quarantined**: removed
    from circulation (neither owned nor free) until ``rehabilitate`` puts
    them back — the engine scrubs (zeroes + re-checksums) the device block
    first.
    """

    def __init__(self, num_blocks: int, max_slots: int, max_logical: int,
                 block_l: int, block_bytes: int = 0,
                 budget_bytes: Optional[int] = None):
        assert num_blocks >= 1 and max_slots >= 1 and max_logical >= 1
        self.num_blocks = int(num_blocks)
        self.block_l = int(block_l)
        self.block_bytes = int(block_bytes)
        self.budget_bytes = None if budget_bytes is None else int(budget_bytes)
        self.max_slots = int(max_slots)
        self.max_logical = int(max_logical)
        # LIFO free list: physical ids 1..num_blocks (0 is trash).
        self._free: List[int] = list(range(self.num_blocks, 0, -1))
        self._owned: Dict[int, List[int]] = {}  # slot -> physical ids
        self._rate: Dict[int, int] = {}         # slot -> bytes per block
        self._quarantined: List[int] = []
        self.tables = np.full((max_slots, max_logical), TRASH_BLOCK,
                              np.int32)
        self.peak_used = 0
        self._peak_bytes = 0
        # Telemetry sink (repro.obs.Obs), installed by the scheduler;
        # watermark gauges refresh on every alloc/free so the exported
        # metrics track occupancy without polling.
        self.obs: Optional[object] = None

    def _obs_watermarks(self) -> None:
        obs = self.obs
        if obs is None:
            return
        reg = obs.registry
        reg.gauge("pool_used_blocks", "allocated physical blocks"
                  ).set(self.used_blocks)
        reg.gauge("pool_free_blocks", "free-list physical blocks"
                  ).set(self.free_blocks)
        reg.gauge("pool_quarantined_blocks",
                  "blocks held out pending scrub"
                  ).set(len(self._quarantined))
        reg.gauge("pool_used_bytes",
                  "dense-packed bytes live (per-slot geometry pricing)",
                  unit="B").set(self.used_bytes)

    # -- accounting ------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free) - len(self._quarantined)

    @property
    def quarantined_blocks(self) -> List[int]:
        return list(self._quarantined)

    @property
    def used_bytes(self) -> int:
        """Dense-packed bytes live right now, priced per slot geometry."""
        return sum(len(owned) * self._rate.get(slot, self.block_bytes)
                   for slot, owned in self._owned.items())

    def slot_rate(self, slot: int) -> int:
        """Bytes one block costs for ``slot`` (its admission geometry)."""
        return self._rate.get(slot, self.block_bytes)

    def bytes_for(self, n_tokens: int, block_bytes: Optional[int] = None
                  ) -> int:
        """Dense-packed bytes a request holding ``n_tokens`` KV rows pins
        (block-granular — partial blocks occupy whole blocks)."""
        rate = self.block_bytes if block_bytes is None else int(block_bytes)
        return blocks_for(n_tokens, self.block_l) * rate

    def stats(self) -> PoolStats:
        cap = (self.budget_bytes if self.budget_bytes is not None
               else self.num_blocks * self.block_bytes)
        used = self.used_bytes
        return PoolStats(num_blocks=self.num_blocks,
                         free_blocks=self.free_blocks,
                         used_blocks=self.used_blocks,
                         peak_used=self.peak_used,
                         quarantined=len(self._quarantined),
                         block_bytes=self.block_bytes,
                         capacity_bytes=cap,
                         used_bytes=used,
                         free_bytes=max(0, cap - used),
                         peak_bytes=self._peak_bytes,
                         budget_bytes=self.budget_bytes)

    def slot_blocks(self, slot: int) -> int:
        return len(self._owned.get(slot, ()))

    def owner_of(self, phys: int) -> Optional[int]:
        """Slot owning physical block ``phys``; None if free/quarantined."""
        for slot, owned in self._owned.items():
            if phys in owned:
                return slot
        return None

    def owned_ids(self) -> List[int]:
        """Every currently allocated physical block id."""
        return [p for owned in self._owned.values() for p in owned]

    def _bytes_ok(self, extra_blocks: int, rate: int) -> bool:
        if self.budget_bytes is None:
            return True
        return self.used_bytes + extra_blocks * rate <= self.budget_bytes

    def can_admit(self, n_tokens: int, block_bytes: Optional[int] = None,
                  reserve_blocks: int = 0) -> bool:
        """Admission gate: blocks covering the prompt KV rows *and* the
        first decode token must fit, so a fresh request always takes its
        first step without immediately preempting someone. (That is one
        extra block only when the prompt lands exactly on a block
        boundary — a blanket +1 would leave one slot's worth of pool
        permanently idle at full residency.)

        ``block_bytes`` prices the candidate at its own (possibly
        downshifted) geometry against the byte budget; ``reserve_blocks``
        holds back blocks the currently running requests will need for
        their next step (the preemption-storm guard's no-thrash
        headroom)."""
        rate = self.block_bytes if block_bytes is None else int(block_bytes)
        need = blocks_for(n_tokens + 1, self.block_l)
        return (need + reserve_blocks <= self.free_blocks
                and self._bytes_ok(need, rate))

    # -- allocation ------------------------------------------------------

    def _check_slot(self, slot: int) -> int:
        slot = int(slot)
        if not 0 <= slot < self.max_slots:
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {self.max_slots})")
        return slot

    def alloc_upto(self, slot: int, n_tokens: int,
                   block_bytes: Optional[int] = None) -> bool:
        """Grow ``slot``'s table to cover positions [0, n_tokens).

        Returns False (allocating nothing) if the pool cannot supply every
        missing block — the caller then preempts someone and retries.
        ``block_bytes`` registers the slot's per-block byte rate on its
        first allocation (the admission geometry); growth reuses the
        registered rate.
        """
        slot = self._check_slot(slot)
        if n_tokens < 0:
            raise ValueError(f"n_tokens must be >= 0, got {n_tokens}")
        need = blocks_for(n_tokens, self.block_l)
        if need > self.max_logical:
            raise ValueError(
                f"request needs {need} blocks > max_logical "
                f"{self.max_logical} (engine max_len too small)")
        owned = self._owned.setdefault(slot, [])
        if slot not in self._rate:
            self._rate[slot] = (self.block_bytes if block_bytes is None
                                else int(block_bytes))
        missing = need - len(owned)
        if missing <= 0:
            return True
        if missing > len(self._free):
            return False
        if not self._bytes_ok(missing, self._rate[slot]):
            return False
        for _ in range(missing):
            phys = self._free.pop()
            self.tables[slot, len(owned)] = phys
            owned.append(phys)
        self.peak_used = max(self.peak_used, self.used_blocks)
        self._peak_bytes = max(self._peak_bytes, self.used_bytes)
        self._obs_watermarks()
        return True

    def free_slot(self, slot: int, quarantine: Iterable[int] = ()) -> int:
        """Release every block ``slot`` owns (finish or preemption);
        returns the number of blocks recycled to the free list.

        Raises on double-free (a slot that owns nothing) — a freed slot
        whose blocks were already recycled must never be freed again, or
        its old physical ids would alias another request's blocks.
        ``quarantine`` names owned blocks that failed integrity
        verification: they are held out of circulation instead of
        returning to the free list (see ``rehabilitate``).
        """
        slot = self._check_slot(slot)
        if slot not in self._owned:
            raise KeyError(f"double free: slot {slot} owns no blocks")
        # Validate the quarantine set *before* mutating anything: a
        # rejected call must leave the slot's ownership intact.
        bad = set(int(p) for p in quarantine)
        if TRASH_BLOCK in bad:
            raise ValueError("the reserved trash block cannot be "
                             "quarantined")
        unknown = bad - set(self._owned[slot])
        if unknown:
            raise ValueError(f"cannot quarantine blocks {sorted(unknown)}: "
                             f"not owned by slot {slot}")
        owned = self._owned.pop(slot)
        self._rate.pop(slot, None)
        recycled = [p for p in owned if p not in bad]
        self._free.extend(reversed(recycled))
        self._quarantined.extend(sorted(bad))
        self.tables[slot, :] = TRASH_BLOCK
        if bad and self.obs is not None:
            self.obs.event("quarantine", slot=slot, blocks=sorted(bad))
        self._obs_watermarks()
        return len(recycled)

    def rehabilitate(self, phys: int) -> None:
        """Return a quarantined block to the free list. The caller must
        have scrubbed the device block first (zeroed + re-checksummed:
        ``PagedEngine.scrub_block``)."""
        phys = int(phys)
        if phys == TRASH_BLOCK:
            raise ValueError("the reserved trash block is never pooled")
        if phys not in self._quarantined:
            raise ValueError(f"block {phys} is not quarantined")
        self._quarantined.remove(phys)
        self._free.append(phys)
        if self.obs is not None:
            self.obs.event("rehabilitate", block=phys)
        self._obs_watermarks()

    def reset(self) -> None:
        for slot in list(self._owned):
            self.free_slot(slot)

    # -- debug invariants ------------------------------------------------

    def verify_invariants(self) -> None:
        """Raise AssertionError unless the allocator is self-consistent:
        every physical id 1..num_blocks is exactly one of free / owned by
        exactly one slot / quarantined, tables mirror the owned lists,
        and the byte accounting respects the budget. Used by the chaos
        tests after every injected fault."""
        free = list(self._free)
        owned_all = self.owned_ids()
        quar = list(self._quarantined)
        ids = free + owned_all + quar
        assert len(ids) == len(set(ids)), (
            f"block id owned twice: free={free} owned={owned_all} "
            f"quarantined={quar}")
        assert set(ids) == set(range(1, self.num_blocks + 1)), (
            f"block ids leaked: have {sorted(ids)}")
        assert TRASH_BLOCK not in ids
        for slot, owned in self._owned.items():
            row = self.tables[slot]
            assert list(row[:len(owned)]) == owned, (
                f"slot {slot} table/owned mismatch: "
                f"{row[:len(owned)].tolist()} vs {owned}")
            assert (row[len(owned):] == TRASH_BLOCK).all(), (
                f"slot {slot} table has entries past its allocation")
        for slot in range(self.max_slots):
            if slot not in self._owned:
                assert (self.tables[slot] == TRASH_BLOCK).all(), (
                    f"unowned slot {slot} has live table entries")
        if self.budget_bytes is not None:
            assert self.used_bytes <= self.budget_bytes, (
                f"byte budget exceeded: {self.used_bytes} > "
                f"{self.budget_bytes}")
