"""Unified decoder model over the assigned architecture families.

One `DecoderModel` covers dense / MoE / SSM / hybrid / audio / vlm configs:
the repeating layer-kind *period* (e.g. gemma3's 5xlocal+global) is scanned
with stacked parameters via core.stash.sfp_scan, so (a) HLO size is
depth-independent and (b) the cross-pass activation stash is exactly the
SFP-compressed containers — the paper's technique as a first-class feature
of the training step. Remainder layers (n_layers % len(period)) are
unrolled.

The same parameter tree supports three views (params / ShapeDtypeStruct /
logical sharding axes) via common.ParamFactory — the dry-run lowers the
full-size models without allocating anything.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codecs, policies
from repro.configs.base import ArchConfig, GLOBAL, LOCAL, RGLRU, SSD
from repro.core import containers, stash
from repro.distributed import sharding as shd
from repro.models import attention, common, mamba2, moe, rglru

MOE_LB_COEF = 0.01
MOE_Z_COEF = 1e-3


class RunState(NamedTuple):
    """Per-step dynamic inputs controlling precision behaviour.

    ``pol`` is the policy's forward view — an opaque pytree produced by
    ``Policy.forward_view`` and only ever handed back to policy methods;
    the model never inspects it.
    """

    key: jax.Array  # PRNG key for this step
    pol: Any        # policy forward view (possibly empty)


def scope_dims(cfg: ArchConfig) -> policies.ScopeDims:
    return policies.ScopeDims.for_dtype(
        cfg.compute_dtype, n_periods=cfg.n_periods,
        n_rem=len(cfg.remainder))


def init_run_state(cfg: ArchConfig, key: jax.Array,
                   policy=None) -> RunState:
    """A fresh-state RunState for ``policy`` (default: full precision)."""
    pol = policies.coerce(policy)
    dims = scope_dims(cfg)
    st = pol.init_state(dims)
    cview = pol.control_view(st.ctrl, dims)
    return RunState(key=key, pol=pol.forward_view(st.learn, cview, dims))


def _zero_moe_aux():
    z = jnp.zeros((), jnp.float32)
    return {"moe_lb_loss": z, "moe_z_loss": z, "moe_drop_frac": z}


def _kvcache():
    # Deferred: serve.kvcache sits above models in the layer order (it
    # imports models.attention); a module-level import would be cyclic-ish.
    from repro.serve import kvcache
    return kvcache


class DecoderModel:
    def __init__(self, cfg: ArchConfig, policy=None, mesh=None,
                 rules=None, kv_container: Optional[str] = None,
                 stash_containers=None):
        """``policy`` is a precision policy: a ``policies.Policy``, a
        registry name (``"qm"``, ``"qm+qe"``, ...), a legacy
        ``core.sfp.SFPPolicy`` (deprecated shim), or None for full
        precision. ``kv_container`` selects a registry codec for the
        serving KV cache: prefill packs the cache, decode splices packed
        token rows and attends through the fused decompress-attend kernel
        (SFP codecs on pallas/interpret) or the unpack fallback. None =
        raw bf16/fp32 cache.

        ``stash_containers`` (optional, one codec name per period) packs
        each period's activation stash at its *own* container geometry —
        per-layer realized containers instead of one network-wide choice.
        Container geometry is static under jit, so the period scan is
        chained into per-period segments (HLO grows with n_periods);
        derive the tuple from the live policy state with ``stash_plan``
        and rebuild the jitted step when it changes (learned bitlengths
        move slowly, so re-lowering is rare).
        """
        self.cfg = cfg
        self.policy = policies.coerce(policy)
        self.mesh = mesh  # enables SPMD-manual paths (sharded embed lookup)
        self.rules = rules
        self.kv_container = kv_container
        if stash_containers is not None:
            stash_containers = tuple(stash_containers)
            if len(stash_containers) != cfg.n_periods:
                raise ValueError(
                    f"stash_containers needs one codec per period "
                    f"({cfg.n_periods}), got {len(stash_containers)}")
        self.stash_containers = stash_containers
        self.man_bits = containers.spec_for(cfg.compute_dtype).man_bits
        self.dims = scope_dims(cfg)

    def run_state(self, key: jax.Array,
                  pstate: Optional[policies.PolicyState] = None) -> RunState:
        """Build the forward view for this model's policy (fresh state if
        ``pstate`` is None — the train step builds its own from live
        state)."""
        pol = self.policy
        if pstate is None:
            pstate = pol.init_state(self.dims)
        cview = pol.control_view(pstate.ctrl, self.dims)
        return RunState(key=key,
                        pol=pol.forward_view(pstate.learn, cview, self.dims))

    # ------------------------------------------------------------------
    # Parameter construction (params / shapes / axes share one code path)
    # ------------------------------------------------------------------

    def _slot_init(self, p: common.ParamFactory, kind: str):
        cfg = self.cfg
        slot: Dict[str, Any] = {"pre_norm": common.rmsnorm_init(p, cfg.d_model)}
        if kind in (GLOBAL, LOCAL):
            slot["attn"] = attention.attn_init(p, cfg)
        elif kind == SSD:
            slot["ssd"] = mamba2.ssd_init(p, cfg)
            return slot  # mamba2 blocks carry no separate MLP
        elif kind == RGLRU:
            slot["rglru"] = rglru.rglru_init(p, cfg)
        else:  # pragma: no cover
            raise ValueError(kind)
        slot["mlp_norm"] = common.rmsnorm_init(p, cfg.d_model)
        if cfg.is_moe:
            slot["moe"] = moe.moe_init(p, cfg)
        else:
            slot["mlp"] = common.mlp_init(p, cfg.d_model, cfg.d_ff, cfg.glu)
        return slot

    def _build_period(self, p: common.ParamFactory):
        return {f"slot{i}": self._slot_init(p, kind)
                for i, kind in enumerate(self.cfg.period)}

    def build(self, mode: str, key: Optional[jax.Array] = None):
        cfg = self.cfg
        dtype = cfg.compute_dtype

        if mode == common.MODE_PARAMS:
            pf = common.ParamFactory(mode, jax.random.fold_in(key, 0), dtype)
            keys = jax.random.split(jax.random.fold_in(key, 1), cfg.n_periods)
            periods = jax.vmap(
                lambda k: self._build_period(
                    common.ParamFactory(mode, k, dtype)))(keys)
        else:
            pf = common.ParamFactory(mode, dtype=dtype)
            one = self._build_period(common.ParamFactory(mode, dtype=dtype))
            if mode == common.MODE_SHAPE:
                periods = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (cfg.n_periods,) + tuple(s.shape), s.dtype), one)
            else:  # axes
                periods = jax.tree.map(
                    lambda a: ("layers",) + tuple(a), one,
                    is_leaf=lambda a: isinstance(a, tuple))

        params: Dict[str, Any] = {
            "embed": common.embed_init(pf, cfg.padded_vocab, cfg.d_model),
            "final_norm": common.rmsnorm_init(pf, cfg.d_model),
            "periods": periods,
        }
        if not cfg.tie_embeddings:
            params["head"] = pf((cfg.d_model, cfg.padded_vocab),
                                ("embed_r", "vocab"))
        if cfg.remainder:
            rem_pf = (common.ParamFactory(mode, jax.random.fold_in(key, 2),
                                          dtype)
                      if mode == common.MODE_PARAMS
                      else common.ParamFactory(mode, dtype=dtype))
            params["rem"] = {f"slot{i}": self._slot_init(rem_pf, kind)
                             for i, kind in enumerate(cfg.remainder)}
        return params

    def init(self, key: jax.Array):
        return self.build(common.MODE_PARAMS, key)

    def param_shapes(self):
        return self.build(common.MODE_SHAPE)

    def param_axes(self):
        return self.build(common.MODE_AXES)

    # ------------------------------------------------------------------
    # Weight-side fake-quant (exact VJP for learned policies, paper §IV-A)
    # ------------------------------------------------------------------

    def _quantize_weights(self, slot_params, pslice, key):
        pol = self.policy
        if not pol.quantizes_weights:
            return slot_params

        def quant(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            salt = zlib.crc32(name.encode()) % (2 ** 31)
            return pol.quantize_weight(leaf, pslice,
                                       jax.random.fold_in(key, salt),
                                       self.dims)

        return jax.tree_util.tree_map_with_path(quant, slot_params)

    # ------------------------------------------------------------------
    # Layer application
    # ------------------------------------------------------------------

    def _apply_slot(self, slot_params, h, kind, *, positions, prefix_len,
                    pslice, key):
        cfg = self.cfg
        sp = self._quantize_weights(slot_params, pslice, key)
        aux = _zero_moe_aux()
        extras_loss = jnp.zeros((), jnp.float32)

        hn = common.rmsnorm(sp["pre_norm"], h)
        if kind in (GLOBAL, LOCAL):
            h = h + attention.attention_train(
                sp["attn"], hn, cfg, kind=kind, positions=positions,
                prefix_len=prefix_len)
        elif kind == SSD:
            h = h + mamba2.ssd_forward(sp["ssd"], hn, cfg)
            return h, extras_loss, aux
        elif kind == RGLRU:
            h = h + rglru.rglru_forward(sp["rglru"], hn, cfg)

        hm = common.rmsnorm(sp["mlp_norm"], h)
        if cfg.is_moe:
            out, moe_aux = moe.moe_forward(sp["moe"], hm, cfg)
            h = h + out
            aux = moe_aux
            extras_loss = (MOE_LB_COEF * moe_aux["moe_lb_loss"]
                           + MOE_Z_COEF * moe_aux["moe_z_loss"])
        else:
            h = h + common.mlp(sp["mlp"], hm, cfg.act, cfg.glu)
        return h, extras_loss, aux

    # ------------------------------------------------------------------
    # Stash codec (compress/decompress at period boundaries)
    # ------------------------------------------------------------------

    def _make_codec(self, dtype):
        del dtype  # carried by the packed representation itself
        if not self.policy.enabled:
            return stash.identity_compress, stash.identity_decompress, None
        return self._codec_fns(codecs.get(self.policy.container))

    def _codec_fns(self, codec):
        """Stash compress/decompress/stash_grad closures for one codec."""
        pol = self.policy
        dims = self.dims

        def compress(h, x):
            # Fused quantize+pack: the mantissa-bitlength signal rides into
            # the pack kernel, one HBM read of the activation. Exponent
            # truncation (QE/BitWave) happens on the way in — the packed
            # container stores the already-clamped exponents, which is what
            # Gecko-side accounting compresses.
            d = pol.act_decision(x["pol"], x["key"], dims)
            if pol.adapts_exponent:
                h = containers.truncate_exponent(h, d.exp_bits)
            return codec.pack(h, bits=d.man_bits)

        def decompress(c, x):
            del x
            return codec.unpack(c)

        stash_grad = None
        if pol.has_stash_grad:
            def stash_grad(dh, c, x):  # noqa: F811
                h_q = decompress(c, x)
                return {"pol": pol.stash_grad(dh, h_q, x["pol"], dims)}

        return compress, decompress, stash_grad

    def stash_plan(self, pstate: Optional[policies.PolicyState] = None
                   ) -> Tuple[str, ...]:
        """Per-period dense container names realized from the policy's
        current per-layer decisions.

        Host-side: call it outside jit (fresh state when ``pstate`` is
        None), pass the result as ``stash_containers`` to a new
        DecoderModel (or rebuild the jitted step) whenever the plan
        changes. Each period's learned (man_bits, exp_bits) maps through
        ``codecs.dense_name`` — so a period that converged to 2 mantissa /
        4 exponent bits stashes 7-bit dense payloads while a
        precision-hungry neighbour keeps a wider container.
        """
        pol = self.policy
        st = pol.init_state(self.dims) if pstate is None else pstate
        return tuple(codecs.dense_name(m, e)
                     for m, e in pol.layer_decisions(st, self.dims))

    # ------------------------------------------------------------------
    # Training / prefill forward
    # ------------------------------------------------------------------

    def forward(self, params, tokens: jax.Array, run: RunState,
                cond_embeddings: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Full-sequence forward. Returns (logits over token positions, metrics)."""
        shd.set_active_mesh(self.mesh, self.rules)
        cfg = self.cfg
        B, S = tokens.shape
        P = cfg.prefix_tokens if cond_embeddings is not None else 0

        scale = (cfg.d_model ** 0.5) if cfg.emb_scale else None
        h = common.embed(params["embed"], tokens, scale, mesh=self.mesh)
        if P:
            h = jnp.concatenate(
                [cond_embeddings.astype(h.dtype), h], axis=1)
        S_tot = h.shape[1]
        positions = jnp.arange(S_tot)

        compress, decompress, stash_grad = self._make_codec(
            cfg.compute_dtype)

        period = cfg.period

        pol = self.policy

        def period_fn(carry, x):
            h, extras = carry
            aux_sum = _zero_moe_aux()
            for i, kind in enumerate(period):
                h, eloss, aux = self._apply_slot(
                    x["params"][f"slot{i}"], h, kind,
                    positions=positions, prefix_len=P,
                    pslice=x.get("pol"),
                    key=jax.random.fold_in(x["key"], i))
                extras = extras + eloss
                aux_sum = jax.tree.map(lambda a, b: a + b, aux_sum, aux)
            return (h, extras), aux_sum

        keys = jax.random.split(run.key, cfg.n_periods)
        xs = {"params": params["periods"], "key": keys}
        if pol.enabled:
            xs["pol"] = pol.scan_slices(run.pol, self.dims)

        extras0 = jnp.zeros((), jnp.float32)
        if pol.enabled and self.stash_containers is not None:
            # Per-layer containers: each period's stash packs at its own
            # (static) geometry, so the scan is chained into one sfp_scan
            # segment per period — same custom-VJP remat structure, one
            # codec per segment. aux stacks back to the scanned layout.
            carry = (h, extras0)
            aux_parts = []
            for i, cname in enumerate(self.stash_containers):
                comp, decomp, sgrad = self._codec_fns(codecs.get(cname))
                xs_i = jax.tree.map(lambda a: a[i:i + 1], xs)
                carry, aux_i = stash.sfp_scan(period_fn, comp, decomp,
                                              carry, xs_i, stash_grad=sgrad)
                aux_parts.append(aux_i)
            h, extras = carry
            aux = jax.tree.map(lambda *a: jnp.concatenate(a, axis=0),
                               *aux_parts)
        else:
            (h, extras), aux = stash.sfp_scan(
                period_fn, compress, decompress, (h, extras0), xs,
                stash_grad=stash_grad)

        # Remainder layers (unrolled, decision applied straight-through at
        # the stash boundary).
        for i, kind in enumerate(cfg.remainder):
            rs = (pol.rem_slice(run.pol, i, self.dims) if pol.enabled
                  else None)
            if pol.enabled:
                d = pol.act_decision(
                    rs, jax.random.fold_in(run.key, 1000 + i), self.dims)
                h = policies.apply_decision_ste(
                    h, d, self.dims, adapts_exponent=pol.adapts_exponent)
            h, eloss, _aux = self._apply_slot(
                params["rem"][f"slot{i}"], h, kind, positions=positions,
                prefix_len=P, pslice=rs,
                key=jax.random.fold_in(run.key, 2000 + i))
            extras = extras + eloss

        h = common.rmsnorm(params["final_norm"], h)
        if P:
            h = h[:, P:]
        logits = common.unembed(params, h, tied=cfg.tie_embeddings,
                                softcap=cfg.final_softcap,
                                valid_vocab=cfg.vocab)
        metrics = {"moe_aux_loss": extras}
        for k in ("moe_lb_loss", "moe_z_loss", "moe_drop_frac"):
            metrics[k] = aux[k].mean() if cfg.is_moe else jnp.zeros((), jnp.float32)
        return logits, metrics

    def loss(self, params, batch: Dict[str, jax.Array], run: RunState
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, metrics = self.forward(
            params, batch["tokens"], run,
            cond_embeddings=batch.get("cond_embeddings"))
        xent = common.softmax_xent(logits, batch["labels"])
        loss = xent + metrics["moe_aux_loss"]
        metrics = dict(metrics, xent=xent)
        return loss, metrics

    # ------------------------------------------------------------------
    # Serving: cache init + prefill + decode
    # ------------------------------------------------------------------

    def _slot_cache(self, kind: str, batch: int, max_len: int, spec_only: bool):
        cfg = self.cfg
        dt = cfg.compute_dtype
        if kind in (GLOBAL, LOCAL):
            if self.kv_container is not None:
                kvc = _kvcache()
                f = (kvc.packed_cache_spec if spec_only
                     else kvc.packed_cache_init)
                return f(cfg, kind, batch, max_len, self.kv_container)
            f = attention.cache_spec if spec_only else attention.cache_init
            return f(cfg, kind, batch, max_len, dt)
        if kind == SSD:
            f = mamba2.ssd_cache_spec if spec_only else mamba2.ssd_cache_init
            return f(cfg, batch, dt)
        f = rglru.lru_cache_spec if spec_only else rglru.lru_cache_init
        return f(cfg, batch, dt)

    def init_cache(self, batch: int, max_len: int, spec_only: bool = False):
        cfg = self.cfg
        per = {f"slot{i}": self._slot_cache(k, batch, max_len, spec_only)
               for i, k in enumerate(cfg.period)}
        if spec_only:
            periods = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (cfg.n_periods,) + tuple(s.shape), s.dtype), per)
        else:
            periods = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), per)
        cache = {"periods": periods}
        if cfg.remainder:
            cache["rem"] = {f"slot{i}": self._slot_cache(k, batch, max_len,
                                                         spec_only)
                            for i, k in enumerate(cfg.remainder)}
        return cache

    def _decode_slot(self, slot_params, h, slot_cache, pos, kind,
                     tables=None, prefix_planes=None):
        cfg = self.cfg
        hn = common.rmsnorm(slot_params["pre_norm"], h)
        if kind in (GLOBAL, LOCAL):
            if tables is not None and kind == GLOBAL:
                # Paged pool: blocks gathered through the block table
                # inside the kernel; local ring layers stay per-slot
                # contiguous (window-bounded) and take the packed path
                # below with per-row positions.
                out, new_cache = _kvcache().attention_decode_paged(
                    slot_params["attn"], hn, slot_cache, tables, pos, cfg,
                    container=self.kv_container,
                    prefix_planes=prefix_planes)
            elif self.kv_container is not None:
                out, new_cache = _kvcache().attention_decode_packed(
                    slot_params["attn"], hn, slot_cache, pos, cfg, kind=kind,
                    container=self.kv_container,
                    prefix_planes=prefix_planes)
            else:
                out, new_cache = attention.attention_decode(
                    slot_params["attn"], hn, slot_cache, pos, cfg, kind=kind)
            h = h + out
        elif kind == SSD:
            out, new_cache = mamba2.ssd_decode(slot_params["ssd"], hn,
                                               slot_cache, cfg)
            return h + out, new_cache
        else:
            out, new_cache = rglru.rglru_decode(slot_params["rglru"], hn,
                                                slot_cache, cfg)
            h = h + out
        hm = common.rmsnorm(slot_params["mlp_norm"], h)
        if cfg.is_moe:
            h = h + moe.moe_decode(slot_params["moe"], hm, cfg)
        else:
            h = h + common.mlp(slot_params["mlp"], hm, cfg.act, cfg.glu)
        return h, new_cache

    def _prefill_slot(self, slot_params, h, kind, *, positions, prefix_len,
                      max_len):
        cfg = self.cfg
        hn = common.rmsnorm(slot_params["pre_norm"], h)
        if kind in (GLOBAL, LOCAL):
            out, (k, v) = attention.attention_train(
                slot_params["attn"], hn, cfg, kind=kind, positions=positions,
                prefix_len=prefix_len, return_kv=True)
            h = h + out
            if self.kv_container is not None:
                # Packed caches round up to fused-kernel block multiples;
                # prefill must produce the same allocation as init_cache.
                L = _kvcache().cache_len(cfg, kind, max_len)
            else:
                L = min(max_len, cfg.window) if kind == LOCAL else max_len
            if kind == LOCAL:
                k, v = attention.ring_pack_kv(k, v, L)
            else:
                pad = L - k.shape[1]
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = attention.KVCache(k=k.astype(cfg.compute_dtype),
                                          v=v.astype(cfg.compute_dtype))
            if self.kv_container is not None:
                new_cache = _kvcache().pack_prefill_cache(
                    new_cache, self.kv_container)
        elif kind == SSD:
            out, new_cache = mamba2.ssd_forward(slot_params["ssd"], hn, cfg,
                                                return_cache=True)
            return h + out, new_cache
        else:
            out, new_cache = rglru.rglru_forward(slot_params["rglru"], hn,
                                                 cfg, return_cache=True)
            h = h + out
        hm = common.rmsnorm(slot_params["mlp_norm"], h)
        if cfg.is_moe:
            out, _aux = moe.moe_forward(slot_params["moe"], hm, cfg)
            h = h + out
        else:
            h = h + common.mlp(slot_params["mlp"], hm, cfg.act, cfg.glu)
        return h, new_cache

    def prefill(self, params, tokens: jax.Array, max_len: int,
                cond_embeddings: Optional[jax.Array] = None):
        """Process a full prompt, returning (last-position logits, cache).

        ``max_len`` sizes the global-attention KV cache (prompt + decode
        budget). The prompt (with any multimodal prefix) must fit max_len.
        """
        shd.set_active_mesh(self.mesh, self.rules)
        cfg = self.cfg
        B, S = tokens.shape
        P = cfg.prefix_tokens if cond_embeddings is not None else 0
        scale = (cfg.d_model ** 0.5) if cfg.emb_scale else None
        h = common.embed(params["embed"], tokens, scale, mesh=self.mesh)
        if P:
            h = jnp.concatenate([cond_embeddings.astype(h.dtype), h], axis=1)
        positions = jnp.arange(h.shape[1])
        max_len = max(max_len, h.shape[1])  # prefix tokens extend the cache

        def period_fn(h, p):
            caches = {}
            for i, kind in enumerate(cfg.period):
                h, c = self._prefill_slot(p[f"slot{i}"], h, kind,
                                          positions=positions, prefix_len=P,
                                          max_len=max_len)
                caches[f"slot{i}"] = c
            return h, caches

        h, period_caches = jax.lax.scan(period_fn, h, params["periods"])
        cache = {"periods": period_caches}
        if cfg.remainder:
            cache["rem"] = {}
            for i, kind in enumerate(cfg.remainder):
                h, c = self._prefill_slot(params["rem"][f"slot{i}"], h, kind,
                                          positions=positions, prefix_len=P,
                                          max_len=max_len)
                cache["rem"][f"slot{i}"] = c
        h = common.rmsnorm(params["final_norm"], h)
        logits = common.unembed(params, h[:, -1:], tied=cfg.tie_embeddings,
                                softcap=cfg.final_softcap,
                                valid_vocab=cfg.vocab)
        return logits, cache

    def decode_step(self, params, cache, token: jax.Array, pos: jax.Array,
                    tables: Optional[jax.Array] = None,
                    prefix_planes: Optional[int] = None
                    ) -> Tuple[jax.Array, Any]:
        """One decode step. token: (B, 1) int32; pos: scalar int32 absolute
        position (prefix + generated so far). Returns (logits (B, 1, V), cache).

        With ``tables`` (B, nb) this is the continuous-batching paged
        step: ``pos`` becomes (B,) per-slot positions (idle slots carry
        pos 0 and a trash-block table row; their logits are garbage the
        engine discards), global attention layers in ``cache`` hold
        ``kvcache.PagedKV`` pool slices addressed through the tables, and
        local ring / SSD / RGLRU layers hold per-slot dense state.
        Requires ``kv_container`` in that mode.

        ``prefix_planes`` makes every packed-attention *read* expand only
        the leading P' payload bits (the speculative draft mode); K/V
        writes and all recurrent state updates stay full-fidelity.
        Requires ``kv_container``.
        """
        assert prefix_planes is None or self.kv_container is not None, \
            "prefix_planes (draft reads) needs a packed kv_container"
        shd.set_active_mesh(self.mesh, self.rules)
        cfg = self.cfg
        scale = (cfg.d_model ** 0.5) if cfg.emb_scale else None
        h = common.embed(params["embed"], token, scale, mesh=self.mesh)

        def period_fn(h, x):
            p, c = x
            new_c = {}
            for i, kind in enumerate(cfg.period):
                h, nc = self._decode_slot(p[f"slot{i}"], h, c[f"slot{i}"],
                                          pos, kind, tables=tables,
                                          prefix_planes=prefix_planes)
                new_c[f"slot{i}"] = nc
            return h, new_c

        h, new_periods = jax.lax.scan(
            period_fn, h, (params["periods"], cache["periods"]))
        new_cache = {"periods": new_periods}
        if cfg.remainder:
            new_cache["rem"] = {}
            for i, kind in enumerate(cfg.remainder):
                h, nc = self._decode_slot(params["rem"][f"slot{i}"], h,
                                          cache["rem"][f"slot{i}"], pos,
                                          kind, tables=tables,
                                          prefix_planes=prefix_planes)
                new_cache["rem"][f"slot{i}"] = nc
        h = common.rmsnorm(params["final_norm"], h)
        logits = common.unembed(params, h, tied=cfg.tie_embeddings,
                                softcap=cfg.final_softcap,
                                valid_vocab=cfg.vocab)
        return logits, new_cache

    def decode_step_paged(self, params, cache, token: jax.Array,
                          pos: jax.Array, tables: jax.Array,
                          prefix_planes: Optional[int] = None
                          ) -> Tuple[jax.Array, Any]:
        """Paged decode step (see ``decode_step`` with ``tables``)."""
        assert self.kv_container is not None, "paged decode needs a codec"
        return self.decode_step(params, cache, token, pos, tables=tables,
                                prefix_planes=prefix_planes)
