"""Pack/unpack microbenchmark: prefill / insert / generate phases.

Maxtext-style decomposition of the serving loop into its three cache
operations, measured per container geometry directly at the codec layer
(no model around it — this isolates the container's own cost):

  * **prefill** — pack a whole (B, L, D) bf16 context into the packed
    cache layout (the prompt-ingest write path);
  * **insert**  — splice a packed (B, 1, D) token row into the cache
    ring (the per-decode-step write path). A single row splice is
    dispatch-dominated (microseconds of work under ~0.1 ms of launch
    overhead, reading as a bogus ~0.07 GB/s), so the phase times one
    jitted batch of ``INSERT_K`` consecutive splices and reports the
    amortized per-insert ms/GB/s — the figure a decode burst actually
    pays;
  * **generate** — unpack the whole packed cache back to bf16 (the
    per-decode-step read path the ref fallback pays every token, and the
    flash-decode kernels stream tile by tile).

Each phase reports median ms, the bytes it moves (dense side + packed
side, from the container's PackFields geometry), and the achieved GB/s —
the roofline view: pack/unpack are pure byte-shuffles, so achieved GB/s
against the machine's streaming bandwidth is the efficiency of the
bit-plane expansion itself.

Geometries swept: dense bit-plane ``sfp-m1e2`` (4-bit payload),
``sfp-m2e4`` (7), ``sfp-m3e5`` (9, two plane blocks) and the fixed-lane
``sfp8``/``sfp16`` words; backends ``ref`` (XLA) and ``interpret`` (the
Pallas kernels under the interpreter, at a reduced shape — correctness
cross-check and kernel-shape coverage, not a speed claim).

``--profile`` wraps every ref-backend phase in a ``jax.profiler`` trace
(one capture per geometry/phase) under ``experiments/traces/
decode_micro/`` — nightly CI uploads that directory as an artifact.
Emitted as BENCH_decode_micro.json standalone or via benchmarks/run.py.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

GEOMETRIES = ("sfp-m1e2", "sfp-m2e4", "sfp-m3e5", "sfp8", "sfp16")
# Consecutive row splices timed as one jitted call in the insert phase;
# its ms/bytes are reported per splice. Must stay well under L - pos.
INSERT_K = 16
# (B, L, D) per backend: D = 4 groups of 128 lanes on ref; interpret runs
# the Pallas kernels under the interpreter, so it gets a small shape.
SHAPES = {"ref": (4, 512, 512), "interpret": (1, 128, 128)}
ITERS = {"ref": 10, "interpret": 2}
OUT = Path(__file__).resolve().parent.parent / "BENCH_decode_micro.json"
TRACE_DIR = (Path(__file__).resolve().parent.parent / "experiments"
             / "traces" / "decode_micro")


def _median_ms(fn, iters):
    fn()  # compile + warm caches
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def _packed_bytes(fields, n_values):
    """Dense-packed bytes for ``n_values`` lanes: payload + group bases."""
    groups = n_values // 128
    return n_values * fields.payload_bits // 8 + groups


def _phase_bytes(fields, B, L, D, itemsize):
    """Bytes moved per phase: dense side + packed side (read + write)."""
    full, row = B * L * D, B * D
    return {
        "prefill": full * itemsize + _packed_bytes(fields, full),
        "insert": row * itemsize + _packed_bytes(fields, row),
        "generate": _packed_bytes(fields, full) + full * itemsize,
    }


def run(profile: bool = False) -> dict:
    from repro import codecs
    from repro.kernels import ops
    from repro.serve.kvcache import _splice

    dtype = jnp.bfloat16
    itemsize = jnp.dtype(dtype).itemsize
    out = {"dtype": str(jnp.dtype(dtype)), "geometries": list(GEOMETRIES),
           "shapes": {k: list(v) for k, v in SHAPES.items()},
           "insert_k": INSERT_K,  # insert ms/gbps are per-splice, timed
           #                        as one jitted batch of this many
           "backends": {}}
    for backend, (B, L, D) in SHAPES.items():
        iters = ITERS[backend]
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(0),
                                    (B, L, D)).astype(dtype)
        row = x[:, :1]
        pos = jnp.asarray(L // 2, jnp.int32)
        ops.force_backend(backend)
        per_geo = {}
        try:
            for name in GEOMETRIES:
                codec = codecs.get(name)
                fields = codec.pack_fields(dtype)
                packed = jax.jit(codec.pack)(x)
                packed = jax.block_until_ready(
                    jax.tree.map(lambda a: a, packed))
                row_pk = jax.jit(codec.pack)(row)

                def insert_k(c, r, p):
                    # One dispatch, INSERT_K consecutive splices: the
                    # timing divides back to per-insert cost below.
                    return jax.lax.fori_loop(
                        0, INSERT_K,
                        lambda i, acc: _splice(acc, r, p + i), c)

                phases = {
                    "prefill": jax.jit(codec.pack),
                    "insert": jax.jit(insert_k),
                    "generate": jax.jit(codec.unpack),
                }
                args = {"prefill": (x,), "insert": (packed, row_pk, pos),
                        "generate": (packed,)}
                nbytes = _phase_bytes(fields, B, L, D, itemsize)
                geo = {"payload_bits": int(fields.payload_bits),
                       "dense": bool(fields.dense), "phases": {}}
                for ph, fn in phases.items():
                    call = lambda: jax.block_until_ready(fn(*args[ph]))
                    ms = _median_ms(call, iters)
                    if ph == "insert":
                        ms /= INSERT_K  # amortized per-splice cost
                    if profile and backend == "ref":
                        tdir = TRACE_DIR / name / ph
                        tdir.mkdir(parents=True, exist_ok=True)
                        with jax.profiler.trace(str(tdir)):
                            call()
                    geo["phases"][ph] = {
                        "ms": ms,
                        "bytes": float(nbytes[ph]),
                        "gbps": nbytes[ph] / ms / 1e6,
                    }
                per_geo[name] = geo
        finally:
            ops.force_backend(None)
        out["backends"][backend] = per_geo
    if profile:
        out["trace_dir"] = str(TRACE_DIR)
    return out


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", action="store_true",
                    help="capture jax.profiler traces per ref phase "
                         f"under {TRACE_DIR}")
    args = ap.parse_args(argv)
    r = run(profile=args.profile)
    OUT.write_text(json.dumps(r, indent=2))
    print(json.dumps(r, indent=2))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
