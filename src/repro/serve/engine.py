"""Serving engine: prefill + decode with (optionally compressed) KV cache.

`cache_axes` mirrors DecoderModel.init_cache structurally and assigns the
logical sharding: batch over (pod, data), the KV sequence dim over `model`
(flash-decoding style — XLA's softmax reductions over the sharded dim
become exact all-reduces), recurrent-state widths over `model`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, GLOBAL, LOCAL, SSD
from repro.models import attention, mamba2, rglru
from repro.models.model import DecoderModel


def _slot_axes(kind: str):
    if kind in (GLOBAL, LOCAL):
        return attention.KVCache(k=("batch", "cache_seq", "kv", None),
                                 v=("batch", "cache_seq", "kv", None))
    if kind == SSD:
        return mamba2.SSDCache(conv_x=("batch", None, "ssm_inner"),
                               conv_B=("batch", None, "state"),
                               conv_C=("batch", None, "state"),
                               state=("batch", "heads", None, None))
    return rglru.LRUCache(conv=("batch", None, "lru"),
                          state=("batch", "lru"))


def cache_axes(model: DecoderModel):
    cfg = model.cfg
    is_tuple = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a)
    per = {f"slot{i}": _slot_axes(k) for i, k in enumerate(cfg.period)}
    periods = jax.tree.map(lambda a: ("layers",) + tuple(a), per,
                           is_leaf=is_tuple)
    axes = {"periods": periods}
    if cfg.remainder:
        axes["rem"] = {f"slot{i}": _slot_axes(k)
                       for i, k in enumerate(cfg.remainder)}
    return axes


def make_serve_step(model: DecoderModel, greedy: bool = True):
    """(params, cache, token, pos) -> (next_token, cache). One decode step."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step


def make_prefill_step(model: DecoderModel, max_len: int):
    def prefill_step(params, tokens, cond_embeddings=None):
        return model.prefill(params, tokens, max_len,
                             cond_embeddings=cond_embeddings)

    return prefill_step


@dataclasses.dataclass
class GenerationResult:
    tokens: Any
    steps: int


def generate(model: DecoderModel, params, prompt: jax.Array, max_new: int,
             max_len: Optional[int] = None,
             cond_embeddings: Optional[jax.Array] = None) -> GenerationResult:
    """Greedy batched generation (host loop; used by examples + tests)."""
    B, S = prompt.shape
    P = model.cfg.prefix_tokens if cond_embeddings is not None else 0
    max_len = max_len or (P + S + max_new)
    prefill = jax.jit(make_prefill_step(model, max_len))
    step = jax.jit(make_serve_step(model))
    logits, cache = prefill(params, prompt, cond_embeddings)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = P + S
    for i in range(max_new - 1):
        tok, cache = step(params, cache, tok, jnp.asarray(pos, jnp.int32))
        out.append(tok)
        pos += 1
    return GenerationResult(tokens=jnp.concatenate(out, axis=1),
                            steps=max_new)
