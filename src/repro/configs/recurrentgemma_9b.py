"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427 (Griffin); unverified] 38L, d_model=4096, 16H (GQA
kv=1 = MQA), d_ff=12288, vocab=256000. Pattern: 2 recurrent blocks per
local-attention block; 38 = 12 full (rglru, rglru, local) periods + 2
remainder rglru layers.
"""
from repro.configs.base import ArchConfig, LOCAL, RGLRU, register

RECURRENTGEMMA_9B = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256_000,
    period=(RGLRU, RGLRU, LOCAL),
    window=2048,
    lru_width=4096,
    conv_width=4,
    act="gelu",
    emb_scale=True,
    source="arXiv:2402.19427 (Griffin/RecurrentGemma); assignment spec",
))
