import jax.numpy as jnp
import numpy as np

from repro.data import pipeline, synthetic


def _cfg(**kw):
    base = dict(vocab=512, seq_len=32, global_batch=4, seed=3)
    base.update(kw)
    return synthetic.SyntheticConfig(**base)


def test_deterministic_replay():
    c = synthetic.MarkovCorpus(_cfg())
    a = c.batch(7)
    b = synthetic.MarkovCorpus(_cfg()).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    b = synthetic.MarkovCorpus(_cfg()).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_batches_iterator_restarts_at_step():
    it = synthetic.batches(_cfg(), start_step=5)
    first = next(it)
    direct = synthetic.MarkovCorpus(_cfg()).batch(5)
    np.testing.assert_array_equal(first["tokens"], direct["tokens"])


def test_stream_is_learnable_not_uniform():
    """Bigram statistics must carry signal (QM/BitChop need a falling loss)."""
    c = synthetic.MarkovCorpus(_cfg(global_batch=16, seq_len=256))
    b = c.batch(0)
    toks = np.asarray(b["tokens"]).reshape(-1)
    v = c.v
    pairs = {}
    for a_, b_ in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a_), []).append(int(b_))
    # conditional successor sets are much smaller than the vocab
    branching = np.mean([len(set(vv)) for vv in pairs.values() if len(vv) > 4])
    assert branching < v / 4


def test_prefetch_preserves_order_and_count():
    def gen():
        for i in range(5):
            yield {"x": np.full((2,), i)}
    out = list(pipeline.prefetch(gen(), depth=2))
    assert len(out) == 5
    for i, b in enumerate(out):
        assert float(b["x"][0]) == i


def test_prefetch_propagates_errors():
    def gen():
        yield {"x": np.zeros(1)}
        raise ValueError("boom")
    it = pipeline.prefetch(gen())
    next(it)
    try:
        next(it)
        assert False
    except ValueError:
        pass
