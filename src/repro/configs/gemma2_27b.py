"""gemma2-27b [dense] — alternating local/global attention, logit softcaps.

[arXiv:2408.00118; hf] 46L, d_model=4608, 32H (GQA kv=16), d_ff=36864,
vocab=256000.
"""
from repro.configs.base import ArchConfig, GLOBAL, LOCAL, register

GEMMA2_27B = register(ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256_000,
    period=(LOCAL, GLOBAL),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    emb_scale=True,
    source="arXiv:2408.00118 (Gemma 2); assignment spec",
))
