"""Decode-step benchmark: bf16 KV cache vs packed sfp8/sfp16 caches.

Decode is bandwidth-bound on the KV-cache read — the paper's memory-wall
regime. This benchmark reports, per (batch, cache-length) point:

  * measured ms/step on the ref backend for the raw cache
    (attention.attention_decode) and each packed container
    (kvcache.attention_decode_packed — on ref that is the
    unpack-then-attend fallback), and
  * modeled HBM cache-traffic bytes/step for (a) the raw bf16 cache,
    (b) the fused decompress-attend kernel (packed payload + bases read,
    nothing else: the bf16 cache never materializes in HBM), and (c) the
    unpack fallback (packed read + full-precision write + read of the
    decompressed copy) — the path the fused kernel removes.

The model counts only K+V cache traffic (the decode step's dominant term);
q/out/weight traffic is identical across variants and omitted. Emitted as
BENCH_decode.json (repo root) standalone or via benchmarks/run.py.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

POINTS_FULL = [(1, 512), (4, 1024), (8, 2048)]
POINTS_QUICK = [(1, 256)]
# Fixed-lane words plus two dense bit-plane geometries: sfp-m2e4 reads
# 7 bits/value + bases — below the 0.504x floor any 8-bit lane imposes —
# and sfp-m1e2 is the narrowest (4-bit) plane decode the serving stack
# downshifts to under pressure.
CONTAINERS = ("sfp8", "sfp16", "sfp-m2e4", "sfp-m1e2")
ITERS = 20
ITERS_QUICK = 5
OUT = Path(__file__).resolve().parent.parent / "BENCH_decode.json"


def _median_ms(fn, iters):
    fn()  # compile + warm caches
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def _cache_traffic_model(B, L, D, itemsize, fields):
    """Bytes of K+V cache traffic for one decode step, per path."""
    raw = 2 * B * L * D * itemsize  # read K + V once
    packed = 2 * B * L * (D * fields.payload_bits // 8 + D // 128)
    return {
        "raw": float(raw),
        "fused": float(packed),  # packed read only; no decompressed copy
        "unpack_fallback": float(packed + 2 * raw),  # + write/read the copy
    }


def run(quick: bool = False) -> dict:
    from repro import codecs, configs
    from repro.configs.base import reduced
    from repro.kernels import ops
    from repro.models import attention, common
    from repro.serve import kvcache

    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="bfloat16")
    D = cfg.n_kv_heads * cfg.head_dim_
    dtype = cfg.compute_dtype
    itemsize = jnp.dtype(dtype).itemsize
    pf = common.ParamFactory(common.MODE_PARAMS, jax.random.PRNGKey(0), dtype)
    params = attention.attn_init(pf, cfg)
    points = POINTS_QUICK if quick else POINTS_FULL
    iters = ITERS_QUICK if quick else ITERS

    ops.force_backend("ref")
    results = []
    try:
        for B, L in points:
            h_tok = 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                            (B, 1, cfg.d_model)).astype(dtype)
            pos = jnp.asarray(L - 1, jnp.int32)

            raw_cache = attention.cache_init(cfg, "global", B, L, dtype)
            raw_step = jax.jit(lambda c: attention.attention_decode(
                params, h_tok, c, pos, cfg, kind="global"))
            ms = {"bf16": _median_ms(
                lambda: jax.block_until_ready(raw_step(raw_cache)), iters)}

            traffic = {"bf16": _cache_traffic_model(
                B, L, D, itemsize,
                codecs.fields_for("sfp8", dtype))["raw"]}
            ratios = {}
            for name in CONTAINERS:
                pk_cache = kvcache.packed_cache_init(cfg, "global", B, L,
                                                     name)
                pk_step = jax.jit(
                    lambda c, n=name: kvcache.attention_decode_packed(
                        params, h_tok, c, pos, cfg, kind="global",
                        container=n))
                ms[name] = _median_ms(
                    lambda: jax.block_until_ready(pk_step(pk_cache)), iters)
                t = _cache_traffic_model(B, L, D, itemsize,
                                         codecs.fields_for(name, dtype))
                traffic[f"{name}_fused"] = t["fused"]
                traffic[f"{name}_unpack_fallback"] = t["unpack_fallback"]
                ratios[f"{name}_fused"] = t["fused"] / traffic["bf16"]
            results.append({
                "B": B, "L": L, "D": D,
                "ms_per_step": ms,
                "hbm_cache_bytes_per_step": traffic,
                "fused_bytes_vs_bf16": ratios,
            })
    finally:
        ops.force_backend(None)

    return {
        "backend": "ref",
        "dtype": str(jnp.dtype(dtype)),
        "containers": list(CONTAINERS),
        "iters": iters,
        "fused_materializes_bf16_cache": False,
        "points": results,
    }


# CI regression guard (--quick): every dense bit-plane decode must stay
# within this factor of the fixed-lane sfp8 step at the smoke shape.
# The budget is loose against the full-sweep acceptance (~2.5x) because
# the (1, 256) smoke point is dispatch- rather than bandwidth-dominated
# and CI machines are noisy — it catches the failure mode that matters:
# the plane expansion regressing back to per-bit gathers (>10x). The
# narrow sfp-m1e2 (pressure-downshift) geometry expands fewer planes
# than sfp-m2e4, but at this dispatch-bound shape both ratios jitter up
# to ~3x run-to-run, hence the extra headroom.
QUICK_MAX_DENSE_VS_SFP8 = 3.5
QUICK_DENSE_GUARDED = ("sfp-m2e4", "sfp-m1e2")


def _check_quick(r: dict) -> None:
    ms = r["points"][0]["ms_per_step"]
    failures = []
    for name in QUICK_DENSE_GUARDED:
        ratio = ms[name] / ms["sfp8"]
        status = "OK" if ratio <= QUICK_MAX_DENSE_VS_SFP8 else "FAIL"
        print(f"quick guard: {name}/sfp8 = {ratio:.2f}x "
              f"(budget {QUICK_MAX_DENSE_VS_SFP8:.1f}x) {status}")
        if ratio > QUICK_MAX_DENSE_VS_SFP8:
            failures.append(
                f"{name} {ms[name]:.3f} ms vs sfp8 {ms['sfp8']:.3f} ms "
                f"({ratio:.2f}x > {QUICK_MAX_DENSE_VS_SFP8:.1f}x)")
    if failures:
        raise SystemExit("dense decode regression: " + "; ".join(failures))


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="single small point, fewer iters (CI smoke); "
                         "asserts the dense-vs-sfp8 latency guard")
    args = ap.parse_args(argv)
    r = run(quick=args.quick)
    OUT.write_text(json.dumps(r, indent=2))
    print(json.dumps(r, indent=2))
    print(f"wrote {OUT}")
    if args.quick:
        _check_quick(r)


if __name__ == "__main__":
    main()
