"""Fig 13: cumulative activation footprint vs BF16 / JS / GIST++.

JS: zero-skip sparse coding with one tag bit per value. GIST++: ReLU-pool
tensors at 1 bit/value, sparsity coding elsewhere only when it wins.
SFP_QM/SFP_BC: the dynamic containers (measured bitlengths from the
trained runs) on top of Gecko exponents.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import footprint


def run():
    base = common.cnn_run("none")
    qm = common.cnn_run("qm")
    bc = common.cnn_run("bitchop")
    _, stash = common.cnn_stash(base, "none")

    totals = {"bf16": 0, "js": 0, "gist": 0, "sfp_qm": 0, "sfp_bc": 0,
              "fp32": 0}
    for s in stash:
        t = jnp.asarray(s["tensor"])
        totals["fp32"] += footprint.baseline_bits(t, "fp32")
        totals["bf16"] += footprint.baseline_bits(t, "bf16")
        totals["js"] += footprint.js_bits(t, 16)
        totals["gist"] += footprint.gist_bits(t, 16,
                                              relu_pool=s["relu_pool"])
        totals["sfp_qm"] += footprint.sfp_footprint(
            t, qm["final_qm_bits"], signless=s["signless"]).total_bits
        totals["sfp_bc"] += footprint.sfp_footprint(
            t, float(bc["final_bc_bits"]), signless=s["signless"]).total_bits
    out = {k: v / totals["bf16"] for k, v in totals.items()}
    out["sparsity"] = float(np.mean([
        float((jnp.asarray(s["tensor"]) == 0).mean()) for s in stash]))
    return out


def main():
    r = run()
    print("activation footprint relative to BF16:")
    for k in ("fp32", "bf16", "js", "gist", "sfp_bc", "sfp_qm"):
        print(f"  {k:8s} {r[k]:.3f}")
    print(f"(mean activation sparsity {100*r['sparsity']:.0f}%)")
    return r


if __name__ == "__main__":
    main()
