"""CLI driver: ``python -m repro.analysis``.

Fast tier (default, CI-gating, < 60 s):
  * AST lints over src/repro (layer 1)
  * jaxpr/HLO contracts on the quick geometry set (layer 2)
  * VMEM budget sweep over the quick geometries

Nightly (``--full``): the contract + VMEM sweeps widen to every
registered dense geometry, and the donation audit also compiles each
entry point so XLA's donation warnings are surfaced.

Exit status: 0 when every finding is waived in the baseline file, 1 when
active findings remain (or the baseline is malformed). Stale waivers are
reported but do not fail — delete them when you see them.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List

from repro.analysis import findings as _findings

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_BASELINE = REPO_ROOT / "analysis_baseline.json"
DEFAULT_PATHS = [REPO_ROOT / "src" / "repro"]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static precision/kernel contract checker "
                    "(AST lints + jaxpr contracts).")
    p.add_argument("--full", action="store_true",
                   help="nightly mode: sweep every registered dense "
                        "geometry (not just the quick set)")
    p.add_argument("--baseline", type=pathlib.Path,
                   default=DEFAULT_BASELINE,
                   help="waiver file (default: analysis_baseline.json at "
                        "the repo root)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable findings on stdout")
    p.add_argument("--no-lints", action="store_true",
                   help="skip the AST lint layer")
    p.add_argument("--no-contracts", action="store_true",
                   help="skip the jaxpr contract + VMEM layers")
    p.add_argument("--paths", nargs="*", type=pathlib.Path, default=None,
                   help="files/dirs to lint (default: src/repro)")
    return p


def collect(args) -> List[_findings.Finding]:
    found: List[_findings.Finding] = []
    if not args.no_lints:
        from repro.analysis import astlint
        roots = [p.resolve() for p in (args.paths or DEFAULT_PATHS)]
        found += astlint.run_lints(roots, REPO_ROOT)
    if not args.no_contracts:
        from repro.analysis import contracts, vmem
        found += contracts.run_contracts(full=args.full)
        geoms = (contracts.full_geometries() if args.full
                 else contracts.QUICK_GEOMETRIES)
        found += vmem.check_vmem(geoms)
    return found


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        waivers = _findings.load_baseline(args.baseline)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"error: bad baseline {args.baseline}: {e}", file=sys.stderr)
        return 1

    found = collect(args)
    active, waived, stale = _findings.split_by_baseline(found, waivers)

    if args.json:
        print(json.dumps({
            "active": [f.to_json() for f in active],
            "waived": [f.to_json() for f in waived],
            "stale_waivers": stale,
        }, indent=2))
    else:
        for f in active:
            print(f.format())
        if waived:
            print(f"-- {len(waived)} finding(s) waived by "
                  f"{args.baseline.name}")
        for key in stale:
            print(f"-- stale waiver (no matching finding, delete it): "
                  f"{key}")
        status = "FAIL" if active else "ok"
        print(f"repro.analysis: {status} — {len(active)} active, "
              f"{len(waived)} waived, {len(stale)} stale waiver(s)")
    return 1 if active else 0
