"""Table I / Fig 12: total memory footprint + accuracy under SFP_QM / SFP_BC.

Trains the paper-family CNN (ResNet-8 on synthetic data — DESIGN.md D1) and
a reduced LM under each policy, then accounts the stashed-tensor footprint
bit-exactly: mantissa bits from the learned/heuristic bitlengths, exponents
through Gecko, signs elided for provably-nonnegative tensors.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import footprint, gecko


def footprint_for(stash, mantissa_bits, exp_bits=None) -> Dict[str, float]:
    """``exp_bits`` (scalar or {site: bits}) prices the exponent field at
    a reduced bitlength — the QE/BitWave account; None keeps the full
    container exponent (QM/BitChop)."""
    total_sfp = total_js = total_fp32 = total_bf16 = 0
    parts = {"sign": 0, "mantissa": 0, "exponent": 0}
    for s in stash:
        t = jnp.asarray(s["tensor"])
        bits = (mantissa_bits[s["name"]]
                if isinstance(mantissa_bits, dict) else mantissa_bits)
        ebits = (exp_bits[s["name"]]
                 if isinstance(exp_bits, dict) else exp_bits)
        rep = footprint.sfp_footprint(t, bits, exp_bits=ebits,
                                      signless=s["signless"])
        rep_js = footprint.sfp_js_footprint(t, bits, signless=s["signless"])
        total_sfp += rep.total_bits
        total_js += min(rep_js.total_bits, rep.total_bits)
        total_fp32 += footprint.baseline_bits(t, "fp32")
        total_bf16 += footprint.baseline_bits(t, "bf16")
        parts["sign"] += rep.sign_bits
        parts["mantissa"] += rep.mantissa_bits
        parts["exponent"] += rep.exponent_bits
    return {"sfp_bits": total_sfp, "fp32_bits": total_fp32,
            "bf16_bits": total_bf16,
            "vs_fp32": total_sfp / total_fp32,
            "vs_bf16": total_sfp / total_bf16,
            "js_vs_fp32": total_js / total_fp32,
            "share_sign": parts["sign"] / total_sfp,
            "share_mantissa": parts["mantissa"] / total_sfp,
            "share_exponent": parts["exponent"] / total_sfp}


def run() -> Dict:
    out = {}
    base = common.cnn_run("none")
    for mode in ("qm", "bitchop"):
        r = common.cnn_run(mode)
        bits = (r.get("final_qm_bits_per_layer", r["final_qm_bits"])
                if mode == "qm" else float(r["final_bc_bits"]))
        params, stash = common.cnn_stash(r, mode, act_bits=bits)
        fp = footprint_for(stash, bits)
        acc = np.mean([h["acc"] for h in r["history"][-10:]])
        acc_base = np.mean([h["acc"] for h in base["history"][-10:]])
        mean_bits = (float(np.mean(list(bits.values())))
                     if isinstance(bits, dict) else float(bits))
        out[f"resnet8_{mode}"] = {
            "acc": float(acc), "acc_fp32_baseline": float(acc_base),
            "acc_delta": float(acc - acc_base),
            "mantissa_bits": mean_bits, **fp}
        if isinstance(bits, dict):
            out[f"resnet8_{mode}"]["bits_per_layer"] = bits
        # The exponent-side account the registry unlocked: price the same
        # stash as if BitWave/QE had also reduced the exponent field (the
        # qm row's mantissa bits + a reduced exponent range). 5 exponent
        # bits covers fp32 activations' typical post-norm spread.
        if mode == "qm":
            fp_e = footprint_for(stash, bits, exp_bits=5)
            out["resnet8_qm_exp5"] = {
                "acc": float(acc), "acc_fp32_baseline": float(acc_base),
                "acc_delta": float(acc - acc_base),
                "mantissa_bits": mean_bits, "exponent_bits": 5.0, **fp_e}
    return out


def main():
    res = run()
    for name, r in res.items():
        print(f"{name}: footprint={100*r['vs_fp32']:.1f}% of FP32 "
              f"({100*r['vs_bf16']:.1f}% of BF16), acc {r['acc']:.3f} "
              f"(baseline {r['acc_fp32_baseline']:.3f}, "
              f"delta {r['acc_delta']:+.3f}), bits={r['mantissa_bits']:.2f}")
        print(f"  breakdown: sign {100*r['share_sign']:.0f}% / "
              f"mantissa {100*r['share_mantissa']:.0f}% / "
              f"exponent {100*r['share_exponent']:.0f}%; "
              f"+JS zero-skip -> {100*r['js_vs_fp32']:.1f}% of FP32")
    return res


if __name__ == "__main__":
    main()
