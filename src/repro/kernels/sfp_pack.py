"""Pallas TPU kernels: fixed-width SFP container pack/unpack (+ fused
quantize+pack).

The paper's compressor/decompressor (§V) adapted to the TPU memory
hierarchy (DESIGN.md §2): instead of a bit-serial packer at the DRAM pins,
values are re-containered in 8/16-bit lanes on the HBM<->VMEM path with one
shared 8-bit base exponent per 128-lane group (a Gecko column base).

Kernels are format-agnostic: the payload word geometry arrives as a
``kernels.ref.PackFields`` (mantissa bits kept, delta-exponent bits,
payload width); the container-name -> geometry mapping lives in the codec
registry (``repro.codecs``). The primary entry point is
``sfp_quantize_pack``: it fuses the mantissa truncation Q(M, n) from
Quantum Mantissa / BitChop with the exponent delta encoding in a single
VMEM pass — one HBM read of the activation instead of two (the separate
``mantissa_quant`` kernel followed by ``sfp_pack``), exactly the fusion the
paper's hardware packers do.

Layouts (see kernels/ref.py for the bit-level oracle):
  payload word = sign<<(P-1) | dexp<<(P-1-E) | man_top<<(P-1-E-K)
(dexp == max, man == 0) encodes exact zero; dexp saturates (values more
than 2^-dexp_max below the group max flush — bounded error, see tests).
Bases are per-128-lane-group shared exponents, stored as (R, 1) uint8.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import containers
from repro.kernels import ref as kref

LANES = kref.GROUP  # 128
DEFAULT_BLOCK_ROWS = 64


def vmem_estimate(*, fields: kref.PackFields,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  dtype=jnp.bfloat16, fused: bool = True) -> int:
    """Static per-grid-step VMEM footprint model, in bytes.

    Double-buffered in/out block windows plus the int32 working tiles of
    ``_pack_body`` (bitcast words, exponent/mantissa fields, packed word —
    modeled as four live (block_rows, 128) int32 tiles; the unpack
    direction is bounded by the same count). Budget model for
    ``repro.analysis.vmem``, not an allocator.
    """
    isz = jnp.dtype(dtype).itemsize
    psz = jnp.dtype(fields.payload_dtype).itemsize
    blocks = 2 * (
        block_rows * LANES * isz             # x in
        + block_rows * LANES * psz           # payload out
        + block_rows * 1                     # bases out (uint8)
    )
    if fused:
        blocks += 2 * 4                      # n scalar (1, 1) int32
    temps = 4 * block_rows * LANES * 4
    return blocks + temps


def _pack_body(x, fields: kref.PackFields, spec, n=None):
    """Shared kernel body: (block, 128) floats -> (payload, base) words.

    ``n`` (optional traced scalar) fuses Q(M, n) into the same pass.
    """
    u = jax.lax.bitcast_convert_type(x, spec.int_dtype).astype(jnp.int32)
    sign = (u >> spec.sign_shift) & 1
    e = (u >> spec.exp_shift) & spec.exp_mask
    man = u & spec.man_mask
    if n is not None:
        nn = jnp.clip(n, 0, spec.man_bits)
        drop = spec.man_bits - nn
        man = man & (spec.man_mask ^ ((1 << drop) - 1))

    base = jnp.max(e, axis=-1, keepdims=True)
    dexp = base - e
    man_top = man >> (spec.man_bits - fields.man_keep)
    flush = (e == 0) | (dexp > fields.dexp_max)
    dexp = jnp.where(flush, fields.dexp_max, jnp.minimum(dexp,
                                                         fields.dexp_max))
    man_top = jnp.where(flush, 0, man_top)
    sign = jnp.where(e == 0, 0, sign)

    word = ((sign << fields.sign_shift) | (dexp << fields.dexp_shift)
            | (man_top << fields.man_shift))
    return word.astype(fields.word_dtype), base.astype(jnp.uint8)


def _pack_kernel(x_ref, payload_ref, base_ref, *, spec, fields):
    payload_ref[...], base_ref[...] = _pack_body(x_ref[...], fields, spec)


def _quantize_pack_kernel(n_ref, x_ref, payload_ref, base_ref, *, spec,
                          fields):
    payload_ref[...], base_ref[...] = _pack_body(
        x_ref[...], fields, spec, n=n_ref[0, 0])


def _unpack_kernel(payload_ref, base_ref, o_ref, *, spec,
                   fields: kref.PackFields):
    p = payload_ref[...].astype(jnp.int32)
    sign = (p >> fields.sign_shift) & 1
    dexp = (p >> fields.dexp_shift) & fields.dexp_max
    man_top = (p >> fields.man_shift) & ((1 << fields.man_keep) - 1)
    base = base_ref[...].astype(jnp.int32)
    e = jnp.maximum(base - dexp, 0)
    man = man_top << (spec.man_bits - fields.man_keep)
    flush = (dexp == fields.dexp_max) & (man_top == 0)
    e = jnp.where(flush, 0, e)
    man = jnp.where(flush, 0, man)
    sign = jnp.where(flush, 0, sign)
    word = (
        (sign << spec.sign_shift) | (e << spec.exp_shift) | man
    ).astype(spec.int_dtype)
    o_ref[...] = jax.lax.bitcast_convert_type(word, spec.dtype)


def _to_rows(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % LANES
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), pad


def _row_grid(rows2d: jax.Array, block_rows: int):
    rows = rows2d.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        rows2d = jnp.pad(rows2d, ((0, rpad), (0, 0)))
    return rows2d, rows, rpad, block_rows


@functools.partial(jax.jit, static_argnames=("fields", "block_rows",
                                             "interpret"))
def sfp_pack(x: jax.Array, *, fields: kref.PackFields,
             block_rows: int = DEFAULT_BLOCK_ROWS,
             interpret: Optional[bool] = None):
    """Pack ``x`` into (payload rows, per-row base exponents).

    Returns (payload (R, 128) uint8|uint16, bases (R, 1) uint8). Rows are
    128-lane groups of the flattened tensor (Gecko columns).
    """
    interpret = kref.default_interpret(interpret)
    spec = containers.spec_for(x)
    rows2d, _pad = _to_rows(x)
    rows2d, rows, rpad, block_rows = _row_grid(rows2d, block_rows)
    grid = (rows2d.shape[0] // block_rows,)

    payload, bases = pl.pallas_call(
        functools.partial(_pack_kernel, spec=spec, fields=fields),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(rows2d.shape, fields.payload_dtype),
            jax.ShapeDtypeStruct((rows2d.shape[0], 1), jnp.uint8),
        ],
        interpret=interpret,
    )(rows2d)
    if rpad:
        payload, bases = payload[:rows], bases[:rows]
    return payload, bases


@functools.partial(jax.jit, static_argnames=("fields", "block_rows",
                                             "interpret"))
def sfp_quantize_pack(x: jax.Array, n: jax.Array, *, fields: kref.PackFields,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: Optional[bool] = None):
    """Fused Q(M, n) + pack: one VMEM pass, one HBM read of ``x``.

    Bit-exact against mantissa_quant.mantissa_quantize followed by
    sfp_pack; ``n`` is a traced scalar carried in SMEM (updated per step by
    Quantum Mantissa / BitChop).
    """
    interpret = kref.default_interpret(interpret)
    spec = containers.spec_for(x)
    rows2d, _pad = _to_rows(x)
    rows2d, rows, rpad, block_rows = _row_grid(rows2d, block_rows)
    grid = (rows2d.shape[0] // block_rows,)

    payload, bases = pl.pallas_call(
        functools.partial(_quantize_pack_kernel, spec=spec, fields=fields),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # scalar n
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(rows2d.shape, fields.payload_dtype),
            jax.ShapeDtypeStruct((rows2d.shape[0], 1), jnp.uint8),
        ],
        interpret=interpret,
    )(jnp.asarray(n, jnp.int32).reshape(1, 1), rows2d)
    if rpad:
        payload, bases = payload[:rows], bases[:rows]
    return payload, bases


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "fields",
                                             "block_rows", "interpret"))
def sfp_unpack(payload: jax.Array, bases: jax.Array, *, shape: tuple,
               dtype, fields: kref.PackFields,
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: Optional[bool] = None) -> jax.Array:
    interpret = kref.default_interpret(interpret)
    spec = containers.spec_for(jnp.dtype(dtype))

    rows = payload.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        payload = jnp.pad(payload, ((0, rpad), (0, 0)))
        bases = jnp.pad(bases, ((0, rpad), (0, 0)))
    grid = (payload.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_unpack_kernel, spec=spec, fields=fields),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(payload.shape, spec.dtype),
        interpret=interpret,
    )(payload, bases)
    if rpad:
        out = out[:rows]
    n = 1
    for s in shape:
        n *= s
    return out.reshape(-1)[:n].reshape(shape)
