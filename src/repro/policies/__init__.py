"""Unified precision-policy API (see base.py for the contract).

Every precision-adaptation path in the system — the activation-stash
bitlength signal, weight fake-quant, the footprint regularizer, the loss
controller — resolves its strategy here:

    policy = policies.get("qm")          # or "qe", "bitwave", ...
    policy = policies.get("qm+qe",       # compose: learn mantissa AND
                          container="sfp8", gamma=0.1)  # exponent bits
    state  = policy.init_state(dims)     # PolicyState(learn, ctrl) pytree
    d      = policy.act_decision(pslice, key, dims)  # PrecisionDecision

Registered policies:
  none    — full-precision baseline (every hook is a no-op)
  static  — fixed bitlengths (Gist-style ablation)
  qm      — Quantum Mantissa: learned per-scope mantissa bits (§IV-A)
  qe      — Quantum Exponent: learned per-scope exponent bits (§IV)
  afloat  — QE + AdaptivFloat-style learned per-scope exponent *bias*
            offsets (a related-work plugin exercising the registry and
            the dense containers from outside the paper)
  bitchop — loss-EMA controlled network-wide mantissa bits (§IV-B)
  bitwave — BitChop's controller driving mantissa + exponent bits

New strategies (Flexpoint shared-exponent controllers, ...) subclass
``Policy`` and register via ``policies.register()``; they become
available to the model, train step, launchers, and benchmarks at once.
"""
from repro.policies.base import (Policy, PolicyState, PrecisionDecision,
                                 ScopeDims, apply_decision_ste, coerce,
                                 full_decision, get, modeled_footprint,
                                 names, register, ste_truncate,
                                 validate_name)
from repro.policies.afloat import AFloatPolicy
from repro.policies.bitwave import BitChopPolicy, BitWavePolicy
from repro.policies.composite import CompositePolicy
from repro.policies.quantum import QEPolicy, QMPolicy
from repro.policies.static import NonePolicy, StaticPolicy

register(NonePolicy)
register(StaticPolicy)
register(QMPolicy)
register(QEPolicy)
register(AFloatPolicy)
register(BitChopPolicy)
register(BitWavePolicy)

__all__ = [
    "Policy", "PolicyState", "PrecisionDecision", "ScopeDims",
    "apply_decision_ste", "coerce", "full_decision", "get",
    "modeled_footprint", "names", "register", "ste_truncate",
    "validate_name",
    "NonePolicy", "StaticPolicy", "QMPolicy", "QEPolicy", "AFloatPolicy",
    "BitChopPolicy", "BitWavePolicy", "CompositePolicy",
]
