"""Training substrate: state, step builder, loop, gradient compression."""
