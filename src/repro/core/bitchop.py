"""BitChop: history-based network-wide mantissa bitlength control.

Paper §IV-B. Observes the per-batch training loss, maintains an exponential
moving average (eq. 8) and a noise threshold epsilon (EMA of |L - Mavg|),
and once per period (N = 1 batch) decides to shrink / keep / grow the
single network-wide mantissa bitlength (eq. 9):

    n <- n - 1   if Mavg > L + eps     (loss clearly improving)
    n <- n       if |Mavg - L| <= eps
    n <- n + 1   if Mavg < L - eps     (loss clearly regressing)

The controller is a pure function over a small state pytree so it can live
on-device inside a jitted train step (the paper implements it as a tiny
hardware block fed by a loss register — the software analogue is a fused
scalar update). Full precision is forced for a window after learning-rate
changes (the paper: "Full precision is used during LR changes").
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BitChopConfig:
    alpha: float = 0.1            # loss EMA decay (eq. 8)
    eps_alpha: float = 0.1        # EMA decay for the |L - Mavg| noise proxy
    eps_scale: float = 1.0        # epsilon = eps_scale * err_ema
    max_bits: int = 7             # container mantissa bits (7 bf16, 23 fp32)
    min_bits: int = 0
    period: int = 1               # batches per decision period (paper: N=1)
    warmup_steps: int = 8         # observe-only steps before first decision
    lr_change_hold: int = 100     # full-precision steps after an LR change


class BitChopState(NamedTuple):
    mavg: jax.Array        # fp32 scalar, EMA of loss
    err_ema: jax.Array     # fp32 scalar, EMA of |L - mavg|
    n: jax.Array           # int32 scalar, current mantissa bitlength
    step: jax.Array        # int32 scalar
    hold_until: jax.Array  # int32 scalar; full precision while step < hold_until


def init(cfg: BitChopConfig) -> BitChopState:
    return BitChopState(
        mavg=jnp.asarray(0.0, jnp.float32),
        err_ema=jnp.asarray(0.0, jnp.float32),
        n=jnp.asarray(cfg.max_bits, jnp.int32),
        step=jnp.asarray(0, jnp.int32),
        hold_until=jnp.asarray(0, jnp.int32),
    )


def _loss_signal(state, loss, cfg):
    """The shared eq. 8-9 machinery: EMA updates + shrink/keep/grow signal.

    ``state`` needs (mavg, err_ema, step, hold_until); ``cfg`` needs
    (alpha, eps_alpha, eps_scale, warmup_steps, period) — both BitChop
    and BitWave satisfy this. Returns (mavg, err_ema, decide, shrink,
    grow); shrink/grow are ungated, callers combine with ``decide``.
    """
    loss = jnp.asarray(loss, jnp.float32)
    first = state.step == 0
    mavg0 = jnp.where(first, loss, state.mavg)
    err = jnp.abs(loss - mavg0)
    err_ema = jnp.where(
        first, err, state.err_ema + cfg.eps_alpha * (err - state.err_ema)
    )
    # eq. (8): Mavg <- Mavg + alpha * (L - Mavg)
    mavg = mavg0 + cfg.alpha * (loss - mavg0)

    eps = cfg.eps_scale * err_ema
    decide = (
        (state.step >= cfg.warmup_steps)
        & (state.step >= state.hold_until)
        & ((state.step % cfg.period) == 0)
    )
    # eq. (9)
    shrink = mavg0 > loss + eps
    grow = mavg0 < loss - eps
    return mavg, err_ema, decide, shrink, grow


def update(state: BitChopState, loss, cfg: BitChopConfig,
           lr_changed=False) -> BitChopState:
    """One observe/decide step (eq. 8 + 9). Safe to call inside jit."""
    mavg, err_ema, decide, shrink, grow = _loss_signal(state, loss, cfg)
    delta = jnp.where(shrink, -1, jnp.where(grow, 1, 0)).astype(jnp.int32)
    n = jnp.where(decide, state.n + delta, state.n)
    n = jnp.clip(n, cfg.min_bits, cfg.max_bits)

    lr_changed = jnp.asarray(lr_changed, bool)
    hold_until = jnp.where(
        lr_changed, state.step + cfg.lr_change_hold, state.hold_until
    ).astype(jnp.int32)
    # During the hold window run at full container precision.
    n = jnp.where(state.step < hold_until, cfg.max_bits, n)

    return BitChopState(
        mavg=mavg,
        err_ema=err_ema,
        n=n.astype(jnp.int32),
        step=state.step + 1,
        hold_until=hold_until,
    )


def effective_bits(state: BitChopState, cfg: BitChopConfig) -> jax.Array:
    """Bitlength to apply this step (full precision inside hold windows)."""
    return jnp.where(state.step < state.hold_until, cfg.max_bits, state.n)


# ----------------------------------------------------------------------
# BitWave: the same loss-EMA controller driving mantissa AND exponent bits
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BitWaveConfig:
    """BitWave = BitChop's eq. 8-9 signals steering two bitlengths.

    The paper's BitWave adjusts mantissa and exponent bitlengths
    network-wide from the loss signal. A single shrink budget is spent
    round-robin (mantissa first — it is the bigger field, so the
    footprint derivative is larger), while a regression signal grows both
    at once: recovery must be fast, exploration can be gradual.
    """

    alpha: float = 0.1
    eps_alpha: float = 0.1
    eps_scale: float = 1.0
    max_man_bits: int = 7         # container mantissa bits (7 bf16, 23 fp32)
    min_man_bits: int = 0
    max_exp_bits: int = 8         # container exponent bits
    min_exp_bits: int = 2         # a 1-bit exponent has no normal codes
    period: int = 1
    warmup_steps: int = 8
    lr_change_hold: int = 100


class BitWaveState(NamedTuple):
    mavg: jax.Array        # fp32 scalar, EMA of loss
    err_ema: jax.Array     # fp32 scalar, EMA of |L - mavg|
    n_man: jax.Array       # int32 scalar, current mantissa bitlength
    n_exp: jax.Array       # int32 scalar, current exponent bitlength
    turn: jax.Array        # int32 scalar; even -> next shrink hits mantissa
    step: jax.Array
    hold_until: jax.Array


def bitwave_init(cfg: BitWaveConfig) -> BitWaveState:
    return BitWaveState(
        mavg=jnp.asarray(0.0, jnp.float32),
        err_ema=jnp.asarray(0.0, jnp.float32),
        n_man=jnp.asarray(cfg.max_man_bits, jnp.int32),
        n_exp=jnp.asarray(cfg.max_exp_bits, jnp.int32),
        turn=jnp.asarray(0, jnp.int32),
        step=jnp.asarray(0, jnp.int32),
        hold_until=jnp.asarray(0, jnp.int32),
    )


def bitwave_update(state: BitWaveState, loss, cfg: BitWaveConfig,
                   lr_changed=False) -> BitWaveState:
    """One observe/decide step over both bitlengths. Safe inside jit."""
    mavg, err_ema, decide, shrink, grow = _loss_signal(state, loss, cfg)
    shrink = decide & shrink
    grow = decide & grow

    man_turn = (state.turn % 2) == 0
    n_man = state.n_man - jnp.where(shrink & man_turn, 1, 0)
    n_exp = state.n_exp - jnp.where(shrink & ~man_turn, 1, 0)
    n_man = jnp.where(grow, n_man + 1, n_man)
    n_exp = jnp.where(grow, n_exp + 1, n_exp)
    n_man = jnp.clip(n_man, cfg.min_man_bits, cfg.max_man_bits)
    n_exp = jnp.clip(n_exp, cfg.min_exp_bits, cfg.max_exp_bits)
    turn = state.turn + jnp.where(shrink, 1, 0)

    lr_changed = jnp.asarray(lr_changed, bool)
    hold_until = jnp.where(
        lr_changed, state.step + cfg.lr_change_hold, state.hold_until
    ).astype(jnp.int32)
    in_hold = state.step < hold_until
    n_man = jnp.where(in_hold, cfg.max_man_bits, n_man)
    n_exp = jnp.where(in_hold, cfg.max_exp_bits, n_exp)

    return BitWaveState(
        mavg=mavg,
        err_ema=err_ema,
        n_man=n_man.astype(jnp.int32),
        n_exp=n_exp.astype(jnp.int32),
        turn=turn.astype(jnp.int32),
        step=state.step + 1,
        hold_until=hold_until,
    )


def bitwave_effective(state: BitWaveState, cfg: BitWaveConfig):
    """(man_bits, exp_bits) to apply this step (full precision in holds)."""
    in_hold = state.step < state.hold_until
    man = jnp.where(in_hold, cfg.max_man_bits, state.n_man)
    exp = jnp.where(in_hold, cfg.max_exp_bits, state.n_exp)
    return man, exp
