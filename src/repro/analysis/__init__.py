"""Static precision/kernel contract checker (``python -m repro.analysis``).

Two layers over the codebase's precision machinery:

* **AST lints** (``astlint``): host syncs inside traced code, stale
  ``interpret=True`` defaults, ``force_backend`` leaks, Python truthiness
  on traced values, unresolvable container/policy name literals (checked
  against the real registries, with did-you-mean), float64 introductions.
* **jaxpr/HLO contracts** (``contracts``, ``vmem``): precision-leak
  detection on the fused quantize+pack, buffer-geometry equality between
  declared and materialized footprints, a donation audit over every
  ``donate_argnums`` entry point, a recompile guard over the serving
  steps, and a static VMEM budget sweep per kernel × arch × geometry.

Violations either get fixed or get an explicit one-line-justified waiver
in ``analysis_baseline.json``; CI runs the fast tier on every push and
the full geometry sweep nightly.
"""
from repro.analysis.findings import (Finding, load_baseline,
                                     split_by_baseline)
from repro.analysis.names import check_container, check_policy
from repro.analysis.runner import build_parser, main

__all__ = [
    "Finding", "load_baseline", "split_by_baseline",
    "check_container", "check_policy", "build_parser", "main",
]
