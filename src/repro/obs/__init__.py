"""Unified telemetry: metrics registry + span tracer + precision timeline.

One ``Obs`` object is the whole observability surface for a process. The
registry is always live (recording into it is cheap enough to leave on);
the span tracer and precision timeline are opt-in, because they retain
per-event state for export. Construction from launcher flags::

    obs = Obs(metrics_path=args.metrics_out, trace_path=args.trace_out,
              timeline_path=args.timeline_out)
    sched = Scheduler(eng, ..., obs=obs)
    ...
    obs.flush()   # writes prometheus text + Perfetto trace JSON

Hot-path contract (enforced by the ``obs-no-hot-path-sync`` lint in
`repro.analysis`): obs mutators are host-side only. Nothing in this
package may be called from inside a jitted/pallas function — callers
record *after* device values have been pulled to the host at an existing
boundary. The registry/tracer/timeline take plain Python scalars and
never force a device sync themselves.
"""
from __future__ import annotations

from typing import Any

from repro.obs.registry import (EventLog, MetricsRegistry,  # noqa: F401
                                log_buckets)
from repro.obs.timeline import PrecisionTimeline  # noqa: F401
from repro.obs.trace import SpanTracer  # noqa: F401


class Obs:
    """Facade bundling registry, event log, tracer, and timeline.

    ``tracer`` / ``timeline`` are ``None`` unless enabled — call sites
    guard with ``if obs.tracer is not None`` so the disabled path costs
    one attribute load.
    """

    def __init__(self, *, metrics_path: str | None = None,
                 events_path: str | None = None,
                 trace_path: str | None = None,
                 timeline_path: str | None = None,
                 trace: bool = False, timeline: bool = False) -> None:
        self.registry = MetricsRegistry()
        self.metrics_path = metrics_path
        self.events = EventLog(events_path)
        self.tracer = (SpanTracer()
                       if (trace or trace_path is not None) else None)
        self.trace_path = trace_path
        self.timeline = (PrecisionTimeline(timeline_path)
                         if (timeline or timeline_path is not None)
                         else None)

    def event(self, name: str, **fields: Any) -> None:
        self.events.emit(name, **fields)

    def flush(self) -> None:
        """Write every file-backed exporter; safe to call repeatedly."""
        if self.metrics_path:
            with open(self.metrics_path, "w") as fh:
                fh.write(self.registry.to_prometheus())
        if self.tracer is not None and self.trace_path:
            self.tracer.write(self.trace_path)

    def close(self) -> None:
        self.flush()
        self.events.close()
        if self.timeline is not None:
            self.timeline.close()
