"""Quantum Mantissa: learning mantissa bitlengths with gradient descent.

The policy wiring (state layout, penalty scheduling, SGD updates, scope
views) lives in repro.policies.quantum.QMPolicy; this module owns the
quantizer math and its custom VJP. quantum_exponent.py is the
exponent-side sibling (same estimator over containers.truncate_exponent).

Paper §IV-A. A real-valued bitlength parameter n per (tensor, kind) is
optimized jointly with the model:

  forward  : q = Q(x, floor(n) + Bernoulli(frac(n)))          (eq. 5, 6)
  backward : dL/dx = dL/dq                                     (STE)
             dL/dn = sum(dL/dq * (Q(x, floor(n)+1) - Q(x, floor(n))))
  loss     : L = L0 + gamma * sum_i lambda_i * n_i             (eq. 7)

The dL/dn term is the exact derivative of the expectation
E[Q(x, n)] = (1-{n}) Q(x, floor n) + {n} Q(x, floor n + 1), which is
piecewise-linear in n — this is the "function of the weight values and
gradients" the paper computes with O(n) overhead (§IV-A3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Mapping

import jax
import jax.numpy as jnp

from repro.core import containers


@partial(jax.custom_vjp, nondiff_argnums=())
def qm_quantize(x: jax.Array, n: jax.Array, key: jax.Array) -> jax.Array:
    """Stochastic fractional-bitlength mantissa quantization (eq. 5+6).

    Args:
      x:   float array (fp32 or bf16).
      n:   scalar float32 bitlength parameter (differentiable).
      key: PRNG key; one Bernoulli draw per call (per-tensor granularity).
    """
    spec = containers.spec_for(x)
    n_int = containers.stochastic_bitlength(n, key, spec.man_bits)
    return containers.truncate_mantissa(x, n_int)


def _qm_fwd(x, n, key):
    spec = containers.spec_for(x)
    n_int = containers.stochastic_bitlength(n, key, spec.man_bits)
    q = containers.truncate_mantissa(x, n_int)
    # Save x and n (cheap: n is scalar); Q(x, floor), Q(x, floor+1) are
    # recomputed in the backward pass — keeping the stash small is the point.
    return q, (x, n)


def _qm_bwd(res, g):
    x, n = res
    spec = containers.spec_for(x)
    nf = jnp.clip(jnp.asarray(n, jnp.float32), 0.0, float(spec.man_bits))
    floor_n = jnp.floor(nf).astype(jnp.int32)
    ceil_n = jnp.minimum(floor_n + 1, spec.man_bits)
    q_lo = containers.truncate_mantissa(x, floor_n)
    q_hi = containers.truncate_mantissa(x, ceil_n)
    # dE[Q]/dn = Q(x, floor+1) - Q(x, floor)   (0 once n >= man_bits)
    diff = (q_hi - q_lo).astype(jnp.float32)
    dn = jnp.sum(g.astype(jnp.float32) * diff).astype(jnp.float32)
    dx = g.astype(x.dtype)  # straight-through
    return dx, dn, None


qm_quantize.defvjp(_qm_fwd, _qm_bwd)


def qm_quantize_deterministic(x: jax.Array, n: jax.Array) -> jax.Array:
    """Deployment-mode quantization: round the learned bitlength up (§IV-A4)."""
    spec = containers.spec_for(x)
    n_int = jnp.clip(jnp.ceil(jnp.asarray(n, jnp.float32)), 0, spec.man_bits).astype(jnp.int32)
    return containers.truncate_mantissa(x, n_int)


@dataclasses.dataclass(frozen=True)
class QMConfig:
    """Hyper-parameters for Quantum Mantissa (paper defaults)."""

    gamma: float = 0.1          # regularizer strength (0.1 -> 0.01 -> 0.001)
    init_bits: float = 7.0      # start at full bf16 mantissa
    lr: float = 0.01            # learning rate for the bitlength params
    min_bits: float = 0.0
    # step thresholds at which gamma decays 10x (paper: epochs 0/30/60 of 90)
    gamma_decay_steps: tuple = ()
    # freeze (round up) bitlengths for the final fraction of training (§IV-A4)
    freeze_final_fraction: float = 0.111  # last 10 of 90 epochs


def gamma_at(cfg: QMConfig, step: jax.Array) -> jax.Array:
    g = jnp.asarray(cfg.gamma, jnp.float32)
    for s in cfg.gamma_decay_steps:
        g = jnp.where(step >= s, g * 0.1, g)
    return g


def init_bitlengths(names, cfg: QMConfig) -> Dict[str, jax.Array]:
    """One fp32 bitlength parameter per named tensor group."""
    return {name: jnp.asarray(cfg.init_bits, jnp.float32) for name in names}


def footprint_lambdas(numels: Mapping[str, int]) -> Dict[str, float]:
    """lambda_i = tensor i's share of the total stash footprint (eq. 7).

    The paper weights each group by its footprint so the penalty measures
    total memory, making the optimizer squeeze big tensors hardest.
    """
    total = float(sum(numels.values()))
    if total <= 0:
        return {k: 0.0 for k in numels}
    return {k: float(v) / total for k, v in numels.items()}


def qm_penalty(bitlengths: Mapping[str, jax.Array], lambdas: Mapping[str, float],
               gamma) -> jax.Array:
    """gamma * sum_i lambda_i * n_i  (eq. 7, second term)."""
    acc = jnp.asarray(0.0, jnp.float32)
    for name, n in bitlengths.items():
        lam = lambdas.get(name, 0.0)
        acc = acc + lam * jnp.clip(jnp.asarray(n, jnp.float32), 0.0, None)
    return jnp.asarray(gamma, jnp.float32) * acc


def clip_bitlengths(bitlengths: Dict[str, jax.Array], max_bits: float,
                    min_bits: float = 0.0) -> Dict[str, jax.Array]:
    return {k: jnp.clip(v, min_bits, max_bits) for k, v in bitlengths.items()}
