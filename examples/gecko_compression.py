"""Gecko on real trained tensors: distributions and ratios (Fig 9/10).

  PYTHONPATH=src:. python examples/gecko_compression.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import containers, gecko

r = common.lm_run("none", steps=80)
weights = [jnp.asarray(v) for v in jax.tree.leaves(r["params"])
           if hasattr(v, "ndim") and v.ndim >= 2][:6]
exp = jnp.concatenate([containers.exponent_field(w).reshape(-1)
                       for w in weights])
centered = np.asarray(exp, np.int32) - 127
print(f"exponent distribution over {exp.size} trained weights:")
for lo, hi in ((-64, -17), (-16, -9), (-8, -5), (-4, -1), (0, 0), (1, 4),
               (5, 8), (9, 127)):
    frac = ((centered >= lo) & (centered <= hi)).mean()
    print(f"  [{lo:+4d},{hi:+4d}]: {'#' * int(frac * 60):60s} {frac:.1%}")
for mode in ("delta", "bias"):
    print(f"gecko {mode}: ratio {float(gecko.compression_ratio(exp, mode)):.3f}"
          " (paper: ~0.52-0.56)")
pv = np.asarray(gecko.per_value_bits(exp, "delta"))
print(f"post-encoding bits/exponent: mean {pv.mean():.2f}, "
      f"<=1b {100*(pv<=1).mean():.0f}%, <=4b {100*(pv<=4).mean():.0f}%")
