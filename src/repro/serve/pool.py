"""Paged packed-KV block pool: host-side allocator for the serving engine.

The serving analogue of the paper's containers-at-the-memory-interface: KV
bytes live *packed* in fixed-size physical blocks (one block = the packed
flash-decode kernel's KV block — ``ops.DECODE_BLOCK_L`` token rows), and a
request owns blocks, not a contiguous region. Device memory is one
request-agnostic pool slice per global-attention layer
(``kvcache.PagedKV``); this module owns everything host-side: the free
list, per-slot block tables, admission accounting and eviction. Because
blocks are codec-packed, pool capacity is measured in *compressed* bytes —
an sfp8 pool holds ~2x the tokens of a raw bf16 cache in the same HBM
footprint, which is exactly the admission-throughput win the scheduler
converts into tok/s.

A *dense* policy-derived geometry (``sfp-m{K}e{E}``, bit-plane payloads)
pushes the same lever further: a 7-bit ``sfp-m2e4`` pool holds ~2.27x the
tokens of raw bf16 where fixed-lane sfp8 stops at ~1.98x.

Physical block 0 is reserved as the *trash block*: idle engine slots (and
logical blocks past a row's allocation) point their table entries at it,
so the jitted fixed-shape decode step can always scatter/gather without
branching — writes to block 0 are garbage by construction and never read
through a valid position mask.

The codec geometry is uniform across the pool (one container name — possibly
a policy-derived ``sfp*-m*e*`` geometry, see serve/precision.py); blocks
are not retyped on free/realloc.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

TRASH_BLOCK = 0


def blocks_for(n_tokens: int, block_l: int) -> int:
    """Physical blocks needed to hold ``n_tokens`` KV rows."""
    return max(0, -(-int(n_tokens) // block_l))


@dataclasses.dataclass
class PoolStats:
    num_blocks: int      # allocatable blocks (trash block excluded)
    free_blocks: int
    used_blocks: int
    peak_used: int
    block_bytes: int = 0  # dense-packed bytes per block (0 = not priced)

    @property
    def capacity_bytes(self) -> int:
        return self.num_blocks * self.block_bytes

    @property
    def used_bytes(self) -> int:
        return self.used_blocks * self.block_bytes

    @property
    def free_bytes(self) -> int:
        return self.free_blocks * self.block_bytes

    @property
    def peak_bytes(self) -> int:
        return self.peak_used * self.block_bytes


class BlockPool:
    """Free list + per-slot block tables over ``num_blocks`` physical blocks.

    ``num_blocks`` counts *allocatable* blocks; one extra trash block is
    implicit (physical id 0), so device pool arrays must be sized
    ``num_blocks + 1``. Tables are dense numpy (max_slots, max_logical)
    int32 handed to the jitted step each call; unallocated entries point
    at the trash block.

    Admission accounting is measured in *dense-packed bytes*:
    ``block_bytes`` is what one physical block really occupies under the
    pool's codec geometry (payload words or bit planes + group bases,
    summed over the layers sharing this pool — see
    ``kvcache.paged_block_bytes``), so a dense sub-byte container admits
    proportionally more tokens into the same HBM budget than a fixed-lane
    one. Blocks remain the allocation granule; bytes are blocks times
    ``block_bytes``, and every stat is exposed in both units.
    """

    def __init__(self, num_blocks: int, max_slots: int, max_logical: int,
                 block_l: int, block_bytes: int = 0):
        assert num_blocks >= 1 and max_slots >= 1 and max_logical >= 1
        self.num_blocks = int(num_blocks)
        self.block_l = int(block_l)
        self.block_bytes = int(block_bytes)
        self.max_slots = int(max_slots)
        self.max_logical = int(max_logical)
        # LIFO free list: physical ids 1..num_blocks (0 is trash).
        self._free: List[int] = list(range(self.num_blocks, 0, -1))
        self._owned: Dict[int, List[int]] = {}  # slot -> physical ids
        self.tables = np.full((max_slots, max_logical), TRASH_BLOCK,
                              np.int32)
        self.peak_used = 0

    # -- accounting ------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def bytes_for(self, n_tokens: int) -> int:
        """Dense-packed bytes a request holding ``n_tokens`` KV rows pins
        (block-granular — partial blocks occupy whole blocks)."""
        return blocks_for(n_tokens, self.block_l) * self.block_bytes

    def stats(self) -> PoolStats:
        return PoolStats(num_blocks=self.num_blocks,
                         free_blocks=self.free_blocks,
                         used_blocks=self.used_blocks,
                         peak_used=self.peak_used,
                         block_bytes=self.block_bytes)

    def slot_blocks(self, slot: int) -> int:
        return len(self._owned.get(slot, ()))

    def can_admit(self, n_tokens: int) -> bool:
        """Admission gate: blocks covering the prompt KV rows *and* the
        first decode token must fit, so a fresh request always takes its
        first step without immediately preempting someone. (That is one
        extra block only when the prompt lands exactly on a block
        boundary — a blanket +1 would leave one slot's worth of pool
        permanently idle at full residency.)"""
        return blocks_for(n_tokens + 1, self.block_l) <= self.free_blocks

    # -- allocation ------------------------------------------------------

    def alloc_upto(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover positions [0, n_tokens).

        Returns False (allocating nothing) if the pool cannot supply every
        missing block — the caller then preempts someone and retries.
        """
        need = blocks_for(n_tokens, self.block_l)
        if need > self.max_logical:
            raise ValueError(
                f"request needs {need} blocks > max_logical "
                f"{self.max_logical} (engine max_len too small)")
        owned = self._owned.setdefault(slot, [])
        missing = need - len(owned)
        if missing <= 0:
            return True
        if missing > len(self._free):
            return False
        for _ in range(missing):
            phys = self._free.pop()
            self.tables[slot, len(owned)] = phys
            owned.append(phys)
        self.peak_used = max(self.peak_used, self.used_blocks)
        return True

    def free_slot(self, slot: int) -> int:
        """Release every block ``slot`` owns (finish or preemption);
        returns the number of blocks recycled."""
        owned = self._owned.pop(slot, [])
        self._free.extend(reversed(owned))
        self.tables[slot, :] = TRASH_BLOCK
        return len(owned)

    def reset(self) -> None:
        for slot in list(self._owned):
            self.free_slot(slot)
