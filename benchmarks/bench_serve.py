"""Serving-engine benchmark: paged-packed pool vs contiguous caches.

Continuous-batching decode is the memory-wall regime the paper's
containers target at the DRAM interface: every decode step re-reads each
request's whole KV history. This benchmark sweeps batch size and reports,
per point:

  * measured tok/s of (a) the scheduler-driven paged engine (sfp8 pool),
    (b) contiguous packed generate (``kv_container``), and (c) raw bf16
    generate — all on the ref backend, same prompts and budgets; and
  * modeled HBM cache bytes per decode step across all attention layers:
    ``bf16_contiguous`` reads 2*B*L_alloc*D raw values per layer,
    ``packed_contiguous`` the same rows packed, and ``paged_packed`` only
    the *allocated* packed blocks (block tables don't read dead slack) —
    the paged pool wins twice, once on the container ratio and once on
    allocation granularity.

Both pool geometries are swept: fixed-lane ``sfp8`` (8.06 bits/value) and
the dense bit-plane ``sfp-m2e4`` (7.06 bits/value), with the pool's
admission accounting reported in dense-packed bytes (block_bytes /
capacity / peak watermark).

The paged engine is additionally swept over decode-burst length K (one
jitted ``lax.scan`` of K steps per scheduler round, host work only at
burst boundaries): per-K tok/s and mean TTFT land under ``paged_burst``;
the headline ``paged_packed`` tok/s is the best burst configuration.

Acceptance headline: ``paged_bytes_vs_bf16`` <= 0.6 at equal batch (the
sfp8 point; the dense container lands lower still). Emitted as
BENCH_serve.json (repo root) standalone or via benchmarks/run.py.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

POINTS_FULL = [1, 4, 8]
POINTS_QUICK = [2]
# Fixed-lane sfp8 vs the dense 7-bit sfp-m2e4 bit-plane pool: the dense
# geometry admits ~2.27x the tokens of raw bf16 per HBM byte where the
# 8-bit lane stops at ~1.98x.
CONTAINERS = ("sfp8", "sfp-m2e4")
# Decode-burst lengths swept on the paged engine. MAX_NEW leaves room
# for a full 32-token burst after the admission token, so K=32 measures
# a real scan and not a clamped rerun of K=8.
BURSTS = (1, 8, 32)
PROMPT_LEN = 120
MAX_NEW = 40
OUT = Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _cache_traffic_model(cfg, B, n_ctx, max_len, block_l, fields):
    """Bytes of K+V cache traffic for one decode step at context n_ctx,
    summed over the attention layers, per serving path."""
    from repro.configs.base import GLOBAL, LOCAL
    from repro.serve import kvcache

    D = cfg.n_kv_heads * cfg.head_dim_
    raw_itemsize = 2  # bf16 serving cache
    packed_row = D * fields.payload_bits // 8 + D // 128
    kinds = (list(cfg.period) * cfg.n_periods) + list(cfg.remainder)
    out = {"bf16_contiguous": 0.0, "packed_contiguous": 0.0,
           "paged_packed": 0.0}
    for kind in kinds:
        if kind not in (GLOBAL, LOCAL):
            continue
        if kind == LOCAL:
            # Window-bounded: every path stores the ring contiguously.
            l_raw = min(max_len, cfg.window)
            l_pk = kvcache.cache_len(cfg, kind, max_len)
            l_paged = l_pk
        else:
            l_raw = max_len
            l_pk = kvcache.cache_len(cfg, kind, max_len)
            # Paged: only the blocks the request actually owns are read.
            l_paged = -(-n_ctx // block_l) * block_l
        out["bf16_contiguous"] += 2 * B * l_raw * D * raw_itemsize
        out["packed_contiguous"] += 2 * B * l_pk * packed_row
        out["paged_packed"] += 2 * B * l_paged * packed_row
    return out


def run(quick: bool = False, bursts=BURSTS) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import codecs, configs
    from repro.configs.base import reduced
    from repro.kernels import ops
    from repro.models.model import DecoderModel
    from repro.serve import engine
    from repro.serve.scheduler import Request, Scheduler

    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="bfloat16")
    dtype = cfg.compute_dtype
    raw_model = DecoderModel(cfg)
    pk_models = {c: DecoderModel(cfg, kv_container=c) for c in CONTAINERS}
    params = raw_model.init(jax.random.PRNGKey(0))
    points = POINTS_QUICK if quick else POINTS_FULL

    ops.force_backend("ref")
    results = []
    try:
        for B in points:
            rng = np.random.RandomState(1)
            prompts = rng.randint(0, cfg.vocab, size=(B, PROMPT_LEN)
                                  ).astype(np.int32)
            max_len = PROMPT_LEN + MAX_NEW

            def timed(fn):
                fn()  # compile + warm caches
                t0 = time.perf_counter()
                fn()
                return time.perf_counter() - t0

            toks = B * MAX_NEW
            pj = jnp.asarray(prompts)
            dt_raw = timed(lambda: jax.block_until_ready(
                engine.generate(raw_model, params, pj, max_new=MAX_NEW,
                                max_len=max_len).tokens))
            point = {
                "B": B, "prompt_len": PROMPT_LEN, "max_new": MAX_NEW,
                "tok_per_s": {"bf16_contiguous": toks / dt_raw},
                "containers": {},
            }

            for cname in CONTAINERS:
                pk_model = pk_models[cname]
                fields = codecs.fields_for(cname, dtype)
                dt_pk = timed(lambda: jax.block_until_ready(
                    engine.generate(pk_model, params, pj, max_new=MAX_NEW,
                                    max_len=max_len).tokens))

                # One engine per point: its jitted step/scatter/burst
                # loops compile once (warmed by timed()'s first call);
                # each run gets a fresh scheduler and drains the pool
                # back to empty.
                eng = engine.PagedEngine(pk_model, params, max_slots=B,
                                         max_len=max_len)

                burst_stats = {}
                for K in bursts:
                    ttft_box = {}
                    sched_box = {}

                    def paged_run():
                        ttft_box.clear()
                        t0 = time.perf_counter()
                        sched = Scheduler(
                            eng, on_token=lambda uid, tok, done:
                            ttft_box.setdefault(
                                uid, time.perf_counter() - t0))
                        sched_box["s"] = sched
                        return sched.run(
                            [Request(uid=i, prompt=prompts[i],
                                     max_new=MAX_NEW) for i in range(B)],
                            burst=K)

                    dt_k = timed(paged_run)
                    # Percentiles from the scheduler's own obs histograms
                    # (the timed run's scheduler — warm caches, fresh
                    # registry per run).
                    sh = sched_box["s"]
                    burst_stats[str(K)] = {
                        "tok_per_s": toks / dt_k,
                        "ttft_s": float(np.mean(list(ttft_box.values()))),
                        **{f"ttft_s_p{q}": round(
                            sh._h_ttft.percentile(q / 100), 6)
                           for q in (50, 95, 99)},
                        **{f"token_latency_s_p{q}": round(
                            sh._h_tok.percentile(q / 100), 6)
                           for q in (50, 95, 99)},
                    }
                best_k = max(burst_stats,
                             key=lambda k: burst_stats[k]["tok_per_s"])

                traffic = _cache_traffic_model(
                    cfg, B, n_ctx=PROMPT_LEN + MAX_NEW // 2,
                    max_len=eng.max_len, block_l=eng.block_l, fields=fields)
                st = eng.pool.stats()
                point["containers"][cname] = {
                    "tok_per_s": {
                        "packed_contiguous": toks / dt_pk,
                        "paged_packed":
                            burst_stats[best_k]["tok_per_s"],
                    },
                    "paged_burst": burst_stats,
                    "paged_best_burst": int(best_k),
                    "hbm_cache_bytes_per_step": traffic,
                    "paged_bytes_vs_bf16": (traffic["paged_packed"]
                                            / traffic["bf16_contiguous"]),
                    # host-side admission accounting, in dense-packed
                    # bytes (pool.BlockPool): what one block really costs
                    # and the high-water mark this run touched.
                    "pool": {"block_bytes": int(st.block_bytes),
                             "capacity_bytes": int(st.capacity_bytes),
                             "peak_bytes": int(st.peak_bytes)},
                }
            first = point["containers"][CONTAINERS[0]]
            point["paged_bytes_vs_bf16"] = first["paged_bytes_vs_bf16"]
            results.append(point)
    finally:
        ops.force_backend(None)

    return {
        "backend": "ref",
        "dtype": str(jnp.dtype(dtype)),
        "containers": list(CONTAINERS),
        "bursts": [int(k) for k in bursts],
        "block_l": int(ops.DECODE_BLOCK_L),
        "points": results,
    }


def run_degraded(quick: bool = False) -> dict:
    """Degraded-mode section: fault-tolerant serving under an arrival
    flood with seeded bit-flip injection.

    Three scenarios on the same virtual-clock workload (waved flood,
    bounded queue, per-request deadlines):

      * ``unflooded``       — spread arrivals, no faults: the tok/s bar.
      * ``flood``           — thundering-herd waves, seeded bit flips,
                              pressure controller OFF (wide-geometry
                              admissions only): the shed baseline.
      * ``flood_degraded``  — same flood + flips with the precision-
                              downshift controller ON: new admissions
                              narrow to DEGRADED and are priced at the
                              narrower per-block bytes, so the same byte
                              budget runs more concurrent requests.

    Acceptance (asserted here): the controller sheds strictly fewer
    requests than the controller-off flood, and its paged tok/s stays
    within 10% of the unflooded run.
    """
    import jax

    from repro import configs
    from repro.configs.base import reduced
    from repro.kernels import ops
    from repro.models.model import DecoderModel
    from repro.serve import engine, faults, precision
    from repro.serve.scheduler import Request, Scheduler

    WIDE, DEGRADED = "sfp-m3e5", "sfp-m1e2"
    N, WAVE, WAVE_GAP = (12, 4, 8.0) if quick else (18, 6, 10.0)
    PROMPT, NEW = 100, 20
    MAX_PENDING, TTL = 6, 60.0
    NUM_BLOCKS, SLOTS = 4, 8

    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="bfloat16")
    model = DecoderModel(cfg, kv_container=WIDE)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = rng.randint(0, cfg.vocab, size=(N, PROMPT)).astype(np.int32)

    def reqs_for(flood: bool):
        out = []
        for i in range(N):
            t = (float(i // WAVE) * WAVE_GAP if flood
                 else float(i) * 3.0)  # spread: one every 3 virtual s
            out.append(Request(uid=i, prompt=prompts[i], max_new=NEW,
                               arrival=t, deadline=t + TTL))
        return out

    def scenario(eng, flood: bool, pressure, p_flip: float):
        def one_run():
            clock = {"t": 0.0}

            def now():
                clock["t"] += 1.0
                return clock["t"]

            hook = (faults.FaultInjector(eng, seed=11, p_flip=p_flip)
                    if p_flip else None)
            ttft = {}
            sched = Scheduler(
                eng, on_token=lambda uid, tok, done:
                ttft.setdefault(uid, sched.stats.decode_steps),
                max_pending=MAX_PENDING, pressure=pressure)
            t0 = time.perf_counter()
            sched.run(reqs_for(flood), now_fn=now, fault_hook=hook)
            dt = time.perf_counter() - t0
            if hook:
                hook.detach()
            sched.scrub_quarantined()  # restore the pool for the next run
            if pressure is not None:
                pressure.degraded = False
            s = sched.stats
            return {
                "tok_per_s": s.emitted_tokens / max(dt, 1e-9),
                **{f"ttft_s_p{q}": round(
                    sched._h_ttft.percentile(q / 100), 6)
                   for q in (50, 95, 99)},
                **{f"token_latency_s_p{q}": round(
                    sched._h_tok.percentile(q / 100), 6)
                   for q in (50, 95, 99)},
                "wall_s": round(dt, 3),
                "emitted_tokens": s.emitted_tokens,
                "mean_ttft_steps": (round(float(np.mean(
                    list(ttft.values()))), 2) if ttft else None),
                "finished_ok": s.finished,
                "shed_pct": round(100.0 * s.shed / N, 1),
                "deadline_miss_pct": round(
                    100.0 * s.deadline_misses / N, 1),
                "recoveries": s.recoveries,
                "corrupt_blocks": s.corrupt_blocks,
                "downshifted": s.downshifted,
                "preemptions": s.preemptions,
            }

        one_run()  # compile + warm caches
        return one_run()

    ops.force_backend("ref")
    try:
        eng_off = engine.PagedEngine(model, params, max_slots=SLOTS,
                                     max_len=256, num_blocks=NUM_BLOCKS)
        unflooded = scenario(eng_off, flood=False, pressure=None,
                             p_flip=0.0)
        flood_off = scenario(eng_off, flood=True, pressure=None,
                             p_flip=0.05)
        eng_on = engine.PagedEngine(model, params, max_slots=SLOTS,
                                    max_len=256, num_blocks=NUM_BLOCKS,
                                    degraded_container=DEGRADED)
        flood_on = scenario(
            eng_on, flood=True,
            pressure=precision.PressureController(low=0.6, high=0.85),
            p_flip=0.05)
    finally:
        ops.force_backend(None)

    assert flood_on["shed_pct"] < flood_off["shed_pct"], (
        f"pressure controller must shed strictly less than the "
        f"controller-off flood: {flood_on['shed_pct']}% vs "
        f"{flood_off['shed_pct']}%")
    assert flood_on["tok_per_s"] >= 0.9 * unflooded["tok_per_s"], (
        f"degraded-mode tok/s fell >10% below the unflooded run: "
        f"{flood_on['tok_per_s']:.1f} vs {unflooded['tok_per_s']:.1f}")
    return {
        "container": WIDE, "degraded_container": DEGRADED,
        "requests": N, "wave": WAVE, "wave_gap_s": WAVE_GAP,
        "max_pending": MAX_PENDING, "deadline_ttl_s": TTL,
        "num_blocks": NUM_BLOCKS, "max_slots": SLOTS,
        "p_flip": 0.05,
        "unflooded": unflooded,
        "flood": flood_off,
        "flood_degraded": flood_on,
    }


def run_speculation(quick: bool = False) -> dict:
    """Self-speculative decoding section: draft plane-depth x K sweep.

    One warm paged engine per sweep; each (draft_planes, K) point drives
    the same prompts through ``Scheduler.run(speculate=K)`` — K decode
    steps whose packed-KV reads expand only the leading ``draft_planes``
    bit planes, then one batched full-width verify that commits the
    longest matching prefix plus the verifier's correction token. Output
    is greedy-token-identical to ``burst=1`` by construction (asserted
    here against the baseline run), so the whole sweep is a pure
    throughput/acceptance trade: deeper drafts accept more but read more
    planes; larger K amortizes more dispatch overhead but risks longer
    rejected suffixes.

    Asserted acceptance: every point's acceptance rate is > 0, and the
    best point's tok/s >= the non-speculative ``burst=1`` baseline.
    """
    import jax

    from repro import codecs, configs
    from repro.configs.base import reduced
    from repro.kernels import ops
    from repro.models.model import DecoderModel
    from repro.serve import engine
    from repro.serve.scheduler import Request, Scheduler

    B = 2 if quick else 4
    KS = (2, 4) if quick else (2, 4, 8)
    CONTAINER = "sfp8"
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="bfloat16")
    model = DecoderModel(cfg, kv_container=CONTAINER)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(9)
    prompts = rng.randint(0, cfg.vocab, size=(B, PROMPT_LEN)
                          ).astype(np.int32)
    toks = B * MAX_NEW
    fields = codecs.fields_for(CONTAINER, cfg.compute_dtype)
    full = fields.payload_bits
    depths = ((full - 1,) if quick
              else tuple(sorted({fields.dexp_bits + 2, full - 1})))

    def timed(fn):
        fn()  # compile + warm caches
        t0 = time.perf_counter()
        out = fn()
        return time.perf_counter() - t0, out

    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=B,
                                 max_len=PROMPT_LEN + MAX_NEW)
        reqs = lambda: [Request(uid=i, prompt=prompts[i], max_new=MAX_NEW)
                        for i in range(B)]
        dt_base, base_out = timed(
            lambda: Scheduler(eng).run(reqs(), burst=1))
        base_tok_s = toks / dt_base

        points = {}
        for dp in depths:
            for K in KS:
                box = {}

                def spec_run():
                    sched = box["s"] = Scheduler(eng)
                    return sched.run(reqs(), speculate=K, draft_planes=dp)

                dt, out = timed(spec_run)
                for uid in base_out:  # token-identity vs burst=1
                    assert np.array_equal(base_out[uid], out[uid]), (
                        f"speculative stream diverged (uid={uid}, "
                        f"draft_planes={dp}, K={K})")
                s = box["s"].stats
                rate = s.draft_accepted / max(1, s.drafted)
                assert rate > 0, (dp, K, s.drafted, s.draft_accepted)
                points[f"p{dp}_k{K}"] = {
                    "draft_planes": dp, "K": K,
                    "tok_per_s": toks / dt,
                    "acceptance_rate": round(rate, 4),
                    "drafted": s.drafted,
                    "draft_accepted": s.draft_accepted,
                    "draft_rejected": s.draft_rejected,
                    "spec_rounds": s.spec_rounds,
                }
    finally:
        ops.force_backend(None)

    best = max(points, key=lambda k: points[k]["tok_per_s"])
    assert points[best]["tok_per_s"] >= base_tok_s, (
        f"best speculative point {best} ({points[best]['tok_per_s']:.1f} "
        f"tok/s) fell below the non-speculative burst=1 baseline "
        f"({base_tok_s:.1f} tok/s)")
    return {
        "container": CONTAINER, "B": B, "prompt_len": PROMPT_LEN,
        "max_new": MAX_NEW, "payload_bits": int(full),
        "draft_depths": [int(d) for d in depths], "Ks": [int(k) for k in KS],
        "tok_per_s_nonspec_burst1": round(base_tok_s, 2),
        "best_point": best,
        "speedup_vs_burst1": round(
            points[best]["tok_per_s"] / base_tok_s, 3),
        "points": points,
    }


def run_obs_overhead(quick: bool = False) -> dict:
    """Price the telemetry: the same paged workload with the default Obs
    (registry only — always on) vs the full surface (span tracer + a
    precision-timeline entry every scheduler step). Best-of-3 each on one
    warm engine. Asserted acceptance: full instrumentation keeps >= 95%
    of baseline tok/s — observability must never become the bottleneck it
    is supposed to find.
    """
    import jax

    from repro import configs
    from repro import obs as obs_mod
    from repro.configs.base import reduced
    from repro.kernels import ops
    from repro.models.model import DecoderModel
    from repro.serve import engine
    from repro.serve.scheduler import Request, Scheduler

    B = 2 if quick else 4
    K = 8
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="bfloat16")
    model = DecoderModel(cfg, kv_container="sfp8")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(5)
    prompts = rng.randint(0, cfg.vocab, size=(B, PROMPT_LEN)
                          ).astype(np.int32)
    toks = B * MAX_NEW

    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=B,
                                 max_len=PROMPT_LEN + MAX_NEW)

        def one(full: bool) -> float:
            obs = obs_mod.Obs(trace=True, timeline=True) if full else None
            sched = Scheduler(eng, obs=obs)
            t0 = time.perf_counter()
            sched.run([Request(uid=i, prompt=prompts[i], max_new=MAX_NEW)
                       for i in range(B)], burst=K)
            return toks / (time.perf_counter() - t0)

        one(False)  # compile + warm caches
        base = max(one(False) for _ in range(3))
        inst = max(one(True) for _ in range(3))
    finally:
        ops.force_backend(None)

    ratio = inst / base
    assert ratio >= 0.95, (
        f"full telemetry cost more than 5% tok/s: {inst:.1f} vs "
        f"{base:.1f} baseline (ratio {ratio:.3f})")
    return {
        "B": B, "burst": K, "best_of": 3,
        "tok_per_s_baseline": round(base, 2),
        "tok_per_s_instrumented": round(inst, 2),
        "ratio": round(ratio, 4),
    }


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="single small point (CI smoke)")
    ap.add_argument("--burst", type=str, default=None,
                    help="comma list of decode-burst lengths to sweep "
                         f"(default {','.join(map(str, BURSTS))})")
    ap.add_argument("--degraded", action="store_true",
                    help="add the fault-tolerance degraded-mode section "
                    "(flood + injected faults + pressure controller)")
    args = ap.parse_args(argv)
    bursts = (tuple(int(k) for k in args.burst.split(","))
              if args.burst else BURSTS)
    r = run(quick=args.quick, bursts=bursts)
    r["speculation"] = run_speculation(quick=args.quick)
    r["observability_overhead"] = run_obs_overhead(quick=args.quick)
    if args.degraded:
        r["degraded_mode"] = run_degraded(quick=args.quick)
    OUT.write_text(json.dumps(r, indent=2))
    print(json.dumps(r, indent=2))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
