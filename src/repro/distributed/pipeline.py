"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For depths beyond what TP x FSDP covers (or to span slow inter-pod links),
layers split into S stages along a `pipe` mesh axis; microbatches stream
through with the standard GPipe schedule expressed as a rotating shard_map
loop: each device holds one stage's parameters, activations move stage to
stage with ppermute, and the loop runs (n_micro + S - 1) ticks (bubble
included).

This module is self-contained and validated in tests/spmd (8 host
devices); the 512-chip dry-run meshes use TP x FSDP x DP which covers the
assigned model sizes (DESIGN.md §4).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x_micro: jax.Array, mesh: Mesh,
                   axis: str = "pipe") -> jax.Array:
    """Run microbatches through S pipeline stages.

    Args:
      stage_fn: (params_for_stage, h) -> h, applied by every device to the
        activation currently resident on it.
      stage_params: pytree whose leaves have leading dim S (one slice per
        stage); sharded over ``axis``.
      x_micro: (n_micro, mb, ...) microbatched input, replicated.
      mesh: mesh containing ``axis``.

    Returns (n_micro, mb, ...) outputs (as produced by the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, xs):
        # params_local: leaves (1, ...) — this device's stage.
        params_here = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            buf, outs = carry  # buf: activation resident on this device
            # stage 0 ingests microbatch t (when in range)
            feed = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jax.lax.dynamic_index_in_dim(xs, feed, keepdims=False)
            h = jnp.where(stage == 0, x_in, buf)
            h = stage_fn(params_here, h)
            # last stage emits microbatch (t - S + 1)
            out_idx = t - (n_stages - 1)
            emit = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                out_idx >= 0,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(
                    jnp.where(emit, h, o[jnp.maximum(out_idx, 0)])),
                lambda o: o, outs)
            # rotate activations to the next stage
            h_next = jax.lax.ppermute(h, axis, perm)
            return (h_next, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them to all.
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    from repro.distributed import sharding as _shd
    return _shd.shard_map(
        local, mesh=mesh,
        in_specs=(spec_params, P()), out_specs=P(),
        check_vma=False)(stage_params, x_micro)
