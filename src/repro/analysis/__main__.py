import sys

from repro.analysis.runner import main

sys.exit(main())
