"""Name-literal validation against the real registries.

The lint layer never reimplements the container/policy grammars: a
container literal is checked by resolving it through
``codecs.validate_name`` (registry + parametric factories), a policy
literal through ``policies.validate_name`` ('+'-composition parsed
without construction). Both return the registry's own did-you-mean
message on failure, so the lint and the launchers fail with identical
diagnostics.
"""
from __future__ import annotations

from typing import Optional


def check_container(name: str) -> Optional[str]:
    """None if ``name`` resolves as a container codec, else the error."""
    from repro import codecs
    try:
        codecs.validate_name(name)
        return None
    except ValueError as e:
        return str(e)


def check_policy(name: str) -> Optional[str]:
    """None if ``name`` parses as a policy ('+'-composition ok)."""
    from repro import policies
    try:
        policies.validate_name(name)
        return None
    except ValueError as e:
        return str(e)
