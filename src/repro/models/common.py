"""Shared model components: param factory, norms, embeddings, RoPE, MLP.

Parameters are plain nested dicts. ``ParamFactory`` lets the same builder
code produce real arrays (init), ShapeDtypeStructs (dry-run) or logical
sharding axes (pjit specs) — the three views stay in sync by construction.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

MODE_PARAMS = "params"
MODE_SHAPE = "shape"
MODE_AXES = "axes"


class ParamFactory:
    """One code path for params / shapes / logical axes."""

    def __init__(self, mode: str, key: Optional[jax.Array] = None,
                 dtype=jnp.bfloat16):
        self.mode = mode
        self.key = key
        self.dtype = jnp.dtype(dtype)
        self._counter = 0

    def _next_key(self):
        self._counter += 1
        return jax.random.fold_in(self.key, self._counter)

    def __call__(self, shape: Sequence[int], axes: Tuple[Optional[str], ...],
                 init: str = "normal", scale: Optional[float] = None,
                 dtype=None):
        shape = tuple(int(s) for s in shape)
        assert len(axes) == len(shape), (shape, axes)
        dtype = jnp.dtype(dtype) if dtype is not None else self.dtype
        if self.mode == MODE_AXES:
            return tuple(axes)
        if self.mode == MODE_SHAPE:
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
            scale = fan_in ** -0.5
        return (jax.random.normal(self._next_key(), shape, jnp.float32)
                * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(p: ParamFactory, dim: int, axis: str = "embed"):
    return {"scale": p((dim,), (axis,), init="zeros")}  # gemma-style (1+scale)


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings. x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angle = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(p: ParamFactory, vocab: int, d: int):
    # The table's model dim stays logically unsharded ("embed_r"): the
    # lookup shards the *vocab* dim over `model` (see sharded_embed) and the
    # tied unembed matmul contracts over the replicated d.
    return {"table": p((vocab, d), ("vocab", "embed_r"), scale=1.0)}


def sharded_embed(table: jax.Array, tokens: jax.Array, mesh) -> jax.Array:
    """Embedding lookup with the vocab dim sharded over `model`.

    A plain gather along a sharded axis triggers GSPMD "involuntary full
    rematerialization" (replicates the table AND scrambles downstream batch
    shardings). The manual form — local masked gather + psum over `model` —
    partitions exactly.
    """
    from jax.sharding import PartitionSpec as P

    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= sizes[a]
    if tokens.shape[0] % n_batch_shards != 0:  # e.g. batch=1 decode cells
        batch_axes = None

    def local(tab, tok):
        vloc = tab.shape[0]
        idx = jax.lax.axis_index("model")
        rel = tok - idx * vloc
        ok = (rel >= 0) & (rel < vloc)
        out = tab[jnp.clip(rel, 0, vloc - 1)]
        out = jnp.where(ok[..., None], out, jnp.zeros((), out.dtype))
        return jax.lax.psum(out, "model")

    from repro.distributed import sharding as _shd
    return _shd.shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None), P(batch_axes, None)),
        out_specs=P(batch_axes, None, None))(table, tokens)


def embed(params, tokens: jax.Array, scale: Optional[float] = None,
          mesh=None) -> jax.Array:
    if mesh is not None and "model" in mesh.axis_names:
        h = sharded_embed(params["table"], tokens, mesh)
    else:
        h = params["table"][tokens]
    if scale is not None:
        h = h * jnp.asarray(scale, h.dtype)
    return h


def unembed(params, h: jax.Array, *, tied: bool,
            softcap: Optional[float] = None,
            valid_vocab: Optional[int] = None) -> jax.Array:
    table = params["embed"]["table"] if tied else params["head"]
    if tied:
        logits = jnp.einsum("bsd,vd->bsv", h, table)
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, table)
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) < valid_vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Dense MLP (optionally gated)
# ---------------------------------------------------------------------------

def mlp_init(p: ParamFactory, d: int, ff: int, glu: bool):
    out = {
        "w_in": p((d, ff), ("embed", "ff")),
        "w_out": p((ff, d), ("ff", "embed")),
    }
    if glu:
        out["w_gate"] = p((d, ff), ("embed", "ff"))
    return out


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp(params, x: jax.Array, act: str, glu: bool) -> jax.Array:
    h = x @ params["w_in"]
    a = activation(act)(h.astype(jnp.float32)).astype(x.dtype)
    if glu:
        a = a * (x @ params["w_gate"])
    return a @ params["w_out"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 valid_mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy in fp32 over valid positions (vocab-shardable).

    The label pick uses an iota-compare reduction instead of
    take_along_axis: a gather along a model-sharded vocab axis would force
    GSPMD to all-gather the fp32 logits (hundreds of GB at 256k vocab),
    while the masked-sum partitions cleanly (each shard contributes its
    local match, one tiny all-reduce).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_ids == labels[..., None], logits, 0.0),
                     axis=-1)
    nll = lse - picked
    if valid_mask is None:
        return jnp.mean(nll)
    vm = valid_mask.astype(jnp.float32)
    return jnp.sum(nll * vm) / jnp.maximum(jnp.sum(vm), 1.0)
