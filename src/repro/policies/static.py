"""Baseline policies: no adaptation, and fixed (Gist-style) bitlengths."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import containers
from repro.policies import base


@dataclasses.dataclass(frozen=True)
class NonePolicy(base.Policy):
    """Full-precision baseline: every hook is a no-op."""

    name = "none"
    enabled = False

    def decision_summary(self, state, dims):
        return {"man_bits": float(dims.man_bits),
                "exp_bits": float(dims.exp_bits)}


@dataclasses.dataclass(frozen=True)
class StaticPolicy(base.Policy):
    """Fixed bitlengths everywhere (the paper's Gist-style ablation).

    ``static_exp_bits=None`` keeps the container's full exponent — the
    pre-registry behaviour; setting it exercises the same truncation path
    QE/BitWave drive adaptively.
    """

    static_act_bits: int = 3
    static_weight_bits: int = 7
    static_exp_bits: Optional[int] = None

    name = "static"

    @property
    def adapts_exponent(self):  # type: ignore[override]
        return self.static_exp_bits is not None

    def forward_view(self, learn, cview, dims):
        return {}

    def _exp(self, dims) -> jax.Array:
        e = dims.exp_bits if self.static_exp_bits is None else \
            self.static_exp_bits
        return jnp.asarray(e, jnp.int32)

    def act_decision(self, pslice, key, dims):
        return base.PrecisionDecision(
            man_bits=jnp.asarray(self.static_act_bits, jnp.int32),
            exp_bits=self._exp(dims))

    def quantize_act(self, x, pslice, key, dims):
        return base.apply_decision_ste(
            x, self.act_decision(pslice, key, dims), dims,
            adapts_exponent=self.adapts_exponent)

    def quantize_weight(self, w, pslice, key, dims):
        w = containers.truncate_mantissa(w, self.static_weight_bits)
        if self.adapts_exponent:
            w = containers.truncate_exponent(w, self.static_exp_bits)
        return w

    def decision_summary(self, state, dims):
        return {"man_bits": float(self.static_act_bits),
                "exp_bits": float(self.static_exp_bits
                                  if self.static_exp_bits is not None
                                  else dims.exp_bits)}
