"""Fixed-width SFP container codecs (sfp8 / sfp16 / parametric sfp*-m*e*).

Owns the container-name -> payload-geometry mapping (kernels are
format-agnostic bit machines taking a ``PackFields``):

  sfp8  byte = sign<<7 | dexp4<<3 | man3           (bf16-range payload)
  sfp16 word = sign<<15 | dexp5<<10 | manK<<(10-K) (K=10 fp32 / 7 bf16)

One shared 8-bit base exponent per 128-lane group (a Gecko column base).
``pack(x, bits)`` uses the *fused* quantize+pack kernel — the Quantum
Mantissa / BitChop truncation and the container encoding happen in a
single pass over the tensor (one HBM read instead of the old
mantissa_quantize -> sfp_compress two-kernel sequence).

Parametric names realize *policy-learned* geometries (deployment mode,
paper §IV-A4): ``sfp{8|16}-m{K}e{E}`` is a K-mantissa-bit,
E-delta-exponent-bit payload in an 8/16-bit word (e.g. ``sfp8-m3e4`` is
sfp8 by another name). They resolve through the codec factory hook, so a
serving pool can derive its container from a trained checkpoint's
PrecisionDecision without pre-registering every geometry.
"""
from __future__ import annotations

import math
import re

import jax
import jax.numpy as jnp

from repro.core import containers
from repro.codecs import base
from repro.kernels import ops
from repro.kernels.ref import GROUP, PackFields

SFP8 = "sfp8"
SFP16 = "sfp16"

_PARAM_NAME = re.compile(r"sfp(8|16)-m(\d+)e(\d+)$")


def fields_for(name: str, dtype_or_spec) -> PackFields:
    """Resolve a container name + source dtype to its payload geometry."""
    spec = (dtype_or_spec if isinstance(dtype_or_spec, containers.FloatSpec)
            else containers.spec_for(jnp.dtype(dtype_or_spec)))
    if name == SFP8:
        return PackFields(man_keep=3, dexp_bits=4, payload_bits=8)
    if name == SFP16:
        man_keep = 10 if spec.man_bits == 23 else 7
        return PackFields(man_keep=man_keep, dexp_bits=5, payload_bits=16)
    m = _PARAM_NAME.match(name)
    if m:
        payload, man, dexp = (int(g) for g in m.groups())
        # Clamp to what the word and the source dtype can actually hold —
        # the *name* records the learned decision; the realized geometry
        # never exceeds the payload (sign + dexp + man <= word) or keeps
        # more mantissa bits than the source has.
        dexp = max(1, min(dexp, payload - 2))
        man = max(1, min(man, payload - 1 - dexp, spec.man_bits))
        return PackFields(man_keep=man, dexp_bits=dexp, payload_bits=payload)
    raise ValueError(f"not an SFP container: {name!r}")


def maybe_codec(name: str):
    """Codec factory for parametric ``sfp{8|16}-m{K}e{E}`` names."""
    return SFPCodec(name) if _PARAM_NAME.match(name) else None


def _nd_layout(shape) -> bool:
    """Rank-preserving (sharding-friendly) layout when lanes align."""
    return len(shape) >= 1 and shape[-1] % GROUP == 0 and shape[-1] > 0


class SFPCodec(base.Codec):
    def __init__(self, name: str):
        self.name = name

    def _fields(self, dtype) -> PackFields:
        return fields_for(self.name, dtype)

    def pack_fields(self, dtype) -> PackFields:
        """SFP payloads have a fixed word geometry — consumers (the packed
        flash-decode kernel) may decompress them inline."""
        return self._fields(dtype)

    def pack(self, x: jax.Array, bits=None) -> base.PackedTensor:
        f = self._fields(x.dtype)
        if _nd_layout(x.shape):
            packed = ops.sfp_compress_nd(x, f, n=bits)
        elif bits is not None:
            packed = ops.sfp_quantize_compress(x, bits, f)
        else:
            packed = ops.sfp_compress(x, f)
        return base.PackedTensor(self.name, x.shape, x.dtype,
                                 {"payload": packed.payload,
                                  "bases": packed.bases})

    def unpack(self, packed: base.PackedTensor) -> jax.Array:
        f = self._fields(packed.dtype)
        raw = ops.Packed(payload=packed.data["payload"],
                         bases=packed.data["bases"])
        if _nd_layout(packed.shape):
            return ops.sfp_decompress_nd(raw, packed.dtype, f)
        return ops.sfp_decompress(raw, packed.shape, packed.dtype, f)

    def packed_bits(self, x: jax.Array, bits=None) -> float:
        """Realized byte-aligned footprint; fixed-width, so independent of
        the quantization signal ``bits`` (that's what makes SFP a
        *container*: the mantissa signal changes accuracy, not bytes).

        Matches pack()'s materialized arrays exactly: the flat layout
        zero-pads the tail to a full 128-lane row, and those pad lanes
        occupy real payload bytes.
        """
        f = self._fields(x.dtype)
        n = int(math.prod(x.shape)) if x.shape else 1
        if _nd_layout(x.shape):
            groups = n // GROUP
            payload_vals = n
        else:
            groups = -(-n // GROUP)
            payload_vals = groups * GROUP  # tail row padded to 128 lanes
        return float(payload_vals * f.payload_bits + groups * 8)
