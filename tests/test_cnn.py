"""Paper-model (CNN) tests: shapes, stash collection, short training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import policies
from repro.core import footprint
from repro.models import cnn
from repro.optim import adamw


def test_resnet8_forward_shapes_and_stash():
    m = cnn.CNN(cnn.RESNET8)
    params = m.init(jax.random.PRNGKey(0))
    batch = cnn.synthetic_images(jax.random.PRNGKey(1), 4, cnn.RESNET8)
    logits, stash = m.forward(params, batch["images"], collect_stash=True)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(stash) >= 6
    assert all(s["signless"] for s in stash[:-1])  # post-ReLU tensors


def test_resnet18_full_config_builds():
    m = cnn.CNN(cnn.RESNET18)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    import math
    n = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
    assert 10e6 < n < 13e6  # ~11.7M params


@pytest.mark.slow
def test_mobilenetv3_small_builds_and_runs():
    cfg = cnn.MOBILENETV3_SMALL
    import dataclasses
    small = dataclasses.replace(cfg, img_size=32, n_classes=10)
    m = cnn.CNN(small)
    params = m.init(jax.random.PRNGKey(0))
    batch = cnn.synthetic_images(jax.random.PRNGKey(1), 2, small)
    logits, stash = m.forward(params, batch["images"], collect_stash=True)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.slow
def test_cnn_trains_on_synthetic_blobs():
    m = cnn.CNN(cnn.RESNET8)
    params = m.init(jax.random.PRNGKey(0))
    st = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0)
    key = jax.random.PRNGKey(42)

    @jax.jit
    def step(params, st, batch):
        (l, aux), g = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
        params, st, _ = adamw.update(g, st, params, cfg,
                                     jnp.asarray(1e-2, jnp.float32))
        return params, st, l

    losses = []
    for i in range(50):
        batch = cnn.synthetic_images(jax.random.fold_in(key, i), 16,
                                     cnn.RESNET8)
        params, st, l = step(params, st, batch)
        losses.append(float(l))
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.3, losses[::8]


def test_cnn_qm_quantized_forward_close():
    pol = policies.get("qm", container="bit_exact")
    m = cnn.CNN(cnn.RESNET8, pol)
    params = m.init(jax.random.PRNGKey(0))
    batch = cnn.synthetic_images(jax.random.PRNGKey(1), 4, cnn.RESNET8)
    full, _ = m.forward(params, batch["images"])
    q, _ = m.forward(params, batch["images"],
                     act_bits=jnp.asarray(4.0, jnp.float32),
                     key=jax.random.PRNGKey(2))
    rel = float(jnp.max(jnp.abs(q - full)) / (jnp.max(jnp.abs(full)) + 1e-9))
    assert rel < 0.5


def test_footprint_on_cnn_stash():
    m = cnn.CNN(cnn.RESNET8)
    params = m.init(jax.random.PRNGKey(0))
    batch = cnn.synthetic_images(jax.random.PRNGKey(1), 2, cnn.RESNET8)
    _, stash = m.forward(params, batch["images"], collect_stash=True)
    t = stash[0]["tensor"]
    rep = footprint.sfp_footprint(t, 2, signless=stash[0]["signless"])
    assert rep.vs_fp32() < 0.5  # 2-bit mantissa + gecko + no sign << fp32
    js = footprint.js_bits(t)
    assert js > 0
