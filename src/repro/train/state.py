"""TrainState: everything a training step carries between steps."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitchop
from repro.optim import adamw


class QMState(NamedTuple):
    """Learned bitlength parameters (fp32) — paper eq. 7's n_i."""

    act: jax.Array       # (n_periods,)
    w: jax.Array         # (n_periods,)
    act_rem: jax.Array   # (n_rem,)
    w_rem: jax.Array     # (n_rem,)


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    qm: QMState
    bc: bitchop.BitChopState
    step: jax.Array
    rng: jax.Array
    # error-feedback residual for compressed cross-pod gradient all-reduce
    grad_residual: Any


def qm_init(cfg, init_bits: float) -> QMState:
    n_rem = len(cfg.remainder)
    full = lambda n: jnp.full((n,), init_bits, jnp.float32)
    return QMState(act=full(cfg.n_periods), w=full(cfg.n_periods),
                   act_rem=full(n_rem), w_rem=full(n_rem))
