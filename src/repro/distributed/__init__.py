"""Distribution substrate: sharding rules, pipeline parallelism, elasticity."""
