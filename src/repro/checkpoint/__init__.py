"""Checkpointing: atomic versioned save/restore, async writer, elastic reshard."""
