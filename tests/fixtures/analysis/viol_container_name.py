"""Seeded violations: container-name literals the registry cannot resolve."""
import argparse

from repro import codecs

codec = codecs.get("sfp9")  # LINT: container-name
kv_container = "spf8"  # LINT: container-name
opts = dict(container="gecko9")  # LINT: container-name

ap = argparse.ArgumentParser()
ap.add_argument("--kv-container", default="sfp_bogus")  # LINT: container-name
good = codecs.get("sfp8")
fine_container = "sfp-m2e4"
