"""Exporter-output validation against checked-in schemas (CI smoke).

A deliberately small JSON-Schema subset — ``type``, ``required``,
``properties``, ``additionalProperties`` (schema-valued), ``items``,
``enum``, ``anyOf``, ``minimum`` — implemented here because the CI image
installs no schema library and the hard no-new-deps rule holds. The
schemas live in ``tests/fixtures/obs/`` so a format drift fails CI with
a diffable fixture, exactly like the analysis fixtures pin lint rules.

CLI (what the CI observability smoke runs)::

    python -m repro.obs.validate \
        --metrics obs/metrics.prom --trace obs/trace.json \
        --timeline obs/timeline.jsonl --events obs/events.jsonl \
        --require-chain --require-downshift

Beyond schema-shape it checks the semantic acceptance criteria: the
Prometheus text parses and carries the TTFT/latency histograms, the
trace holds >=1 complete request span chain (submit -> queued ->
prefill -> decode -> retire), ``--require-downshift`` demands a
downshift-annotated prefill span, and every serve timeline entry's
per-geometry bytes sum exactly to the pool's ``used_bytes``.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Any

_TYPES = {
    "object": dict, "array": list, "string": str,
    "boolean": bool, "null": type(None),
}


def _type_ok(value: Any, t: str) -> bool:
    if t == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    if t == "number":
        return (isinstance(value, (int, float))
                and not isinstance(value, bool))
    return isinstance(value, _TYPES[t])


def validate(value: Any, schema: dict[str, Any],
             path: str = "$") -> list[str]:
    """Return a list of violation messages (empty == valid)."""
    errs: list[str] = []
    t = schema.get("type")
    if t is not None:
        types = t if isinstance(t, list) else [t]
        if not any(_type_ok(value, x) for x in types):
            return [f"{path}: expected {t}, got {type(value).__name__}"]
    if "enum" in schema and value not in schema["enum"]:
        errs.append(f"{path}: {value!r} not in {schema['enum']}")
    if "anyOf" in schema:
        branches = [validate(value, sub, path) for sub in schema["anyOf"]]
        if not any(not b for b in branches):
            errs.append(f"{path}: matched no anyOf branch "
                        f"({'; '.join(branches[0])})")
    if ("minimum" in schema and isinstance(value, (int, float))
            and not isinstance(value, bool) and value < schema["minimum"]):
        errs.append(f"{path}: {value} < minimum {schema['minimum']}")
    if isinstance(value, dict):
        for name in schema.get("required", ()):
            if name not in value:
                errs.append(f"{path}: missing required key {name!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for k, v in value.items():
            if k in props:
                errs.extend(validate(v, props[k], f"{path}.{k}"))
            elif isinstance(extra, dict):
                errs.extend(validate(v, extra, f"{path}.{k}"))
            elif extra is False:
                errs.append(f"{path}: unexpected key {k!r}")
    if isinstance(value, list) and "items" in schema:
        for i, v in enumerate(value):
            errs.extend(validate(v, schema["items"], f"{path}[{i}]"))
    return errs


def validate_jsonl(path: str, schema: dict[str, Any]) -> list[str]:
    errs: list[str] = []
    for i, line in enumerate(Path(path).read_text().splitlines()):
        if not line.strip():
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            errs.append(f"{path}:{i + 1}: not JSON ({e})")
            continue
        errs.extend(f"{path}:{i + 1}: {m}"
                    for m in validate(obj, schema, "$"))
    return errs


_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$")


def validate_prometheus(path: str,
                        require: tuple[str, ...] = ()) -> list[str]:
    """Check exposition-format shape + that required histograms exist
    with a terminating ``+Inf`` bucket."""
    errs: list[str] = []
    seen_inf: set[str] = set()
    text = Path(path).read_text()
    for i, line in enumerate(text.splitlines()):
        if not line or line.startswith("#"):
            continue
        if not _PROM_LINE.match(line):
            errs.append(f"{path}:{i + 1}: malformed sample line: {line!r}")
            continue
        name = line.split("{")[0].split(" ")[0]
        if name.endswith("_bucket") and 'le="+Inf"' in line:
            seen_inf.add(name[:-len("_bucket")])
    for name in require:
        if f"# TYPE {name} histogram" not in text:
            errs.append(f"{path}: missing histogram {name}")
        elif name not in seen_inf:
            errs.append(f"{path}: {name} lacks a +Inf bucket")
    return errs


CHAIN_SPANS = ("queued", "prefill", "decode")
CHAIN_INSTANTS = ("submit", "retire")


def check_trace_chain(trace: dict[str, Any],
                      require_downshift: bool = False) -> list[str]:
    """>=1 lane carrying the full request span chain; optionally >=1
    prefill span annotated with a pressure downshift."""
    events = trace.get("traceEvents", [])
    by_tid: dict[int, dict[str, set[str]]] = {}
    for e in events:
        if e.get("ph") in ("X", "i"):
            d = by_tid.setdefault(e["tid"], {"X": set(), "i": set()})
            d[e["ph"]].add(e["name"])
    complete = [tid for tid, d in by_tid.items()
                if set(CHAIN_SPANS) <= d["X"]
                and set(CHAIN_INSTANTS) <= d["i"]]
    errs: list[str] = []
    if not complete:
        errs.append("trace: no lane has a complete request span chain "
                    f"(need spans {CHAIN_SPANS} + instants "
                    f"{CHAIN_INSTANTS})")
    if require_downshift:
        hit = any(e.get("ph") == "X" and e.get("name") == "prefill"
                  and e.get("args", {}).get("downshift")
                  for e in events)
        if not hit:
            errs.append("trace: no downshift-annotated prefill span")
    return errs


def check_timeline_accounting(path: str) -> list[str]:
    """Per-step geometry bytes must byte-agree with pool accounting."""
    errs: list[str] = []
    for i, line in enumerate(Path(path).read_text().splitlines()):
        if not line.strip():
            continue
        e = json.loads(line)
        if e.get("kind") != "serve":
            continue
        geo = sum(e["geometry_bytes"].values())
        if geo != e["used_bytes"]:
            errs.append(f"{path}:{i + 1}: geometry_bytes sum {geo} != "
                        f"used_bytes {e['used_bytes']}")
        if e["used_bytes"] + e["free_bytes"] != e["capacity_bytes"]:
            errs.append(f"{path}:{i + 1}: used+free != capacity")
    return errs


def _load_schema(schemas_dir: str, name: str) -> dict[str, Any]:
    return json.loads((Path(schemas_dir) / name).read_text())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="validate obs exporter output against the checked-in "
                    "schemas")
    ap.add_argument("--metrics", help="prometheus text file")
    ap.add_argument("--trace", help="Chrome trace_event JSON")
    ap.add_argument("--timeline", help="precision-timeline JSONL")
    ap.add_argument("--events", help="structured-event JSONL")
    ap.add_argument("--schemas-dir", default="tests/fixtures/obs")
    ap.add_argument("--require-chain", action="store_true",
                    help="demand >=1 complete request span chain and the "
                         "TTFT/latency histograms")
    ap.add_argument("--require-downshift", action="store_true",
                    help="demand a downshift-annotated prefill span")
    args = ap.parse_args(argv)

    errs: list[str] = []
    if args.metrics:
        req = (("serve_ttft_seconds", "serve_token_latency_seconds")
               if args.require_chain else ())
        errs += validate_prometheus(args.metrics, req)
    if args.trace:
        trace = json.loads(Path(args.trace).read_text())
        errs += validate(trace, _load_schema(args.schemas_dir,
                                             "trace.schema.json"), "trace")
        if args.require_chain or args.require_downshift:
            errs += check_trace_chain(trace, args.require_downshift)
    if args.timeline:
        errs += validate_jsonl(args.timeline,
                               _load_schema(args.schemas_dir,
                                            "timeline.schema.json"))
        errs += check_timeline_accounting(args.timeline)
    if args.events:
        errs += validate_jsonl(args.events,
                               _load_schema(args.schemas_dir,
                                            "events.schema.json"))
    for e in errs:
        print(f"[obs.validate] {e}")
    print(f"[obs.validate] {'FAIL' if errs else 'ok'} "
          f"({len(errs)} violation(s))")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
