"""A representative clean module: every rule stays silent here."""
import jax
import jax.numpy as jnp

from repro import codecs, policies


def step(x):
    y = jnp.mean(x) * 2
    return jnp.where(y > 0, y, -y)


loss = jax.jit(step)(jnp.zeros((4,)))
host_loss = float(loss)  # outside any traced scope: fine

codec = codecs.get("sfp8")
kv_container = "sfp-m2e4"
policy = "qm+qe"
resolved = policies.validate_name(policy)
