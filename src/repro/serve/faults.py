"""Deterministic fault injection for the paged serving stack.

The serving analogue of the train loop's chaos hook (train/loop.py's
``fault_hook(step)`` fires before every train step): a ``FaultInjector``
is a callable passed to ``Scheduler.run(fault_hook=...)`` and fires
before every scheduler step, seeded so every chaos run is reproducible.

Three fault families, matching what the fault-tolerance layer defends
against:

* **Bit flips** in packed KV planes (``flip_random_bit`` /
  ``p_flip``) — in-memory corruption of allocated blocks, detected by
  the engine's per-block checksums before the next gather.
* **Poisoned bases** (``poison_block_bases``) — a block whose group
  exponents are forced to the top of the range so decompression produces
  non-finite values: corruption the NaN/Inf logit guard must catch when
  checksum integrity is off (or for decodable-but-wrong planes).
* **Alloc failures** (``p_alloc_fail``) — the pool transiently refuses
  an admission-time allocation (the wrapper only fires for slots that
  own nothing yet, so running slots' growth is never sabotaged — that is
  the scheduler's own preemption path); the scheduler must requeue
  gracefully, not crash.

Arrival floods — the third chaos axis — are a workload property, not an
injected fault: drive them with many same-arrival requests (see
``launch/serve.py --trace --flood`` and bench_serve's degraded section).

Every injected fault is appended to ``events`` for test assertions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.engine import PagedEngine


@dataclasses.dataclass
class FaultEvent:
    step: int
    kind: str                   # bit_flip | poison_bases | alloc_fail
    detail: Dict[str, Any]


class FaultInjector:
    def __init__(self, engine: PagedEngine, seed: int = 0,
                 p_flip: float = 0.0, p_alloc_fail: float = 0.0):
        self.engine = engine
        self.rng = np.random.RandomState(seed)
        self.p_flip = float(p_flip)
        self.p_alloc_fail = float(p_alloc_fail)
        self.events: List[FaultEvent] = []
        self._step = -1
        self._armed_alloc_fails = 0
        self._orig_alloc = None
        if self.p_alloc_fail > 0:
            self.attach_alloc_failures()

    # -- bit flips -------------------------------------------------------

    def flip_random_bit(self, step: int = -1) -> Optional[int]:
        """Flip one seeded-random bit in a random *allocated* block's
        packed planes; returns the physical block id (None when nothing
        is allocated — there is no victim to corrupt)."""
        owned = self.engine.pool.owned_ids()
        if not owned:
            return None
        phys = int(owned[self.rng.randint(len(owned))])
        detail = {"phys": phys,
                  "layer": int(self.rng.randint(1 << 16)),
                  "field": int(self.rng.randint(4)),
                  "row": int(self.rng.randint(1 << 16)),
                  "col": int(self.rng.randint(1 << 16)),
                  "bit": int(self.rng.randint(32))}
        self.engine.corrupt_block(phys, layer=detail["layer"],
                                  field=detail["field"], row=detail["row"],
                                  col=detail["col"], bit=detail["bit"])
        self.events.append(FaultEvent(step, "bit_flip", detail))
        return phys

    def poison_block_bases(self, phys: int, value: int = 0xFF,
                           step: int = -1) -> None:
        """Force every group base of block ``phys`` to ``value``: the
        shared exponents saturate, decompression goes non-finite, and the
        NaN/Inf logit guard (not the checksum) must catch it."""
        eng = self.engine
        for grp, key in eng._global_entries():
            kv = eng.mem[grp][key]

            def setrow(a):
                idx = ((slice(None), int(phys)) if a.ndim == 4
                       else (int(phys),))
                fill = np.array(value).astype(a.dtype)
                return a.at[idx].set(fill)

            eng.mem[grp][key] = kv._replace(k_bases=setrow(kv.k_bases),
                                            v_bases=setrow(kv.v_bases))
        self.events.append(FaultEvent(step, "poison_bases",
                                      {"phys": int(phys), "value": value}))

    # -- alloc failures --------------------------------------------------

    def attach_alloc_failures(self) -> None:
        """Wrap ``pool.alloc_upto`` so armed failures refuse admission-time
        allocations (slots owning nothing yet) once each."""
        if self._orig_alloc is not None:
            return
        pool = self.engine.pool
        orig = self._orig_alloc = pool.alloc_upto

        def alloc_upto(slot, n_tokens, block_bytes=None):
            if self._armed_alloc_fails > 0 and pool.slot_blocks(slot) == 0:
                self._armed_alloc_fails -= 1
                self.events.append(FaultEvent(
                    self._step, "alloc_fail",
                    {"slot": int(slot), "n_tokens": int(n_tokens)}))
                return False
            return orig(slot, n_tokens, block_bytes=block_bytes)

        pool.alloc_upto = alloc_upto

    def arm_alloc_failure(self, n: int = 1) -> None:
        """Deterministically arm ``n`` one-shot admission alloc failures
        (the probabilistic path arms these via ``p_alloc_fail``)."""
        self.attach_alloc_failures()
        self._armed_alloc_fails += int(n)

    def detach(self) -> None:
        """Restore the unwrapped allocator."""
        if self._orig_alloc is not None:
            self.engine.pool.alloc_upto = self._orig_alloc
            self._orig_alloc = None

    # -- the hook --------------------------------------------------------

    def __call__(self, step: int) -> None:
        self._step = step
        if self.p_flip and self.rng.random_sample() < self.p_flip:
            self.flip_random_bit(step)
        if self.p_alloc_fail and self.rng.random_sample() < self.p_alloc_fail:
            self._armed_alloc_fails += 1

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out
