"""SPMD correctness worker — run in a subprocess with 8 host devices.

Checks (each prints PASS <name>):
  sharded_vs_single : pjit train step == single-device numerics
  sharded_embed     : shard_map lookup == plain gather
  pipeline          : GPipe ppermute schedule == sequential stages
  grad_compress     : psum_compressed error-feedback collective
  elastic           : checkpoint saved on (4,2) mesh restores on (2,2)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs, policies
from repro.configs.base import reduced
from repro.distributed import pipeline as pp, sharding as shd
from repro.models import common
from repro.models.model import DecoderModel
from repro.optim.schedule import Schedule
from repro.train import grad_compress, step as step_mod
from repro.train.state import TrainState


def make_mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


def test_sharded_vs_single():
    cfg = dataclasses.replace(reduced(configs.get("gemma2-2b")),
                              dtype="float32")
    tc = step_mod.TrainConfig(schedule=Schedule(total_steps=5,
                                                warmup_steps=0),
                              num_microbatches=2)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    # single-device reference
    model0 = DecoderModel(cfg, policies.get("none"))
    step0 = jax.jit(step_mod.make_train_step(model0, tc))
    state0 = step_mod.init_state(model0, jax.random.PRNGKey(0), tc)
    s0, m0 = step0(state0, batch)

    # sharded
    mesh = make_mesh()
    rules = shd.rules_for(mesh)
    model1 = DecoderModel(cfg, policies.get("none"), mesh=mesh)
    step1 = step_mod.make_train_step(model1, tc)
    state1 = step_mod.init_state(model1, jax.random.PRNGKey(0), tc)
    param_sh = shd.tree_shardings(mesh, model1.param_axes(), rules)
    param_sh = shd.refine_shardings(jax.eval_shape(lambda: state1.params),
                                    param_sh, mesh)
    repl = shd.replicated(mesh)
    state_sh = TrainState(
        params=param_sh,
        opt=state1.opt._replace(m=param_sh, v=param_sh, count=repl),
        pstate=jax.tree.map(lambda _: repl, state1.pstate),
        step=repl, rng=repl, grad_residual=None)
    batch_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    with mesh:
        jstep = jax.jit(step1, in_shardings=(state_sh, batch_sh))
        state1 = jax.device_put(state1, state_sh)
        batch1 = jax.device_put(batch, batch_sh)
        s1, m1 = jstep(state1, batch1)

    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=2e-4)
    np.testing.assert_allclose(float(m0["grad_norm"]),
                               float(m1["grad_norm"]), rtol=2e-3)
    # parameters after one step agree
    w0 = jax.tree.leaves(s0.params)[1]
    w1 = jax.tree.leaves(s1.params)[1]
    np.testing.assert_allclose(np.asarray(w0), np.asarray(w1),
                               atol=3e-5, rtol=1e-3)
    print("PASS sharded_vs_single")


def test_sharded_embed():
    mesh = make_mesh()
    table = jax.random.normal(jax.random.PRNGKey(0), (64, 16), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 5), 0, 64)
    with mesh:
        table_s = jax.device_put(
            table, NamedSharding(mesh, P("model", None)))
        got = jax.jit(lambda t, tok: common.sharded_embed(t, tok, mesh))(
            table_s, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(table[tokens]),
                               rtol=1e-6)
    print("PASS sharded_embed")


def test_pipeline():
    mesh = jax.make_mesh((8,), ("pipe",))
    S, d = 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * 0.3

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, d))  # 6 microbatches
    got = pp.pipeline_apply(stage_fn, ws, x, mesh, axis="pipe")

    want = x
    for s in range(S):
        want = jax.vmap(lambda mb: stage_fn(ws[s], mb))(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    print("PASS pipeline")


def test_grad_compress():
    mesh = jax.make_mesh((8,), ("pods",))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 32))}
    res = {"w": jnp.zeros((8, 32))}

    def f(g, r):
        def local(gl, rl):
            out, new_r = grad_compress.psum_compressed(
                {"w": gl}, {"w": rl}, bits=3, axis_name="pods")
            return out["w"], new_r["w"]
        from repro.distributed import sharding as shd
        return shd.shard_map(local, mesh=mesh,
                             in_specs=(P("pods", None), P("pods", None)),
                             out_specs=(P(None, None), P("pods", None)),
                             check_vma=False)(g, r)

    summed, new_res = jax.jit(f)(grads["w"], res["w"])
    exact = jnp.mean(grads["w"], axis=0)
    got = summed[0]
    # 3-bit mantissa + bf16 wire: coarse but correlated; residual holds error
    cos = float(jnp.sum(got * exact)
                / (jnp.linalg.norm(got) * jnp.linalg.norm(exact)))
    assert cos > 0.97, cos
    assert float(jnp.max(jnp.abs(new_res))) > 0
    print("PASS grad_compress")


def test_elastic():
    from repro.checkpoint.manager import CheckpointManager
    from repro.distributed import elastic

    cfg = reduced(configs.get("gemma2-2b"))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    tree_a = jax.device_put(tree, NamedSharding(mesh_a, P("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree_a)
        # "lose" half the fleet: remesh to (2, 2)
        plan = elastic.plan_remesh(4, cfg, global_batch=8, prefer_tp=2)
        mesh_b = elastic.build_mesh(plan)
        sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
        back = mgr.restore(1, tree, shardings=sh_b)
        np.testing.assert_allclose(np.asarray(back["w"]),
                                   np.asarray(tree["w"]), rtol=1e-6)
    print("PASS elastic")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    tests = {
        "sharded_vs_single": test_sharded_vs_single,
        "sharded_embed": test_sharded_embed,
        "pipeline": test_pipeline,
        "grad_compress": test_grad_compress,
        "elastic": test_elastic,
    }
    if which == "all":
        for f in tests.values():
            f()
    else:
        tests[which]()
    print("ALL OK")
