"""Fault-tolerant host training loop.

Responsibilities:
  * periodic async checkpoints (atomic; rollback-safe);
  * automatic restore-and-continue after a step failure (simulated node
    failure in tests): the loop re-places the last good checkpoint and
    replays the data stream from that step (deterministic corpus);
  * straggler watchdog: per-step wall-time deadline; breaches are logged
    and surfaced in metrics (on a real fleet this triggers hot-spare
    swap-in — see DESIGN.md §4);
  * metrics emission (JSONL) for the benchmark/figure scripts.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    metrics_file: Optional[str] = None
    step_deadline_s: Optional[float] = None  # straggler watchdog
    max_restarts: int = 3
    # JSON-able run metadata recorded in every checkpoint manifest (e.g.
    # the precision-policy name, so restores can sanity-check the state
    # tree they are about to fill). May be a callable(state) -> dict so
    # per-save dynamic metadata — the policy's *current* PrecisionDecision
    # summary, which policy-aware serving reads back — is stamped too.
    ckpt_extra: Optional[Any] = None
    # False -> append to an existing metrics file instead of truncating
    # it; segmented drivers (the per-layer-stash refresh loop) set this on
    # every segment after the first so one JSONL spans the whole run.
    metrics_truncate: bool = True


def _scalarize(v):
    """Metrics may be scalars or small arrays (per-scope bitlength
    trajectories); both must survive the JSONL sink."""
    a = np.asarray(v)
    return a.tolist() if a.ndim else float(a)


def _resolve_extra(extra, state):
    return extra(state) if callable(extra) else extra


@dataclasses.dataclass
class LoopResult:
    state: Any
    history: list
    restarts: int
    straggler_steps: int


def run(train_step: Callable, state: Any, batch_iter_factory:
        Callable[[int], Iterator[Dict[str, Any]]], cfg: LoopConfig,
        fault_hook: Optional[Callable[[int], None]] = None) -> LoopResult:
    """Run the loop. ``batch_iter_factory(start_step)`` must restart the
    stream at an arbitrary step (deterministic data). ``fault_hook`` lets
    tests inject failures at chosen steps."""
    mgr = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
           if cfg.ckpt_dir else None)
    history = []
    restarts = 0
    stragglers = 0
    mfile = Path(cfg.metrics_file) if cfg.metrics_file else None
    if mfile:
        mfile.parent.mkdir(parents=True, exist_ok=True)
        if cfg.metrics_truncate or not mfile.exists():
            mfile.write_text("")

    step = int(np.asarray(state.step))
    if mgr is not None and mgr.latest_step() is not None:
        latest = mgr.latest_step()
        state = mgr.restore(latest, state)
        step = int(np.asarray(state.step))

    while step < cfg.total_steps:
        batches = batch_iter_factory(step)
        try:
            for batch in batches:
                if step >= cfg.total_steps:
                    break
                if fault_hook is not None:
                    fault_hook(step)
                t0 = time.time()
                state, metrics = train_step(state, batch)
                metrics = {k: _scalarize(v) for k, v in metrics.items()}
                dt = time.time() - t0
                metrics["step"] = step
                metrics["step_time_s"] = dt
                if cfg.step_deadline_s and dt > cfg.step_deadline_s:
                    stragglers += 1
                    metrics["straggler"] = True
                history.append(metrics)
                if mfile and (step % cfg.log_every == 0
                              or step == cfg.total_steps - 1):
                    with mfile.open("a") as f:
                        f.write(json.dumps(metrics) + "\n")
                step += 1
                if mgr is not None and step % cfg.ckpt_every == 0:
                    mgr.save(step, state, blocking=False,
                             extra=_resolve_extra(cfg.ckpt_extra, state))
        except KeyboardInterrupt:
            raise
        except Exception as e:
            restarts += 1
            if mgr is None or restarts > cfg.max_restarts:
                raise
            mgr.wait()
            latest = mgr.latest_step()
            if latest is None:
                raise RuntimeError("step failed before first checkpoint") from e
            print(f"[loop] step {step} failed ({type(e).__name__}: {e}); "
                  f"restoring step {latest} (restart {restarts})")
            state = mgr.restore(latest, state)
            step = int(np.asarray(state.step))
            continue

    if mgr is not None:
        mgr.save(step, state, blocking=True,
                 extra=_resolve_extra(cfg.ckpt_extra, state))
    return LoopResult(state=state, history=history, restarts=restarts,
                      straggler_steps=stragglers)
