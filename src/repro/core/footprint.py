"""Bit-exact SFP footprint accounting (reproduces Table I / Fig 12 / Fig 13).

Computes, for a tensor and a container policy, exactly how many bits the
paper's variable-length encoding would write to off-chip memory:

  total = sign_bits + mantissa_bits + gecko(exponent_field)

plus the baselines (FP32, BF16) and the comparison schemes of Fig 13
(JS zero-skip and GIST++-style sparsity encoding).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import containers, gecko


@dataclasses.dataclass(frozen=True)
class FootprintReport:
    n_values: int
    sign_bits: int
    mantissa_bits: int
    exponent_bits: int
    metadata_bits: int

    @property
    def total_bits(self) -> int:
        return self.sign_bits + self.mantissa_bits + self.exponent_bits + self.metadata_bits

    def vs_fp32(self) -> float:
        return self.total_bits / (32.0 * max(self.n_values, 1))

    def vs_bf16(self) -> float:
        return self.total_bits / (16.0 * max(self.n_values, 1))

    def breakdown(self) -> Dict[str, float]:
        t = max(self.total_bits, 1)
        return {
            "sign": self.sign_bits / t,
            "mantissa": self.mantissa_bits / t,
            "exponent": self.exponent_bits / t,
            "metadata": self.metadata_bits / t,
        }


def sfp_footprint(x: jax.Array, mantissa_bits, *, exp_bits=None,
                  signless: bool = False,
                  gecko_mode: str = "delta") -> FootprintReport:
    """Exact SFP bits for tensor ``x`` stored at ``mantissa_bits`` mantissa.

    ``mantissa_bits`` may be a python int, a scalar, or fractional (QM's
    expectation: fractional n costs its expected bits). ``exp_bits``
    (Quantum Exponent / BitWave) prices the exponent field at the reduced
    bitlength: the exponents are first clamped to the e-bit range (what
    the policy actually stores), Gecko compresses the clamped stream, and
    the account takes min(gecko, e*n) — the raw reduced-width encoding is
    the fallback when flush-to-zero outliers poison the delta rows. None
    keeps the full container exponent (pre-QE behaviour). ``signless``
    models post-ReLU/softmax tensors whose sign bit is elided (§IV-D).
    """
    n = int(x.size)
    spec = containers.spec_for(x)
    if exp_bits is not None:
        e_clip = float(jnp.clip(jnp.asarray(exp_bits, jnp.float32),
                                containers.MIN_EXP_BITS, spec.exp_bits))
        e_int = int(-(-e_clip // 1))  # ceil: the realized container range
        x_e = containers.truncate_exponent(x, e_int)
        exp = containers.exponent_field(x_e)
        ebits = min(int(gecko.compressed_bits(exp, mode=gecko_mode)),
                    int(round(e_clip * n)))
    else:
        exp = containers.exponent_field(x)
        ebits = int(gecko.compressed_bits(exp, mode=gecko_mode))
    mbits = float(jnp.clip(jnp.asarray(mantissa_bits, jnp.float32), 0,
                           spec.man_bits)) * n
    return FootprintReport(
        n_values=n,
        sign_bits=0 if signless else n,
        mantissa_bits=int(round(mbits)),
        exponent_bits=ebits,
        metadata_bits=0,  # bitlength metadata: a few scalars/layer, negligible
    )


def sfp_js_footprint(x: jax.Array, mantissa_bits, *, signless: bool = False,
                     gecko_mode: str = "delta") -> FootprintReport:
    """SFP + JS zero-skip combination (paper §VI-B): one tag bit per value,
    containers only for the nonzeros — ReLU zeros otherwise poison the
    Gecko delta rows with exponent-field-0 outliers."""
    n = int(x.size)
    flat = x.reshape(-1)
    nz_mask = flat != 0
    nnz = int(jnp.sum(nz_mask))
    exp = containers.exponent_field(flat)
    exp_nz = jnp.where(nz_mask, exp, 127).astype(jnp.uint8)
    # account only nonzero exponents (hardware packs them densely; the
    # where() keeps this jit-friendly at identical group count, which makes
    # the estimate slightly conservative)
    nz_sorted = jnp.sort(exp_nz)  # cluster padding 127s together
    ebits = int(gecko.compressed_bits(nz_sorted, mode=gecko_mode))
    ebits = int(ebits * (nnz / max(n, 1)))
    mbits = float(jnp.clip(jnp.asarray(mantissa_bits, jnp.float32), 0,
                           containers.spec_for(x).man_bits)) * nnz
    return FootprintReport(
        n_values=n,
        sign_bits=(0 if signless else nnz),
        mantissa_bits=int(round(mbits)),
        exponent_bits=ebits,
        metadata_bits=n,  # 1 zero-tag bit per value
    )


def baseline_bits(x: jax.Array, fmt: str) -> int:
    n = int(x.size)
    return {"fp32": 32, "bf16": 16, "fp16": 16}[fmt] * n


def js_bits(x: jax.Array, base_bits: int = 16) -> int:
    """JS: sparse zero-skip with 1 extra bit per value (Fig 13 baseline)."""
    n = int(x.size)
    nnz = int(jnp.sum(x != 0))
    return n + nnz * base_bits


def gist_bits(x: jax.Array, base_bits: int = 16, *, relu_pool: bool = False) -> int:
    """GIST++-style: ReLU-pool tensors cost 1 bit/value; otherwise sparsity
    encoding is used only when it reduces footprint (the '++' refinement)."""
    n = int(x.size)
    if relu_pool:
        return n
    return min(baseline_bits(x, "bf16" if base_bits == 16 else "fp32"), js_bits(x, base_bits))


def container_realized_bits(x: jax.Array, container: str) -> int:
    """Byte-aligned on-TPU container sizes (DESIGN.md D3).

    Uncompressed baselines are priced here; realized containers delegate
    to the codec registry (the one owner of container layouts).
    """
    n = int(x.size)
    baseline = {"bf16": 16, "fp16": 16, "fp32": 32}
    if container in baseline:
        return n * baseline[container]
    from repro import codecs  # local import: codecs accounts via footprint
    return int(codecs.get(container).packed_bits(x))


def container_realized_report(x: jax.Array, container: str
                              ) -> FootprintReport:
    """Realized container footprint with a field-level breakdown.

    Prices what the packed arrays actually occupy — payload planes/words
    plus the shared group bases — not the idealized per-field bit counts:
    for SFP geometries the sign/mantissa/dexp planes are attributed to
    their fields (each plane is ``padded_n`` real bits, tail rows padded
    to 128 lanes) and the 8-bit group bases land in ``metadata_bits``, so
    ``total_bits == codecs.get(container).packed_bits(x)`` exactly. Codecs
    without a fixed payload geometry report their whole realized stream
    as ``exponent+mantissa`` via packed_bits with zero metadata split.
    """
    from repro import codecs  # local import: codecs accounts via footprint

    n = int(x.size)
    codec = codecs.get(container)
    fields = codec.pack_fields(x.dtype)
    total = int(codec.packed_bits(x))
    if fields is None:
        return FootprintReport(n_values=n, sign_bits=0, mantissa_bits=0,
                               exponent_bits=total, metadata_bits=0)
    groups = -(-n // 128)
    padded_n = groups * 128
    return FootprintReport(
        n_values=n,
        sign_bits=padded_n,
        mantissa_bits=padded_n * fields.man_keep,
        exponent_bits=padded_n * fields.dexp_bits,
        # group bases + fixed-lane slack bits the payload word wastes
        # (zero for dense geometries: payload == 1 + E + K there), plus
        # any flat-layout tail padding already inside padded_n
        metadata_bits=total - padded_n * (1 + fields.man_keep
                                          + fields.dexp_bits),
    )


def tensor_group_numels(tree) -> Dict[str, int]:
    """Flatten a pytree of arrays to {path: numel} for QM lambda weights."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[name] = int(leaf.size)
    return out
