import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import containers as C


@pytest.mark.parametrize("dtype,spec", [(jnp.float32, C.FP32),
                                        (jnp.bfloat16, C.BF16)])
def test_split_combine_roundtrip(dtype, spec):
    x = (jax.random.normal(jax.random.PRNGKey(0), (128,), jnp.float32) * 100
         ).astype(dtype)
    y = C.combine_fields(*C.split_fields(x), spec)
    np.testing.assert_array_equal(
        np.asarray(C.bitcast_to_int(x)), np.asarray(C.bitcast_to_int(y)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_truncate_full_bits_is_identity(dtype):
    spec = C.spec_for(jnp.dtype(dtype))
    x = (jax.random.normal(jax.random.PRNGKey(1), (257,), jnp.float32)
         ).astype(dtype)
    y = C.truncate_mantissa(x, spec.man_bits)
    np.testing.assert_array_equal(np.asarray(C.bitcast_to_int(x)),
                                  np.asarray(C.bitcast_to_int(y)))


def test_truncate_zero_bits_keeps_sign_exponent():
    x = jnp.asarray([1.75, -3.5, 0.0, 100.25], jnp.float32)
    y = C.truncate_mantissa(x, 0)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray([1.0, -2.0, 0.0, 64.0]))


def test_truncate_monotone_in_bits():
    """More bits always means error no larger (nested truncation)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,), jnp.float32) * 7
    prev_err = None
    for n in range(24):
        err = float(jnp.max(jnp.abs(x - C.truncate_mantissa(x, n))))
        if prev_err is not None:
            assert err <= prev_err + 1e-12
        prev_err = err


def test_truncate_nested():
    x = jax.random.normal(jax.random.PRNGKey(3), (512,), jnp.float32)
    a = C.truncate_mantissa(C.truncate_mantissa(x, 7), 3)
    b = C.truncate_mantissa(x, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_truncate_traced_n():
    x = jax.random.normal(jax.random.PRNGKey(4), (64,), jnp.float32)
    f = jax.jit(lambda x, n: C.truncate_mantissa(x, n))
    np.testing.assert_array_equal(np.asarray(f(x, jnp.int32(5))),
                                  np.asarray(C.truncate_mantissa(x, 5)))


def test_round_mantissa_error_le_truncate():
    x = jax.random.normal(jax.random.PRNGKey(5), (4096,), jnp.float32)
    for n in (2, 5, 9):
        e_r = float(jnp.mean(jnp.abs(x - C.round_mantissa(x, n))))
        e_t = float(jnp.mean(jnp.abs(x - C.truncate_mantissa(x, n))))
        assert e_r <= e_t


def test_round_mantissa_preserves_inf_nan():
    x = jnp.asarray([jnp.inf, -jnp.inf, jnp.nan, 1.5], jnp.float32)
    y = C.round_mantissa(x, 3)
    assert np.isinf(np.asarray(y)[0]) and np.isinf(np.asarray(y)[1])
    assert np.isnan(np.asarray(y)[2])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_round_mantissa_inf_nan_carry_guard(dtype):
    """The half-ULP add must never run on all-ones-exponent values: without
    the guard, +inf + half carries into a NaN bit pattern and a max-payload
    NaN rolls over past the exponent. Infinities stay *bit-exact* at every
    n; quiet NaNs stay NaN whenever their quiet bit survives (n >= 1); at
    n = man_bits the whole payload is preserved bit-exactly."""
    spec = C.spec_for(jnp.dtype(dtype))
    inf_bits = [spec.exp_mask << spec.exp_shift,
                (1 << spec.sign_shift) | (spec.exp_mask << spec.exp_shift)]
    u_inf = jnp.asarray(inf_bits, dtype=spec.int_dtype)
    x_inf = C.bitcast_to_float(u_inf, spec)
    for n in (0, 1, 2, spec.man_bits):
        y = C.round_mantissa(x_inf, n)
        np.testing.assert_array_equal(np.asarray(C.bitcast_to_int(y)),
                                      np.asarray(u_inf))

    # Quiet NaNs with assorted payloads (quiet bit = mantissa MSB).
    q = 1 << (spec.man_bits - 1)
    nan_bits = [(spec.exp_mask << spec.exp_shift) | q | p
                for p in (0, 1, 5, spec.man_mask >> 1)]
    u_nan = jnp.asarray(nan_bits, dtype=spec.int_dtype)
    x_nan = C.bitcast_to_float(u_nan, spec)
    for n in (1, 2, spec.man_bits):
        assert np.isnan(np.asarray(C.round_mantissa(x_nan, n),
                                   np.float32)).all(), n
    y = C.round_mantissa(x_nan, spec.man_bits)
    np.testing.assert_array_equal(np.asarray(C.bitcast_to_int(y)),
                                  np.asarray(u_nan))


def test_round_mantissa_carry_rounds_up_binade():
    """Mantissa carry into the exponent is the correct IEEE round-up."""
    x = jnp.asarray([1.9375, -1.9375], jnp.float32)  # 1.1111_2
    y = C.round_mantissa(x, 2)
    np.testing.assert_array_equal(np.asarray(y), np.asarray([2.0, -2.0]))


def test_stochastic_bitlength_expectation():
    n = jnp.asarray(3.3, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(0), 2000)
    draws = jax.vmap(lambda k: C.stochastic_bitlength(n, k, 7))(keys)
    mean = float(jnp.mean(draws.astype(jnp.float32)))
    assert abs(mean - 3.3) < 0.08
    assert set(np.unique(np.asarray(draws))) <= {3, 4}


def test_stochastic_bitlength_boundaries():
    """n = 0, n = max_bits, and out-of-range inputs never leave [0, max]."""
    key = jax.random.PRNGKey(1)
    for nf, expect in [(0.0, 0), (7.0, 7), (-3.2, 0), (11.5, 7)]:
        draws = jax.vmap(lambda k: C.stochastic_bitlength(
            jnp.asarray(nf, jnp.float32), k, 7))(jax.random.split(key, 64))
        assert set(np.unique(np.asarray(draws))) == {expect}, nf


def test_stochastic_bitlength_fractional_endpoints():
    """frac ~ 0 and frac ~ 1 collapse to (near-)deterministic draws."""
    keys = jax.random.split(jax.random.PRNGKey(2), 512)
    lo = jax.vmap(lambda k: C.stochastic_bitlength(
        jnp.asarray(3.0 + 1e-7, jnp.float32), k, 7))(keys)
    hi = jax.vmap(lambda k: C.stochastic_bitlength(
        jnp.asarray(4.0 - 1e-7, jnp.float32), k, 7))(keys)
    assert float(jnp.mean(lo.astype(jnp.float32))) < 3.05
    assert float(jnp.mean(hi.astype(jnp.float32))) > 3.95
    assert set(np.unique(np.asarray(lo))) <= {3, 4}
    assert set(np.unique(np.asarray(hi))) <= {3, 4}


def test_exponent_field_matches_numpy():
    x = jax.random.normal(jax.random.PRNGKey(6), (100,), jnp.float32) * 1e3
    e = np.asarray(C.exponent_field(x))
    expect = (np.asarray(x).view(np.uint32) >> 23) & 0xFF
    np.testing.assert_array_equal(e, expect.astype(np.uint8))
