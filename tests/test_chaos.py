"""Chaos suite for the fault-tolerant serving layer: deterministic fault
injection (bit flips, poisoned bases, alloc failures) against the paged
engine + scheduler, plus deadline/cancellation/shedding semantics, the
preemption-storm guard, and pressure-downshift graceful degradation.

The acceptance bar throughout: every corrupted block is detected and
quarantined, every recovered request's stream is token-identical to a
fault-free run, and every terminal outcome is recorded (no silent
drops). Runs on the ref attention backend — fault handling is host-side
control flow, so kernel bit-exactness is covered elsewhere
(test_paged_serve.py)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import reduced
from repro.kernels import ops
from repro.models.model import DecoderModel
from repro.serve import engine, faults, precision
from repro.serve.scheduler import Request, Scheduler


def _model(name, container, **over):
    cfg = dataclasses.replace(reduced(configs.get(name)), dtype="float32",
                              **over)
    return cfg, DecoderModel(cfg, kv_container=container)


def _prompts(rng, cfg, sizes):
    return [rng.randint(0, cfg.vocab, size=s).astype(np.int32)
            for s in sizes]


def _sfp8():
    cfg, model = _model("mistral-large-123b", "sfp8")
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _reqs(cfg, sizes, news, seed=0, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i, prompt=p, max_new=n, **kw)
            for i, (p, n) in enumerate(zip(_prompts(rng, cfg, sizes), news))]


# ---------------------------------------------------------------------------
# Block integrity: checksum detection, quarantine, recompute recovery
# ---------------------------------------------------------------------------


def test_bitflip_detected_quarantined_and_recovered_token_identical():
    """A seeded bit flip in a packed plane must be caught by the per-block
    checksum before the next gather, the block quarantined, and the owner
    recovered by recompute-from-prompt with a stream identical to the
    fault-free run."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128,
                                 num_blocks=4)
        base = Scheduler(eng).run(_reqs(cfg, [6, 9], [6, 6]))
        inj = faults.FaultInjector(eng, seed=3)

        def hook(step):
            if step == 2:
                assert inj.flip_random_bit(step) is not None

        sched = Scheduler(eng)
        out = sched.run(_reqs(cfg, [6, 9], [6, 6]), fault_hook=hook)
    finally:
        ops.force_backend(None)
    s = sched.stats
    assert s.corrupt_blocks == 1 and s.recoveries == 1
    assert s.failed == 0 and s.finished == 2
    # the flipped block itself is out of circulation
    flipped = inj.events[0].detail["phys"]
    assert flipped in eng.pool.quarantined_blocks
    # recovery is invisible in the token streams
    for uid in base:
        np.testing.assert_array_equal(out[uid], base[uid])
    assert any(r.recoveries == 1 for r in sched.results.values())
    eng.pool.verify_invariants()
    # scrubbing rehabilitates the block: pool back to full capacity
    assert sched.scrub_quarantined() == 1
    assert eng.pool.stats().quarantined == 0
    assert eng.pool.free_blocks == eng.pool.num_blocks
    eng.pool.verify_invariants()


def test_nan_guard_catches_corruption_without_checksums():
    """With integrity checksums off, poisoned group bases decompress to
    non-finite values; the NaN/Inf logit guard must quarantine the slot's
    blocks and recover the request token-identically."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128,
                                 num_blocks=4, integrity=False)
        base = Scheduler(eng).run(_reqs(cfg, [6, 9], [6, 6]))
        inj = faults.FaultInjector(eng, seed=0)

        def hook(step):
            if step == 2:
                inj.poison_block_bases(eng.pool.owned_ids()[0], step=step)

        sched = Scheduler(eng)
        out = sched.run(_reqs(cfg, [6, 9], [6, 6]), fault_hook=hook)
    finally:
        ops.force_backend(None)
    s = sched.stats
    assert s.corrupt_blocks == 0        # checksums are off
    assert s.nan_guard_trips == 1 and s.recoveries == 1
    assert s.failed == 0 and s.finished == 2
    assert len(eng.pool.quarantined_blocks) >= 1
    for uid in base:
        np.testing.assert_array_equal(out[uid], base[uid])
    eng.pool.verify_invariants()
    assert sched.scrub_quarantined() >= 1
    assert eng.pool.free_blocks == eng.pool.num_blocks


def test_sticky_fault_fails_request_after_max_recoveries():
    """A fault that recurs on every residency must not livelock: past
    ``max_recoveries`` the request is marked failed and the loop drains."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128,
                                 num_blocks=4)
        inj = faults.FaultInjector(eng, seed=1)

        def hook(step):
            if eng.pool.owned_ids():
                inj.flip_random_bit(step)  # corrupt every residency

        sched = Scheduler(eng, max_recoveries=1)
        out = sched.run(_reqs(cfg, [6], [6]), fault_hook=hook)
    finally:
        ops.force_backend(None)
    assert out == {}
    assert sched.results[0].status == "failed"
    assert sched.stats.failed == 1
    assert sched.stats.recoveries == 2  # initial + one retry, then give up
    assert sched.idle
    eng.pool.verify_invariants()
    assert sched.scrub_quarantined() == len(eng.pool.quarantined_blocks) == 0 \
        or eng.pool.stats().quarantined == 0


def test_alloc_failure_requeues_gracefully():
    """A transiently refused admission-time allocation (injected) must
    requeue the request — counted, not crashed — and the run still emits
    exactly the fault-free streams."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        base = Scheduler(eng).run(_reqs(cfg, [4, 4, 4], [3, 3, 3]))
        inj = faults.FaultInjector(eng, seed=0)
        inj.arm_alloc_failure()

        sched = Scheduler(eng)
        out = sched.run(_reqs(cfg, [4, 4, 4], [3, 3, 3]), fault_hook=inj)
        inj.detach()
    finally:
        ops.force_backend(None)
    assert sched.stats.alloc_failures == 1
    assert inj.counts() == {"alloc_fail": 1}
    assert sched.stats.finished == 3
    for uid in base:
        np.testing.assert_array_equal(out[uid], base[uid])
    eng.pool.verify_invariants()


def test_speculation_recovers_from_bitflip_token_identical():
    """Self-speculative rounds compose with block-integrity recovery: a
    seeded bit flip mid-run quarantines and recomputes exactly as under
    plain decode, the recovered streams equal a fault-free burst=1 run,
    and the draft bookkeeping survives the requeue (accepted + rejected
    == drafted, every request at one terminal outcome)."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128,
                                 num_blocks=4)
        base = Scheduler(eng).run(_reqs(cfg, [6, 9], [6, 6]))
        inj = faults.FaultInjector(eng, seed=3)

        def hook(step):
            if step == 2:
                assert inj.flip_random_bit(step) is not None

        sched = Scheduler(eng)
        out = sched.run(_reqs(cfg, [6, 9], [6, 6]), fault_hook=hook,
                        speculate=3)
    finally:
        ops.force_backend(None)
    s = sched.stats
    assert s.corrupt_blocks == 1 and s.recoveries == 1
    assert s.failed == 0 and s.finished == 2
    for uid in base:
        np.testing.assert_array_equal(out[uid], base[uid])
    # terminal accounting identity holds with speculation on
    assert (s.finished + s.deadline_misses + s.cancelled + s.shed
            + s.failed) == s.submitted == 2
    assert s.spec_rounds >= 1
    assert s.draft_accepted + s.draft_rejected == s.drafted > 0
    res = sched.results
    assert sum(r.drafted for r in res.values()) == s.drafted
    assert sum(r.draft_accepted for r in res.values()) == s.draft_accepted
    eng.pool.verify_invariants()


def test_speculation_under_flood_sheds_and_expires_accountably():
    """Speculation changes pacing, not outcomes: a flooded queue with a
    tight deadline and a bounded pending queue still routes every
    request to exactly one of ok/expired/shed, with finished streams
    token-identical to the burst=1 run of the same trace."""
    cfg, model, params = _sfp8()

    def reqs():
        return _reqs(cfg, [4] * 6, [4] * 6, deadline=2.0)

    def clock():
        t = {"v": 0.0}

        def now():
            t["v"] += 0.3
            return t["v"]

        return now

    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        b = Scheduler(eng, max_pending=4)
        base = b.run(reqs(), now_fn=clock())
        sched = Scheduler(eng, max_pending=4)
        out = sched.run(reqs(), now_fn=clock(), speculate=2)
    finally:
        ops.force_backend(None)
    s = sched.stats
    assert s.shed == b.stats.shed >= 1
    for st in (s, b.stats):
        assert (st.finished + st.deadline_misses + st.cancelled + st.shed
                + st.failed) == st.submitted == 6
    # speculation emits more tokens per clock tick, so it may *finish*
    # requests burst=1 let expire — but any request finished in both
    # runs must carry the identical greedy stream
    both = set(out) & set(base)
    assert s.finished >= b.stats.finished >= 1
    for uid in both:
        np.testing.assert_array_equal(out[uid], base[uid])


# ---------------------------------------------------------------------------
# Deadlines, cancellation, load shedding
# ---------------------------------------------------------------------------


def test_deadlines_expire_running_and_pending():
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128)
        sched = Scheduler(eng)
        rng = np.random.RandomState(0)
        p0, p1 = _prompts(rng, cfg, [4, 4])
        sched.submit(Request(uid=0, prompt=p0, max_new=50, deadline=4.0))
        sched.submit(Request(uid=1, prompt=p1, max_new=3, deadline=2.0))
        clock = {"t": 0.0}

        def now():
            clock["t"] += 1.0
            return clock["t"]

        out = sched.run(now_fn=now)
    finally:
        ops.force_backend(None)
    assert out == {}  # nobody finished ok
    assert sched.stats.deadline_misses == 2
    # the running request kept its partial output; the queued one never
    # got a slot (single-slot engine) and expired with none
    assert sched.results[0].status == "expired"
    assert len(sched.results[0].tokens) >= 1
    assert sched.results[1].status == "expired"
    assert len(sched.results[1].tokens) == 0
    assert sched.idle and eng.pool.used_blocks == 0
    eng.pool.verify_invariants()


def test_submit_rejects_absurd_deadlines():
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128)
        sched = Scheduler(eng)
        p = _prompts(np.random.RandomState(0), cfg, [4])[0]
        with pytest.raises(ValueError, match="absurd deadline"):
            sched.submit(Request(uid=0, prompt=p, max_new=2,
                                 arrival=5.0, deadline=5.0))
        with pytest.raises(ValueError, match="absurd deadline"):
            sched.submit(Request(uid=1, prompt=p, max_new=2,
                                 deadline=float("inf")))
    finally:
        ops.force_backend(None)


def test_cancellation_frees_blocks_immediately():
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128)
        sched = Scheduler(eng)
        for r in _reqs(cfg, [4, 4], [10, 10]):
            sched.submit(r)
        sched.step()                      # admits uid 0; uid 1 queues
        assert eng.pool.used_blocks == 1
        assert sched.cancel(0)            # running: blocks free now
        assert eng.pool.used_blocks == 0
        assert sched.cancel(1)            # pending: removed from the queue
        assert not sched.cancel(42)       # unknown uid
        assert not sched.cancel(0)        # already terminal
    finally:
        ops.force_backend(None)
    assert sched.stats.cancelled == 2 and sched.idle
    assert sched.results[0].status == "cancelled"
    assert len(sched.results[0].tokens) >= 1   # partial output kept
    assert sched.results[1].status == "cancelled"
    eng.pool.verify_invariants()


def test_bounded_queue_sheds_newest_explicitly():
    """6 same-instant arrivals against max_pending=2: the newest four are
    shed with a terminal record each — no silent drops — and the oldest
    two run to completion."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        sched = Scheduler(eng, max_pending=2)
        out = sched.run(_reqs(cfg, [4] * 6, [3] * 6))
    finally:
        ops.force_backend(None)
    assert sorted(out) == [0, 1]
    assert sched.stats.shed == 4 and sched.stats.finished == 2
    assert {u for u, r in sched.results.items() if r.status == "shed"} \
        == {2, 3, 4, 5}
    # every submitted request reached a terminal record
    assert sorted(sched.results) == [0, 1, 2, 3, 4, 5]
    assert all(len(out[u]) == 3 for u in (0, 1))


def test_requeued_requests_are_never_shed():
    """A preempted request holds emitted tokens; the bounded queue must
    shed fresh arrivals instead. Same thrash setup as the storm-guard
    test, plus late arrivals into a tiny queue."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=256,
                                 num_blocks=3)
        sched = Scheduler(eng, max_pending=2)
        reqs = _reqs(cfg, [126, 126], [6, 6])
        fresh = _reqs(cfg, [4, 4, 4], [2, 2, 2], seed=1)
        reqs += [dataclasses.replace(r, uid=10 + i, arrival=2.0)
                 for i, r in enumerate(fresh)]
        clock = {"t": 0.0}

        def now():
            clock["t"] += 1.0
            return clock["t"]

        out = sched.run(reqs, now_fn=now)
    finally:
        ops.force_backend(None)
    assert sched.stats.preemptions >= 1
    # both block-crossers finish despite one being preempted+requeued
    # while the queue sat over its bound; only fresh arrivals are shed
    assert all(len(out[u]) == 6 for u in (0, 1))
    shed = {u for u, r in sched.results.items() if r.status == "shed"}
    assert shed and shed.issubset({10, 11, 12})
    assert sched.results[1].status == "ok"
    eng.pool.verify_invariants()


# ---------------------------------------------------------------------------
# Preemption-storm guard + recompute budget (no livelock, no thrash)
# ---------------------------------------------------------------------------


def test_storm_guard_prevents_admit_preempt_thrash():
    """Two block-crossing requests over a 3-block pool thrash without the
    guard (admit -> grow -> preempt). With storm_guard the second request
    is held at admission until the first drains: zero preemptions,
    oldest finishes first, identical tokens."""
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=256,
                                 num_blocks=3)
        off = Scheduler(eng)
        out_off = off.run(_reqs(cfg, [126, 126], [6, 6]))
        done_order = []
        on = Scheduler(eng, storm_guard=True,
                       on_token=lambda uid, tok, done:
                       done_order.append(uid) if done else None)
        out_on = on.run(_reqs(cfg, [126, 126], [6, 6]))
    finally:
        ops.force_backend(None)
    assert off.stats.preemptions >= 1          # the thrashing baseline
    assert on.stats.preemptions == 0           # the guard removes it
    assert done_order == [0, 1]                # oldest-first progress
    for uid in out_off:
        np.testing.assert_array_equal(out_on[uid], out_off[uid])
    eng.pool.verify_invariants()


def test_recompute_budget_paces_requeued_prefills():
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=2, max_len=128)
        sched = Scheduler(eng, recompute_budget=1)
        # two requeued requests ready at once: the budget admits exactly
        # one per step (the first always goes — progress guarantee)
        for r in _reqs(cfg, [4, 4], [2, 2]):
            sched.pending.append(dataclasses.replace(r, requeued=True))
        sched.step()
        assert sched.stats.admitted == 1
        sched.step()
        assert sched.stats.admitted == 2
        out = sched.run()
        # and a genuinely thrashing workload still drains under budget
        sched2 = Scheduler(eng, recompute_budget=1)
        out2 = sched2.run(_reqs(cfg, [4, 4], [2, 2]))
    finally:
        ops.force_backend(None)
    assert sched.stats.recompute_tokens == 8   # both prompts re-prefilled
    assert all(len(out[u]) == 2 for u in (0, 1))
    assert all(len(out2[u]) == 2 for u in (0, 1))


# ---------------------------------------------------------------------------
# Graceful degradation: pressure-downshifted admissions
# ---------------------------------------------------------------------------


def test_pressure_downshifts_admissions_and_restores():
    """Under byte pressure new admissions downshift to the narrower dense
    geometry (priced at its rate, so more fit the budget); once pressure
    clears, later admissions restore to the wide geometry. Every result
    records the geometry it was served at."""
    cfg, model = _model("mistral-large-123b", "sfp-m3e5")
    params = model.init(jax.random.PRNGKey(0))
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=8, max_len=256,
                                 num_blocks=4,
                                 degraded_container="sfp-m1e2")
        assert eng.degraded_block_bytes < eng.block_bytes
        pc = precision.PressureController(low=0.6, high=0.85)
        sched = Scheduler(eng, pressure=pc)
        out = sched.run(_reqs(cfg, [100] * 8, [10] * 8))
    finally:
        ops.force_backend(None)
    s = sched.stats
    assert s.finished == 8 and all(len(out[u]) == 10 for u in range(8))
    assert s.downshifted >= 1
    containers = {u: r.container for u, r in sched.results.items()}
    assert set(containers.values()) == {"sfp-m3e5", "sfp-m1e2"}
    # FIFO admission under monotone pressure: the first admissions are
    # wide, the flood's tail downshifts
    assert containers[0] == "sfp-m3e5"
    # downshifted blocks were priced at the narrow rate, within budget
    st = eng.pool.stats()
    assert st.budget_bytes is not None and st.peak_bytes <= st.budget_bytes
    # more concurrent residencies than the wide rate alone could afford
    assert st.peak_bytes // eng.block_bytes < eng.pool.peak_used
    # pressure clears once the flood drains: the controller restores
    assert pc.update(st.free_bytes, st.capacity_bytes) is False
    eng.pool.verify_invariants()


def test_pressure_controller_hysteresis_and_validation():
    pc = precision.PressureController(low=0.25, high=0.5)
    assert pc.update(100, 100) is False      # all free
    assert pc.update(20, 100) is True        # below low -> degrade
    assert pc.update(40, 100) is True        # hysteresis: still degraded
    assert pc.update(60, 100) is False       # above high -> restore
    with pytest.raises(ValueError):
        precision.PressureController(low=0.5, high=0.25)
    with pytest.raises(ValueError):
        precision.PressureController(low=-0.1, high=0.5)


def test_scheduler_rejects_pressure_without_degraded_engine():
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=1, max_len=128)
        with pytest.raises(ValueError, match="degraded_container"):
            Scheduler(eng, pressure=precision.PressureController())
    finally:
        ops.force_backend(None)


# ---------------------------------------------------------------------------
# Bounded terminal history
# ---------------------------------------------------------------------------


def test_terminal_history_is_lru_bounded():
    cfg, model, params = _sfp8()
    ops.force_backend("ref")
    try:
        eng = engine.PagedEngine(model, params, max_slots=4, max_len=128)
        sched = Scheduler(eng, history_limit=4)
        sched.run(_reqs(cfg, [3] * 10, [1] * 10))
        keep = Scheduler(eng, history_limit=4, retain_history=True)
        keep.run(_reqs(cfg, [3] * 10, [1] * 10))
    finally:
        ops.force_backend(None)
    assert sched.stats.finished == 10          # work is never dropped
    assert len(sched.results) == 4             # records are LRU-bounded
    assert len(sched.finished) == 4
    assert sorted(sched.results) == [6, 7, 8, 9]  # newest survive
    assert len(keep.results) == 10             # opt-in full retention
