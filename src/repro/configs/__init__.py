"""Config registry: the 10 assigned architectures (+ the paper's own CNNs).

Importing this package registers every architecture; use
``repro.configs.get(name)`` / ``repro.configs.names()``.
"""
from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeConfig, SHAPES, LONG_CONTEXT_OK,
    cells_for, get, input_specs, names, reduced, register,
)
from repro.configs.gemma3_12b import GEMMA3_12B            # noqa: F401
from repro.configs.mistral_large_123b import MISTRAL_LARGE_123B  # noqa: F401
from repro.configs.gemma2_27b import GEMMA2_27B            # noqa: F401
from repro.configs.gemma2_2b import GEMMA2_2B              # noqa: F401
from repro.configs.olmoe_1b_7b import OLMOE_1B_7B          # noqa: F401
from repro.configs.phi35_moe import PHI35_MOE              # noqa: F401
from repro.configs.musicgen_large import MUSICGEN_LARGE    # noqa: F401
from repro.configs.mamba2_370m import MAMBA2_370M          # noqa: F401
from repro.configs.recurrentgemma_9b import RECURRENTGEMMA_9B  # noqa: F401
from repro.configs.paligemma_3b import PALIGEMMA_3B        # noqa: F401

ASSIGNED = [
    GEMMA3_12B, MISTRAL_LARGE_123B, GEMMA2_27B, GEMMA2_2B, OLMOE_1B_7B,
    PHI35_MOE, MUSICGEN_LARGE, MAMBA2_370M, RECURRENTGEMMA_9B, PALIGEMMA_3B,
]
