"""Per-step overhead of each precision policy vs the `none` baseline.

The paper's methods only pay off if the adaptation machinery is cheap
relative to the step it shrinks: this benchmark times one jitted train
step of the reduced gemma2-2b config under every registry policy (and the
composed qm+qe) and reports ms/step plus the overhead ratio against the
full-precision baseline. Emitted as BENCH_policies.json (repo root)
standalone or via benchmarks/run.py; the CI quick-smoke runs --quick
(fewer policies, fewer iters) on every push and the nightly emits the
full sweep.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

# The full sweep covers every registered policy (so future plugins are
# picked up automatically) plus the paper's headline composition.
EXTRA_COMPOSITIONS = ("qm+qe",)
POLICIES_QUICK = ("none", "qm", "qm+qe")
ITERS = 10
ITERS_QUICK = 3
OUT = Path(__file__).resolve().parent.parent / "BENCH_policies.json"


def _median_ms(fn, iters):
    fn()  # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def run(quick: bool = False) -> dict:
    from repro import configs, policies
    from repro.configs.base import reduced
    from repro.data import synthetic
    from repro.models.model import DecoderModel
    from repro.optim import adamw
    from repro.optim.schedule import Schedule
    from repro.train import step as step_mod

    names = (POLICIES_QUICK if quick
             else ("none",) + tuple(n for n in policies.names()
                                    if n != "none") + EXTRA_COMPOSITIONS)
    iters = ITERS_QUICK if quick else ITERS
    cfg = reduced(configs.get("gemma2-2b"), n_layers=4, d_model=128)
    dcfg = synthetic.SyntheticConfig(vocab=cfg.vocab, seq_len=64,
                                     global_batch=8, seed=0)
    corpus = synthetic.MarkovCorpus(dcfg)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch(0).items()}
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=5e-3),
        schedule=Schedule(total_steps=100, warmup_steps=4, base_lr=5e-3))

    results = {}
    for name in names:
        model = DecoderModel(cfg, policies.get(name, container="bit_exact"))
        step = jax.jit(step_mod.make_train_step(model, tc))
        state = step_mod.init_state(model, jax.random.PRNGKey(0), tc)

        def one(state=state, step=step):
            new_state, m = step(state, batch)
            jax.block_until_ready(m["loss"])

        results[name] = {"ms_per_step": _median_ms(one, iters)}

    base = results["none"]["ms_per_step"]
    for name in names:
        results[name]["overhead_vs_none"] = (
            results[name]["ms_per_step"] / base)

    return {
        "arch": cfg.name,
        "config": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                   "batch": 8, "seq": 64},
        "container": "bit_exact",
        "iters": iters,
        "policies": results,
    }


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer policies + iters (CI smoke)")
    args = ap.parse_args(argv)
    r = run(quick=args.quick)
    OUT.write_text(json.dumps(r, indent=2))
    print(json.dumps(r, indent=2))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
