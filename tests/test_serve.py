"""Prefill + decode must reproduce the full-sequence forward logits, for
every layer family; the compressed KV cache must stay close."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import reduced
from repro.models.model import DecoderModel, init_run_state
from repro.serve import engine, kvcache

FAMS = ["gemma3-12b", "mistral-large-123b", "mamba2-370m",
        "recurrentgemma-9b", "olmoe-1b-7b", "paligemma-3b"]


def _model(name):
    cfg = dataclasses.replace(reduced(configs.get(name)), dtype="float32")
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # droplessness
    return cfg, DecoderModel(cfg)


@pytest.mark.parametrize("name", FAMS)
@pytest.mark.slow
def test_prefill_decode_matches_forward(name):
    cfg, model = _model(name)
    params = model.init(jax.random.PRNGKey(0))
    B, S0, n_new = 2, 24, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S0 + n_new),
                                0, cfg.vocab)
    cond = (0.1 * jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.prefix_tokens, cfg.d_model))
        if cfg.prefix_tokens else None)
    P = cfg.prefix_tokens if cond is not None else 0

    run = init_run_state(cfg, jax.random.PRNGKey(3))
    full_logits, _ = model.forward(params, tokens, run, cond_embeddings=cond)

    logits, cache = model.prefill(params, tokens[:, :S0], P + S0 + n_new,
                                  cond_embeddings=cond)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full_logits[:, S0 - 1]),
                               atol=2e-2, rtol=2e-3)
    pos = P + S0
    for t in range(n_new):
        step_logits, cache = model.decode_step(
            params, cache, tokens[:, S0 + t: S0 + t + 1],
            jnp.asarray(pos + t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]),
            np.asarray(full_logits[:, S0 + t]), atol=2e-2, rtol=2e-3)


@pytest.mark.slow
def test_local_ring_cache_decode_matches_forward_long():
    """Decode past the window: ring buffer must stay correct."""
    cfg, model = _model("gemma3-12b")
    cfg = dataclasses.replace(cfg, window=16)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    run = init_run_state(cfg, jax.random.PRNGKey(3))
    full_logits, _ = model.forward(params, tokens, run)
    S0 = 8
    logits, cache = model.prefill(params, tokens[:, :S0], S)
    for t in range(S0, S):
        step_logits, cache = model.decode_step(
            params, cache, tokens[:, t:t + 1], jnp.asarray(t, jnp.int32))
        if t >= S - 3:
            np.testing.assert_allclose(
                np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, t]),
                atol=3e-2, rtol=3e-3)


@pytest.mark.slow
def test_generate_greedy_deterministic():
    cfg, model = _model("mistral-large-123b")
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    r1 = engine.generate(model, params, prompt, max_new=6)
    r2 = engine.generate(model, params, prompt, max_new=6)
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))
    assert r1.tokens.shape == (2, 6)
    assert int(jnp.max(r1.tokens)) < cfg.vocab


def test_packed_kv_decode_close_to_exact():
    cfg, model = _model("mistral-large-123b")
    params = model.init(jax.random.PRNGKey(0))
    B, L = 2, 32
    from repro.models import attention, common
    slot_p = params["periods"]
    p0 = jax.tree.map(lambda a: a[0], slot_p)["slot0"]["attn"]
    h_tok = 0.3 * jax.random.normal(jax.random.PRNGKey(4),
                                    (B, 1, cfg.d_model), jnp.float32)
    raw = attention.cache_init(cfg, "global", B, L, jnp.float32)
    packed = kvcache.packed_cache_init(cfg, "global", B, L)
    pos = jnp.asarray(0, jnp.int32)
    out_raw, raw = attention.attention_decode(p0, h_tok, raw, pos, cfg,
                                              kind="global")
    out_pk, packed = kvcache.attention_decode_packed(p0, h_tok, packed, pos,
                                                     cfg, kind="global")
    # sfp8 KV: 3 mantissa bits -> outputs agree to ~1e-1 relative
    denom = float(jnp.max(jnp.abs(out_raw))) + 1e-9
    assert float(jnp.max(jnp.abs(out_pk - out_raw))) / denom < 0.15


def test_packed_decode_generation_matches_uncompressed():
    """End-to-end: generation over the sfp16-packed KV cache (fused
    decompress-attend kernel, interpret backend) must match the raw-cache
    tokens exactly — sfp16 keeps 10 of fp32's 23 mantissa bits, plenty for
    greedy argmax stability at these scales."""
    from repro.kernels import ops
    cfg, model = _model("mistral-large-123b")
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    r_raw = engine.generate(model, params, prompt, max_new=5)
    packed_model = DecoderModel(cfg, kv_container="sfp16")
    r_ref = engine.generate(packed_model, params, prompt, max_new=5)
    np.testing.assert_array_equal(np.asarray(r_raw.tokens),
                                  np.asarray(r_ref.tokens))  # unpack path
    ops.force_backend("interpret")
    try:
        r_fused = engine.generate(packed_model, params, prompt, max_new=5)
    finally:
        ops.force_backend(None)
    np.testing.assert_array_equal(np.asarray(r_raw.tokens),
                                  np.asarray(r_fused.tokens))  # fused path


def test_packed_generation_rounded_cache_matches_raw():
    """A max_len past one kernel block rounds the packed allocation up to
    a block multiple (raw caches stay exact); the extra masked slots must
    not change the generated tokens."""
    cfg, model = _model("mistral-large-123b")
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    r_raw = engine.generate(model, params, prompt, max_new=4, max_len=200)
    packed_model = DecoderModel(cfg, kv_container="sfp16")
    r_pk = engine.generate(packed_model, params, prompt, max_new=4,
                           max_len=200)  # packed cache L = 256
    np.testing.assert_array_equal(np.asarray(r_raw.tokens),
                                  np.asarray(r_pk.tokens))


def test_pack_prefill_cache_shapes():
    cfg, model = _model("gemma3-12b")
    from repro.models import attention
    raw = attention.cache_init(cfg, "global", 2, 16, jnp.float32)
    pk = kvcache.pack_prefill_cache(raw)
    D = cfg.n_kv_heads * cfg.head_dim_
    assert pk.k.shape == (2, 16, D)
    assert pk.k.data["payload"].shape == (2, 16, D)
    assert pk.k.data["bases"].shape == (2, 16, D // 128)
    spec = kvcache.packed_cache_spec(cfg, "global", 2, 16)
    assert tuple(spec.k.data["payload"].shape) == (2, 16, D)
    assert spec.k.data["payload"].dtype == pk.k.data["payload"].dtype
    assert tuple(spec.v.data["bases"].shape) == (2, 16, D // 128)
    axes = kvcache.packed_cache_axes(cfg, "global", 2, 16)
    assert axes.k.data["payload"] == ("batch", "cache_seq", None)
    assert axes.k.data["bases"] == ("batch", "cache_seq", None)
