"""Compressed gradient synchronization (beyond-paper application).

The paper compresses tensors crossing the DRAM boundary; at multi-pod scale
the analogous expensive boundary is the cross-pod (DCN) gradient
all-reduce. We apply the same recipe: truncate gradient mantissas to a
small bitlength before the reduction and keep the truncation error in a
local *error-feedback* residual that is re-injected next step — the
standard convergence-preserving trick for biased compressors.

Two entry points:
  * compress_grads / error feedback — used inside the big pjit train step
    (XLA owns the actual collective; the entitlement is the truncated
    payload).
  * psum_compressed — explicit shard_map collective for the tested
    multi-device harness (tests/spmd/).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import containers


def compress_grads(grads: Any, residual: Any, bits: int) -> Tuple[Any, Any]:
    """Error-feedback mantissa truncation: returns (compressed, new_residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q = containers.truncate_mantissa(gf, bits)
        return q, gf - q

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_residual(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def psum_compressed(grads: Any, residual: Any, bits: int, axis_name: str
                    ) -> Tuple[Any, Any]:
    """shard_map building block: truncate -> bf16 -> psum -> mean.

    Payload on the wire: bf16 containers with ``bits``-bit mantissas (the
    Gecko exponent packing applies on top in the hardware realization; the
    bit-exact accounting lives in core.footprint).
    """
    q, new_res = compress_grads(grads, residual, bits)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        .astype(jnp.float32) / n, q)
    return summed, new_res
