"""Jitted dispatch wrappers: Pallas kernel on TPU, jnp reference elsewhere.

All model/runtime code calls through these so the same program runs on the
CPU test/dry-run environment (reference path; identical FLOP/byte shape)
and on real TPUs (Pallas path). ``force_backend()`` is the test hook.

These wrappers are format-agnostic: SFP entry points take a
``kernels.ref.PackFields`` payload geometry and the Gecko entry points take
raw exponent groups. Container *names* resolve to geometries in exactly
one place — the codec registry (``repro.codecs``) — which is also the only
API most callers should use.

The SFP packed representation is a plain (payload, bases) array pair —
array-only so it can ride through lax.scan as the compressed stash.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import bitplane_pack as _bp
from repro.kernels import flash_attention as _fa
from repro.kernels import gecko_pack as _gp
from repro.kernels import mantissa_quant as _mq
from repro.kernels import packed_flash_decode as _pfd
from repro.kernels import ref as _ref
from repro.kernels import sfp_pack as _sp

PackFields = _ref.PackFields  # re-export: the kernel-facing format descriptor
decode_kv_mask = _ref.decode_kv_mask  # shared ring-slot validity semantics
prefix_fields = _ref.prefix_fields  # truncated geometry of a draft read
DECODE_BLOCK_L = _pfd.DEFAULT_BLOCK_L  # flash-decode KV block (alloc hint)

_FORCED: Optional[str] = None  # None | 'pallas' | 'ref' | 'interpret'


def force_backend(name: Optional[str]) -> None:
    """Test hook: force 'pallas' (TPU), 'interpret' (CPU pallas), or 'ref'."""
    global _FORCED
    _FORCED = name


def backend() -> str:
    if _FORCED:
        return _FORCED
    return "pallas" if jax.default_backend() == "tpu" else "ref"


class Packed(NamedTuple):
    """SFP-compressed tensor: uint8/uint16 payload + per-group bases."""

    payload: jax.Array  # (R, 128) uint8 or uint16 payload words
    bases: jax.Array    # (R, 1) uint8 shared base exponents


# -- mantissa quantization ---------------------------------------------------

def mantissa_quantize(x: jax.Array, n) -> jax.Array:
    b = backend()
    if b in ("pallas", "interpret"):
        return _mq.mantissa_quantize(x, n, interpret=(b == "interpret"))
    return _ref.mantissa_truncate(x, n)


# -- SFP containers ----------------------------------------------------------
#
# Every entry point dispatches on ``fields.dense``: fixed-lane geometries
# (payload_bits 8/16) go through the word kernels in sfp_pack.py, dense
# sub-byte/odd-width geometries through the bit-plane kernels in
# bitplane_pack.py. Callers never branch — the PackFields carries the
# layout, the Packed pair carries either words or planes.

def sfp_compress(x: jax.Array, fields: PackFields) -> Packed:
    b = backend()
    if b in ("pallas", "interpret"):
        interp = (b == "interpret")
        if fields.dense:
            payload, bases = _bp.bitplane_pack(x, fields=fields,
                                               interpret=interp)
        else:
            payload, bases = _sp.sfp_pack(x, fields=fields, interpret=interp)
    elif fields.dense:
        payload, bases = _ref.bitplane_pack(x, fields)
    else:
        payload, bases = _ref.sfp_pack(x, fields)
    return Packed(payload=payload, bases=bases)


def sfp_decompress(packed: Packed, shape: tuple, dtype,
                   fields: PackFields) -> jax.Array:
    b = backend()
    if b in ("pallas", "interpret"):
        unpack = _bp.bitplane_unpack if fields.dense else _sp.sfp_unpack
        return unpack(packed.payload, packed.bases, shape=tuple(shape),
                      dtype=jnp.dtype(dtype), fields=fields,
                      interpret=(b != "pallas"))
    if fields.dense:
        return _ref.bitplane_unpack(packed.payload, packed.bases,
                                    tuple(shape), jnp.dtype(dtype), fields)
    return _ref.sfp_unpack(packed.payload, packed.bases, tuple(shape),
                           jnp.dtype(dtype), fields)


def sfp_compress_nd(x: jax.Array, fields: PackFields, n=None) -> Packed:
    """Rank-preserving pack (sharding-friendly; last dim % 128 == 0).

    ``n`` (optional traced scalar) fuses Q(M, n) mantissa truncation into
    the pack — a single HBM read instead of the mantissa_quantize ->
    sfp_compress_nd two-kernel sequence. Dense geometries emit bit planes:
    payload (*lead, (D//128) * P * 16) uint8 instead of (*lead, D) words.
    """
    b = backend()
    if b in ("pallas", "interpret"):
        # TPU path: the kernel operates on 128-lane rows; the reshape is a
        # no-op relayout on device. Interpret mode mirrors it for tests.
        rows = x.reshape(-1, _ref.GROUP)
        interp = (b == "interpret")
        if fields.dense:
            if n is None:
                payload, bases = _bp.bitplane_pack(rows, fields=fields,
                                                   interpret=interp)
            else:
                payload, bases = _bp.bitplane_quantize_pack(
                    rows, n, fields=fields, interpret=interp)
        elif n is None:
            payload, bases = _sp.sfp_pack(rows, fields=fields,
                                          interpret=interp)
        else:
            payload, bases = _sp.sfp_quantize_pack(rows, n, fields=fields,
                                                   interpret=interp)
        cols = fields.nd_payload_cols(x.shape[-1])
        return Packed(payload=payload.reshape(*x.shape[:-1], cols),
                      bases=bases.reshape(*x.shape[:-1],
                                          x.shape[-1] // _ref.GROUP))
    if fields.dense:
        payload, bases = _ref.bitplane_pack_nd(x, fields, n=n)
    else:
        payload, bases = _ref.sfp_pack_nd(x, fields, n=n)
    return Packed(payload=payload, bases=bases)


def sfp_decompress_nd(packed: Packed, dtype, fields: PackFields) -> jax.Array:
    b = backend()
    if b in ("pallas", "interpret"):
        G = packed.bases.shape[-1]
        shape = packed.bases.shape[:-1] + (G * _ref.GROUP,)
        if fields.dense:
            rows = packed.payload.reshape(-1, fields.group_payload_bytes)
            unpack = _bp.bitplane_unpack
        else:
            rows = packed.payload.reshape(-1, _ref.GROUP)
            unpack = _sp.sfp_unpack
        bases = packed.bases.reshape(-1, 1)
        return unpack(rows, bases, shape=shape, dtype=jnp.dtype(dtype),
                      fields=fields, interpret=(b != "pallas"))
    if fields.dense:
        return _ref.bitplane_unpack_nd(packed.payload, packed.bases,
                                       jnp.dtype(dtype), fields)
    return _ref.sfp_unpack_nd(packed.payload, packed.bases, jnp.dtype(dtype),
                              fields)


def sfp_quantize_compress(x: jax.Array, n, fields: PackFields) -> Packed:
    """Fused Q(M, n) + flat pack: one pass over ``x`` (single HBM read)."""
    b = backend()
    if b in ("pallas", "interpret"):
        interp = (b == "interpret")
        if fields.dense:
            payload, bases = _bp.bitplane_quantize_pack(
                x, n, fields=fields, interpret=interp)
        else:
            payload, bases = _sp.sfp_quantize_pack(x, n, fields=fields,
                                                   interpret=interp)
        return Packed(payload=payload, bases=bases)
    if fields.dense:
        payload, bases = _ref.bitplane_pack(x, fields, n=n)
    else:
        payload, bases = _ref.sfp_pack(x, fields, n=n)
    return Packed(payload=payload, bases=bases)


def sfp_roundtrip(x: jax.Array, fields: PackFields) -> jax.Array:
    """compress->decompress (fake-quant view of the realized container)."""
    return sfp_decompress(sfp_compress(x, fields), x.shape, x.dtype, fields)


# -- Gecko exponent compression ---------------------------------------------

def gecko_encode(groups: jax.Array):
    """(G, 64) uint8 exponent groups -> (bases, widths, planes)."""
    b = backend()
    if b in ("pallas", "interpret"):
        return _gp.gecko_pack(groups, interpret=(b == "interpret"))
    return _ref.gecko_plane_encode(groups)


def gecko_decode(bases: jax.Array, planes: jax.Array) -> jax.Array:
    """(bases (G, 8), planes (G, 63)) -> (G, 64) uint8 exponents."""
    b = backend()
    if b in ("pallas", "interpret"):
        return _gp.gecko_unpack(bases, planes, interpret=(b == "interpret"))
    return _ref.gecko_plane_decode(bases, planes)


# -- attention ---------------------------------------------------------------

def attention(q, k, v, *, causal=True, window=None, softcap=None,
              prefix_len: int = 0, q_offset: int = 0) -> jax.Array:
    """GQA attention; Pallas flash kernel on TPU, jnp reference off-TPU.

    GQA is native in the kernel: the q-head group is folded into the query
    rows (``q_rep``), so the KH-headed K/V are streamed once per group —
    no repeated-KV materialization in HBM.
    """
    b = backend()
    if b in ("pallas", "interpret") and prefix_len == 0 and q_offset == 0:
        B, Sq, H, D = q.shape
        KH = k.shape[2]
        rep = H // KH
        if rep > 1:
            # (B, Sq, KH, rep, D) -> rows ordered (seq, group): row r of the
            # folded query axis is seq r // rep, group member r % rep.
            qg = q.reshape(B, Sq, KH, rep, D).transpose(0, 1, 3, 2, 4)
            qg = qg.reshape(B, Sq * rep, KH, D)
            o = _fa.flash_attention(qg, k, v, causal=causal, window=window,
                                    softcap=softcap, q_rep=rep,
                                    interpret=(b == "interpret"))
            o = o.reshape(B, Sq, rep, KH, D).transpose(0, 1, 3, 2, 4)
            return o.reshape(B, Sq, H, D)
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap,
                                   interpret=(b == "interpret"))
    return _ref.attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, prefix_len=prefix_len,
                          q_offset=q_offset)


def packed_flash_decode(q, k_packed: Packed, v_packed: Packed, pos, *,
                        fields: PackFields, window=None, softcap=None,
                        prefix_planes: Optional[int] = None) -> jax.Array:
    """One-token decode attention directly over an SFP-packed KV cache.

    q: (B, 1, H, hd); the packed K/V pairs are in the rank-preserving
    ``sfp_pack_nd`` layout — payload (B, L, KH*hd), bases (B, L, D//128).
    On pallas/interpret this is the fused decompress-attend kernel (the
    bf16 cache never materializes in HBM); on the ref backend it is the
    unpack-then-attend oracle, the kernel's bit-exactness target.
    ``prefix_planes`` is the speculative draft read mode: only the leading
    P' payload bits of the same packed cache are expanded, decoded as the
    truncated geometry (``ref.prefix_fields``) — same blocks, fewer planes.
    """
    b = backend()
    if b in ("pallas", "interpret"):
        return _pfd.packed_flash_decode(
            q, k_packed.payload, k_packed.bases, v_packed.payload,
            v_packed.bases, jnp.asarray(pos, jnp.int32), fields=fields,
            window=window, softcap=softcap, interpret=(b == "interpret"),
            prefix_planes=prefix_planes)
    return _ref.packed_flash_decode(
        q, k_packed.payload, k_packed.bases, v_packed.payload,
        v_packed.bases, pos, fields, window=window, softcap=softcap,
        block_l=_pfd.DEFAULT_BLOCK_L,  # kernel-matching accumulation order
        prefix_planes=prefix_planes)


def paged_flash_decode(q, k_packed: Packed, v_packed: Packed,
                       tables, pos, *, fields: PackFields, softcap=None,
                       prefix_planes: Optional[int] = None) -> jax.Array:
    """One-token decode attention over a paged SFP-packed KV block pool.

    The continuous-batching serving step: pool parts are
    (P_blocks, block_l, D) shared across requests, ``tables`` (B, nb)
    maps each row's logical blocks to physical pool blocks, and ``pos``
    (B,) carries per-row decode positions. On pallas/interpret the block
    table is a scalar-prefetch operand and the gather happens inside the
    kernel grid (no contiguous per-request cache in HBM); on the ref
    backend this is the gather-unpack-attend oracle with the identical
    block recurrence. Global attention only. ``prefix_planes`` is the
    speculative draft read mode (see ``packed_flash_decode``).
    """
    b = backend()
    if b in ("pallas", "interpret"):
        return _pfd.paged_flash_decode(
            q, k_packed.payload, k_packed.bases, v_packed.payload,
            v_packed.bases, jnp.asarray(tables, jnp.int32),
            jnp.asarray(pos, jnp.int32), fields=fields, softcap=softcap,
            interpret=(b == "interpret"), prefix_planes=prefix_planes)
    return _ref.paged_flash_decode(
        q, k_packed.payload, k_packed.bases, v_packed.payload,
        v_packed.bases, tables, pos, fields, softcap=softcap,
        prefix_planes=prefix_planes)
