"""Serving engine: prefill + decode with (optionally compressed) KV cache.

Two serving modes share the model:

* **Contiguous** (``generate``): one prefill + one jitted ``lax.scan``
  decode loop over a per-request cache. Compiled functions are memoized
  per (model, shape) so repeated requests never recompile.
* **Paged** (``PagedEngine``): the continuous-batching substrate. A fixed
  number of batch *slots* share one codec-packed KV block pool
  (serve/pool.py); one jitted fixed-shape decode step advances every
  active slot at its own position, gathering KV blocks through the
  scalar-prefetched block table inside the paged flash-decode kernel.
  Request queueing/admission/preemption live above, in serve/scheduler.py.

`cache_axes` mirrors DecoderModel.init_cache structurally and assigns the
logical sharding: batch over (pod, data), the KV sequence dim over `model`
(flash-decoding style — XLA's softmax reductions over the sharded dim
become exact all-reduces), recurrent-state widths over `model`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.configs.base import ArchConfig, GLOBAL, LOCAL, SSD
from repro.kernels import ops
from repro.models import attention, mamba2, rglru
from repro.models.model import DecoderModel
from repro.serve import kvcache as _kvcache
from repro.serve import pool as _pool


def _slot_axes(kind: str, model: DecoderModel, batch: int, max_len: int):
    if kind in (GLOBAL, LOCAL):
        if model.kv_container is not None:
            # Packed parts are (batch, seq, ...): same logical axes. The
            # real (batch, max_len) matter here: PackedTensor carries its
            # logical shape as pytree aux data, and the axes tree must
            # pair leaf-for-leaf with the actual cache tree.
            return _kvcache.packed_cache_axes(model.cfg, kind, batch,
                                              max_len, model.kv_container)
        return attention.KVCache(k=("batch", "cache_seq", "kv", None),
                                 v=("batch", "cache_seq", "kv", None))
    if kind == SSD:
        return mamba2.SSDCache(conv_x=("batch", None, "ssm_inner"),
                               conv_B=("batch", None, "state"),
                               conv_C=("batch", None, "state"),
                               state=("batch", "heads", None, None))
    return rglru.LRUCache(conv=("batch", None, "lru"),
                          state=("batch", "lru"))


def cache_axes(model: DecoderModel, batch: int = 1, max_len: int = 1):
    """Logical sharding axes matching ``model.init_cache(batch, max_len)``.

    ``batch``/``max_len`` are structural only for raw caches (plain axis
    tuples), but packed caches embed their shapes as pytree metadata —
    pass the same values as init_cache when ``model.kv_container`` is set.
    """
    cfg = model.cfg
    is_tuple = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a)
    per = {f"slot{i}": _slot_axes(k, model, batch, max_len)
           for i, k in enumerate(cfg.period)}
    periods = jax.tree.map(lambda a: ("layers",) + tuple(a), per,
                           is_leaf=is_tuple)
    axes = {"periods": periods}
    if cfg.remainder:
        axes["rem"] = {f"slot{i}": _slot_axes(k, model, batch, max_len)
                       for i, k in enumerate(cfg.remainder)}
    return axes


def make_serve_step(model: DecoderModel, greedy: bool = True):
    """(params, cache, token, pos) -> (next_token, cache). One decode step."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step


def make_prefill_step(model: DecoderModel, max_len: int):
    def prefill_step(params, tokens, cond_embeddings=None):
        return model.prefill(params, tokens, max_len,
                             cond_embeddings=cond_embeddings)

    return prefill_step


@dataclasses.dataclass
class GenerationResult:
    tokens: Any
    steps: int


def make_decode_loop(model: DecoderModel, n_steps: int):
    """Jitted greedy decode loop: one ``lax.scan`` over ``n_steps`` steps.

    The whole loop is a single XLA executable, so per-step host dispatch
    overhead disappears; the cache is donated (``donate_argnums``) so XLA
    updates it in place instead of copying the (possibly packed) ring
    buffers every step. Returns (tokens (n_steps, B, 1), final cache).
    """

    serve_step = make_serve_step(model)

    def loop(params, cache, tok, pos0):
        def step(carry, i):
            tok, cache = carry
            tok, cache = serve_step(params, cache, tok, pos0 + i)
            return (tok, cache), tok

        (tok, cache), toks = jax.lax.scan(
            step, (tok, cache), jnp.arange(n_steps, dtype=jnp.int32))
        return toks, cache

    return jax.jit(loop, donate_argnums=(1,))


# Compiled prefill/decode-loop functions, memoized per model instance:
# jax's jit cache keys on function identity, so rebuilding the closure on
# every generate() call recompiled prefill AND the scan loop each time.
# The cache hangs off the model itself — NOT a module-level
# WeakKeyDictionary: the cached closures capture the model, and any
# globally-rooted map whose values reference their key would pin every
# model (plus all its XLA executables) for the process lifetime. On the
# instance, cache and model form an ordinary garbage cycle that dies with
# the model. Below the statics key, jax handles per-input-shape caching.
_CACHE_ATTR = "_serve_compiled"


def compiled(model: DecoderModel, key: Tuple, build):
    per_model = model.__dict__.setdefault(_CACHE_ATTR, {})
    if key not in per_model:
        per_model[key] = build()
    return per_model[key]


def generate(model: DecoderModel, params, prompt: jax.Array, max_new: int,
             max_len: Optional[int] = None,
             cond_embeddings: Optional[jax.Array] = None) -> GenerationResult:
    """Greedy batched generation: jitted prefill + one jitted scan loop.

    Compiled functions are memoized on the model keyed by (max_len,
    n_steps), so repeated requests with the same budget reuse both
    executables instead of re-tracing them per call.
    """
    B, S = prompt.shape
    P = model.cfg.prefix_tokens if cond_embeddings is not None else 0
    max_len = max_len or (P + S + max_new)
    prefill = compiled(model, ("prefill", max_len),
                       lambda: jax.jit(make_prefill_step(model, max_len)))
    logits, cache = prefill(params, prompt, cond_embeddings)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    if max_new > 1:
        loop = compiled(model, ("decode_loop", max_new - 1),
                        lambda: make_decode_loop(model, max_new - 1))
        toks, cache = loop(params, cache, tok,
                           jnp.asarray(P + S, jnp.int32))
        out.append(jnp.moveaxis(toks[..., 0], 0, 1))  # (n, B, 1) -> (B, n)
    return GenerationResult(tokens=jnp.concatenate(out, axis=1),
                            steps=max_new)


# ---------------------------------------------------------------------------
# Paged continuous-batching engine
# ---------------------------------------------------------------------------


class PagedEngine:
    """Fixed-shape batch-slot serving over a paged packed-KV block pool.

    ``max_slots`` requests decode together in one jitted step; each
    global-attention layer stores KV in codec-packed physical blocks
    (``block_l`` = the flash-decode kernel block) shared across slots and
    addressed through per-slot block tables. Local ring layers and
    SSD/RGLRU states are window/width-bounded, so they stay per-slot
    dense. Idle slots run the same step on the reserved trash block and
    their outputs are discarded — the executable never re-specializes as
    requests come and go, which is what makes continuous batching free of
    recompiles.

    The engine is mechanism only: it owns device memory, the block pool
    and the compiled step; admission, preemption and streaming live in
    ``serve/scheduler.py``.
    """

    def __init__(self, model: DecoderModel, params, *, max_slots: int = 8,
                 max_len: int = 256, num_blocks: Optional[int] = None,
                 degraded_container: Optional[str] = None,
                 integrity: bool = True):
        if model.kv_container is None:
            raise ValueError("PagedEngine needs a model with kv_container "
                             "set (the pool stores packed blocks)")
        cfg = model.cfg
        if cfg.prefix_tokens:
            raise NotImplementedError(
                "prefix-conditioned archs are not paged-served yet")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.container = model.kv_container
        self.block_l = ops.DECODE_BLOCK_L
        # The pool block is the kernel block; rounding max_len up keeps
        # prefill's packed cache (cache_len) and the pool block grid the
        # same length, so prefill rows scatter into whole blocks.
        self.max_len = -(-max_len // self.block_l) * self.block_l
        self.nmax = self.max_len // self.block_l
        self.max_slots = int(max_slots)
        if num_blocks is None:
            num_blocks = self.max_slots * self.nmax  # full residency
        # Fail fast if the codec cannot page (no fixed-width geometry) —
        # and price one block in dense-packed bytes across the layers that
        # share the pool, so admission accounting is in realized bytes.
        _kvcache.paged_block_spec(cfg, 1, self.block_l, self.container)
        kinds = list(cfg.period) * cfg.n_periods + list(cfg.remainder)
        self.n_global_layers = sum(k == GLOBAL for k in kinds)
        self.block_bytes = self.n_global_layers * _kvcache.paged_block_bytes(
            cfg, self.block_l, self.container)
        # Graceful degradation (serve/precision.PressureController): under
        # memory pressure the scheduler admits new requests at a *narrower*
        # dense geometry, priced at that geometry's per-block bytes against
        # a fixed byte budget. The budget is `num_blocks` worth of blocks
        # at the configured geometry; the physical arrays over-provision
        # rows so that cheaper blocks are actually allocatable (fixed
        # shapes keep the step jittable — the byte accounting models the
        # HBM the blocks would occupy repacked at their admission width).
        self.degraded_container = degraded_container
        if degraded_container is not None:
            self.degraded_block_bytes = (
                self.n_global_layers
                * _kvcache.paged_block_bytes(cfg, self.block_l,
                                             degraded_container))
            if self.degraded_block_bytes >= self.block_bytes:
                raise ValueError(
                    f"degraded container {degraded_container!r} "
                    f"({self.degraded_block_bytes} B/block) is not narrower "
                    f"than {self.container!r} ({self.block_bytes} B/block)")
            budget_bytes = num_blocks * self.block_bytes
            phys_blocks = min(-(-budget_bytes // self.degraded_block_bytes),
                              self.max_slots * self.nmax)
            phys_blocks = max(phys_blocks, num_blocks)
            self._requant = jax.jit(self._requant_fn)
        else:
            self.degraded_block_bytes = self.block_bytes
            budget_bytes = None
            phys_blocks = num_blocks
            self._requant = None
        self.pool = _pool.BlockPool(phys_blocks, self.max_slots, self.nmax,
                                    self.block_l,
                                    block_bytes=self.block_bytes,
                                    budget_bytes=budget_bytes)
        self.mem = self._init_mem()
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0,))
        self._bursts: Dict[int, Any] = {}  # K -> compiled scan loop
        # (K, draft_planes) -> compiled self-speculative draft+verify round
        self._specs: Dict[Tuple[int, int], Any] = {}
        self.decode_steps = 0
        self.spec_rounds = 0
        # Block integrity: a cheap per-physical-block checksum over the
        # packed planes (kvcache.paged_block_checksums summed across the
        # global layers), recomputed after every legitimate write
        # (pack/insert) and compared before every gather. The scheduler
        # drives verify/refresh; mismatches quarantine the block and
        # recompute the owning request from its prompt.
        self.integrity = bool(integrity)
        self._sums_fn = jax.jit(self._block_sums_fn)
        self.expected_sums = np.zeros(self.pool.num_blocks + 1, np.uint32)
        # Telemetry sink (repro.obs.Obs); the driving Scheduler installs
        # its own. All recording happens at host boundaries — after the
        # jitted call's outputs were pulled to numpy — never inside
        # traced code (enforced by the obs-no-hot-path-sync lint).
        self.obs: Optional[Any] = None

    def _observe(self, name: str, help: str, seconds: float) -> None:
        if self.obs is not None:
            self.obs.registry.histogram(name, help,
                                        unit="s").observe(seconds)

    # -- device memory ---------------------------------------------------

    def _slot_mem(self, kind: str):
        cfg = self.cfg
        if kind == GLOBAL:
            # +1: physical block 0 is the trash block (pool.TRASH_BLOCK).
            return _kvcache.paged_block_init(
                cfg, self.pool.num_blocks + 1, self.block_l, self.container)
        if kind == LOCAL:
            return _kvcache.packed_cache_init(cfg, kind, self.max_slots,
                                              self.max_len, self.container)
        if kind == SSD:
            return mamba2.ssd_cache_init(cfg, self.max_slots,
                                         cfg.compute_dtype)
        return rglru.lru_cache_init(cfg, self.max_slots, cfg.compute_dtype)

    def _init_mem(self):
        cfg = self.cfg
        per = {f"slot{i}": self._slot_mem(k)
               for i, k in enumerate(cfg.period)}
        periods = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), per)
        mem = {"periods": periods}
        if cfg.remainder:
            mem["rem"] = {f"slot{i}": self._slot_mem(k)
                          for i, k in enumerate(cfg.remainder)}
        return mem

    def cache_bytes(self) -> Dict[str, float]:
        """Realized pool bytes (total device allocation) and the
        dense-packed bytes actually *live* (allocated blocks), per the
        host byte accounting."""
        leaves = jax.tree_util.tree_leaves(self.mem)
        total = float(sum(l.size * l.dtype.itemsize for l in leaves))
        st = self.pool.stats()
        return {"total": total,
                "live_block_fraction":
                    st.used_blocks / max(1, st.num_blocks),
                "block_bytes": float(st.block_bytes),
                "pool_capacity_bytes": float(st.capacity_bytes),
                "pool_live_bytes": float(st.used_bytes),
                "pool_peak_bytes": float(st.peak_bytes)}

    # -- block integrity -------------------------------------------------

    def _global_entries(self):
        """(group, key) paths of the paged global-attention layers in mem."""
        out = [("periods", f"slot{i}")
               for i, k in enumerate(self.cfg.period) if k == GLOBAL]
        out += [("rem", f"slot{i}")
                for i, k in enumerate(self.cfg.remainder) if k == GLOBAL]
        return out

    def _block_sums_fn(self, mem):
        """Per-physical-block uint32 checksum summed over global layers."""
        total = jnp.zeros(self.pool.num_blocks + 1, jnp.uint32)
        for j, (grp, key) in enumerate(self._global_entries()):
            total = total + _kvcache.paged_block_checksums(mem[grp][key],
                                                           salt=j + 1)
        return total

    def block_checksums(self) -> np.ndarray:
        """Current checksums of every physical block (trash block = id 0)."""
        return np.asarray(self._sums_fn(self.mem))

    def verify_blocks(self, ids) -> list:
        """Return the subset of physical block ids whose packed planes no
        longer match the checksum recorded at their last legitimate write."""
        ids = [int(p) for p in ids if p != _pool.TRASH_BLOCK]
        if not self.integrity or not ids:
            return []
        t0 = time.perf_counter()
        sums = self.block_checksums()
        bad = [p for p in ids if sums[p] != self.expected_sums[p]]
        self._observe("serve_verify_seconds",
                      "block checksum verification wall time",
                      time.perf_counter() - t0)
        return bad

    def refresh_checksums(self, ids) -> None:
        """Record current checksums as expected — call after every
        legitimate write (prefill scatter / decode step) to the blocks."""
        ids = [int(p) for p in ids if p != _pool.TRASH_BLOCK]
        if not self.integrity or not ids:
            return
        sums = self.block_checksums()
        for p in ids:
            self.expected_sums[p] = sums[p]

    def corrupt_block(self, phys: int, *, layer: int = 0, field: int = 0,
                      row: int = 0, col: int = 0, bit: int = 0) -> None:
        """Chaos/test hook: flip one bit in a packed plane of ``phys``.

        Simulates in-memory corruption (the FaultInjector's bit-flip
        fault). ``layer`` indexes the global layers, ``field`` the PagedKV
        planes (k_payload, k_bases, v_payload, v_bases).
        """
        entries = self._global_entries()
        grp, key = entries[layer % len(entries)]
        kv = self.mem[grp][key]
        field %= len(kv)
        arr = kv[field]
        lead = (0,) if arr.ndim == 4 else ()
        idx = lead + (int(phys), row % arr.shape[-2], col % arr.shape[-1])
        nbits = 8 * arr.dtype.itemsize
        uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[arr.dtype.itemsize]
        word = jax.lax.bitcast_convert_type(arr[idx], uint)
        word = word ^ uint(1 << (bit % nbits))
        arr = arr.at[idx].set(jax.lax.bitcast_convert_type(word, arr.dtype))
        self.mem[grp][key] = kv._replace(**{kv._fields[field]: arr})

    def scrub_block(self, phys: int) -> None:
        """Zero a (quarantined) block's planes and re-record its checksum,
        making it safe to return to the free list (pool.rehabilitate)."""
        for grp, key in self._global_entries():
            kv = self.mem[grp][key]
            self.mem[grp][key] = type(kv)(*(
                a.at[(slice(None), int(phys)) if a.ndim == 4
                     else int(phys)].set(0) for a in kv))
        self.refresh_checksums([phys])
        if self.obs is not None:
            self.obs.event("scrub_block", block=int(phys))

    # -- prefill ---------------------------------------------------------

    def _requant_fn(self, pref_cache):
        """Narrow-requantize the global-layer KV of a prefill cache.

        Degraded admissions store prompt KV at the *narrower* geometry:
        each packed tensor is unpacked, round-tripped through the degraded
        codec, and repacked at the configured container (narrow values are
        exactly representable in the wider geometry, so the pool arrays
        keep one fixed shape and the jitted step never re-specializes).
        Decode-time appends still write at the configured width — the byte
        accounting (pool rates) is what prices the slot at the narrow
        geometry.
        """
        wide = codecs.get(self.container)
        narrow = codecs.get(self.degraded_container)

        def one_pt(pt):
            pay = pt.data["payload"]
            lead = pay.shape[:-2]
            B = 1
            for d in lead:
                B *= int(d)
            L, D = pay.shape[-2], pt.shape[-1]
            flat = codecs.PackedTensor(
                pt.codec, (B, L, D), pt.dtype,
                {k: v.reshape((B,) + v.shape[len(lead):])
                 for k, v in pt.data.items()})
            vals = narrow.roundtrip(wide.unpack(flat))
            rp = wide.pack(vals)
            return codecs.PackedTensor(
                pt.codec, pt.shape, pt.dtype,
                {k: rp.data[k].reshape(pt.data[k].shape) for k in pt.data})

        out = {"periods": dict(pref_cache["periods"])}
        for i, kind in enumerate(self.cfg.period):
            if kind == GLOBAL:
                kv = pref_cache["periods"][f"slot{i}"]
                out["periods"][f"slot{i}"] = kv._replace(k=one_pt(kv.k),
                                                         v=one_pt(kv.v))
        if self.cfg.remainder:
            out["rem"] = dict(pref_cache["rem"])
            for i, kind in enumerate(self.cfg.remainder):
                if kind == GLOBAL:
                    kv = pref_cache["rem"][f"slot{i}"]
                    out["rem"][f"slot{i}"] = kv._replace(k=one_pt(kv.k),
                                                         v=one_pt(kv.v))
        return out

    def _scatter_fn(self, mem, pref_cache, slot, ids):
        """Write one request's prefill cache into slot ``slot``.

        Global layers scatter block-reshaped packed rows to the physical
        ids in ``ids`` (unallocated logical blocks point at the trash
        block and receive identical packed-zero rows — harmless); per-slot
        layers overwrite their slot row wholesale.
        """
        nmax, bl = self.nmax, self.block_l

        def put_blocks(pool_arr, part, leading):
            if leading:
                blk = part[:, 0].reshape(part.shape[0], nmax, bl,
                                         *part.shape[3:])
                return pool_arr.at[:, ids].set(blk)
            blk = part[0].reshape(nmax, bl, *part.shape[2:])
            return pool_arr.at[ids].set(blk)

        def set_slot(m, p, leading):
            def arr(ma, pa):
                return (ma.at[:, slot].set(pa[:, 0]) if leading
                        else ma.at[slot].set(pa[0]))

            def one(ma, pa):
                if isinstance(ma, codecs.PackedTensor):
                    return codecs.PackedTensor(
                        ma.codec, ma.shape, ma.dtype,
                        {k: arr(ma.data[k], pa.data[k]) for k in ma.data})
                return arr(ma, pa)

            return jax.tree.map(
                one, m, p,
                is_leaf=lambda x: isinstance(x, codecs.PackedTensor))

        def scatter_kind(kind, m, p, leading):
            if kind == GLOBAL:
                return _kvcache.PagedKV(
                    k_payload=put_blocks(m.k_payload, p.k.data["payload"],
                                         leading),
                    k_bases=put_blocks(m.k_bases, p.k.data["bases"],
                                       leading),
                    v_payload=put_blocks(m.v_payload, p.v.data["payload"],
                                         leading),
                    v_bases=put_blocks(m.v_bases, p.v.data["bases"],
                                       leading))
            return set_slot(m, p, leading)

        out = {"periods": {
            f"slot{i}": scatter_kind(kind, mem["periods"][f"slot{i}"],
                                     pref_cache["periods"][f"slot{i}"], True)
            for i, kind in enumerate(self.cfg.period)}}
        if self.cfg.remainder:
            out["rem"] = {
                f"slot{i}": scatter_kind(kind, mem["rem"][f"slot{i}"],
                                         pref_cache["rem"][f"slot{i}"],
                                         False)
                for i, kind in enumerate(self.cfg.remainder)}
        return out

    def prefill_into_slot(self, slot: int, prompt: np.ndarray,
                          narrow: bool = False) -> int:
        """Prefill one request into ``slot``; returns its first token.

        The slot's block table must already cover the prompt
        (``pool.alloc_upto``). Uses the model's packed prefill at the
        engine-wide ``max_len``, so every compile is shared across slots
        and the packed rows are bit-identical to the contiguous serving
        path at the same budget. ``narrow=True`` (degraded admission)
        round-trips the prompt KV through ``degraded_container`` before
        scattering, so the stored planes carry the narrow geometry's
        values while keeping the pool's fixed shapes.
        """
        t0 = time.perf_counter()
        prompt = np.asarray(prompt)
        assert prompt.ndim == 1 and prompt.size >= 1, prompt.shape
        if prompt.size >= self.max_len:
            raise ValueError(f"prompt ({prompt.size}) must leave decode "
                             f"room inside max_len ({self.max_len})")
        if narrow and self._requant is None:
            raise ValueError("narrow prefill needs degraded_container")
        prefill = compiled(
            self.model, ("prefill", self.max_len),
            lambda: jax.jit(make_prefill_step(self.model, self.max_len)))
        logits, pref_cache = prefill(self.params, jnp.asarray(prompt)[None],
                                     None)
        if narrow:
            pref_cache = self._requant(pref_cache)
        ids_np = self.pool.tables[slot]
        self.mem = self._scatter(self.mem, pref_cache,
                                 jnp.asarray(slot, jnp.int32),
                                 jnp.asarray(ids_np, jnp.int32))
        if self.integrity:
            self.refresh_checksums([p for p in ids_np
                                    if p != _pool.TRASH_BLOCK])
        tok = int(jnp.argmax(logits[0, -1]))
        self._observe("serve_prefill_seconds",
                      "prefill-into-slot wall time (incl. scatter)",
                      time.perf_counter() - t0)
        return tok

    # -- decode ----------------------------------------------------------

    def _step_fn(self, params, mem, tables, toks, pos):
        logits, mem = self.model.decode_step_paged(params, mem, toks, pos,
                                                   tables)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        # NaN/Inf logit guard: a per-slot "bad" flag computed inside the
        # jitted step (free — logits are already on device). The scheduler
        # quarantines flagged slots instead of streaming garbage.
        bad = ~jnp.all(jnp.isfinite(logits), axis=(1, 2))
        return nxt, bad, mem

    def decode(self, toks: np.ndarray, pos: np.ndarray):
        """One batched decode step over every slot.

        ``toks``/``pos`` are (max_slots,) host arrays; idle slots carry
        token 0 at position 0 with a trash-block table row, and their
        returned tokens are meaningless. Returns ((max_slots,) next
        tokens, (max_slots,) bool non-finite-logit flags).
        """
        t0 = time.perf_counter()
        tables = jnp.asarray(self.pool.tables)
        nxt, bad, self.mem = self._step(
            self.params, self.mem, tables,
            jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        self.decode_steps += 1
        out = np.asarray(nxt), np.asarray(bad)
        self._observe("serve_decode_seconds",
                      "decode dispatch wall time (whole burst)",
                      time.perf_counter() - t0)
        return out

    def _make_burst(self, K: int):
        """Compiled K-step decode burst: one ``lax.scan`` executable.

        Block tables are fixed for the whole burst (the scheduler
        pre-allocates every running slot to its burst horizon), so the
        scan carries only (token, mem) and the per-step host round-trip —
        table upload, dispatch, token download — is paid once per K
        tokens instead of once per token. The pool memory is donated, so
        XLA updates the packed blocks in place across all K steps.
        """

        def burst(params, mem, tables, toks, pos):
            def step(carry, i):
                tok, mem = carry
                logits, mem = self.model.decode_step_paged(
                    params, mem, tok, pos + i, tables)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                bad = ~jnp.all(jnp.isfinite(logits), axis=(1, 2))
                return (nxt[:, None], mem), (nxt, bad)

            (_, mem), (out, bad) = jax.lax.scan(
                step, (toks, mem), jnp.arange(K, dtype=jnp.int32))
            return out, bad, mem  # out/bad: (K, max_slots)

        return jax.jit(burst, donate_argnums=(1,))

    def decode_burst(self, toks: np.ndarray, pos: np.ndarray,
                     burst: int):
        """``burst`` greedy decode steps over every slot in one dispatch.

        Each slot chains its own argmax token across the burst; positions
        advance ``pos + i``. Every running slot must already own blocks
        covering ``pos + burst`` (and ``pos + burst <= max_len``) — the
        scheduler guarantees this before calling. Returns the
        (burst, max_slots) int32 token buffer plus a matching bool buffer
        of non-finite-logit flags; the caller replays per-token
        streaming/finish bookkeeping from them. ``burst == 1`` reuses the
        plain compiled step rather than a scan of one.
        """
        K = int(burst)
        assert K >= 1, K
        if K == 1:
            nxt, bad = self.decode(toks, pos)
            return nxt[None], bad[None]
        fn = self._bursts.get(K)
        if fn is None:
            fn = self._bursts[K] = self._make_burst(K)
        t0 = time.perf_counter()
        tables = jnp.asarray(self.pool.tables)
        out, bad, self.mem = fn(self.params, self.mem, tables,
                                jnp.asarray(toks, jnp.int32)[:, None],
                                jnp.asarray(pos, jnp.int32))
        self.decode_steps += K
        res = np.asarray(out), np.asarray(bad)
        self._observe("serve_decode_seconds",
                      "decode dispatch wall time (whole burst)",
                      time.perf_counter() - t0)
        return res

    # -- self-speculative decoding ---------------------------------------

    def default_draft_planes(self) -> int:
        """Deepest valid draft prefix shallower than full width, if any.

        The draft must keep the sign, the full shared-exponent delta and
        at least one mantissa bit (``ops.prefix_fields`` enforces this),
        so very narrow containers (e.g. sfp-m1e2) may only support the
        full width — speculation still works, the draft just reads every
        plane.
        """
        fields = _kvcache._paged_fields(self.cfg, self.container)
        return max(fields.payload_bits - 1, fields.dexp_bits + 2)

    def validate_draft_planes(self, draft_planes: int) -> int:
        """Check ``draft_planes`` against the pool geometry; returns it."""
        fields = _kvcache._paged_fields(self.cfg, self.container)
        ops.prefix_fields(fields, int(draft_planes))  # raises ValueError
        return int(draft_planes)

    def _non_global_keys(self) -> Tuple[tuple, tuple]:
        """slot keys of the per-slot (non paged-pool) layer state in mem."""
        per = tuple(f"slot{i}" for i, k in enumerate(self.cfg.period)
                    if k != GLOBAL)
        rem = tuple(f"slot{i}" for i, k in enumerate(self.cfg.remainder)
                    if k != GLOBAL)
        return per, rem

    def _make_spec(self, K: int, draft_planes: int):
        """Compiled self-speculative round: K draft steps at prefix
        precision, one batched full-width verify, device-side acceptance
        and bit-exact state rollback — a single executable per
        (K, draft_planes), memoized like the burst loops.

        Protocol (greedy, guaranteed token-identical to plain decode):

        * **Draft**: ``lax.scan`` of K decode steps whose packed-attention
          reads expand only the leading ``draft_planes`` bit planes per
          group (``prefix_planes``); KV writes and recurrent updates stay
          full width.
        * **Rewind**: per-slot layer state (local packed rings, SSD and
          RGLRU states) is restored to its round-start snapshot. Paged
          pool rows the draft wrote need no rollback: the verify pass
          rewrites each position before any step can attend to it, and
          rows past the current position are causally masked — so
          speculation allocates and touches exactly the blocks a burst of
          the same horizon would (zero additional pool bytes).
        * **Verify**: ``lax.scan`` of K full-width steps teacher-forced
          with [token, d_1..d_{K-1}] over the same positions, stacking
          the per-slot layer state after every step.
        * **Accept**: per slot, ``m`` = longest prefix with d_i == v_i;
          ``n_emit = min(m+1, K)`` (the verifier's correction token is
          always emitted, so at least one token commits per round). The
          committed per-slot state is the verify stack at step
          ``n_emit-1``; because accepted verify steps consumed exactly
          the tokens a non-speculative decode would have, that state —
          and every emitted token — is bit-exact vs. ``burst=1`` decode.

        The stacked rollback state costs K extra copies of the per-slot
        (window/width-bounded) layers inside the executable — never of
        the block pool itself.
        """
        per_keys, rem_keys = self._non_global_keys()

        def extract(mem):
            out = {"periods": {k: mem["periods"][k] for k in per_keys}}
            if rem_keys:
                out["rem"] = {k: mem["rem"][k] for k in rem_keys}
            return out

        def merge(mem, ng):
            out = {"periods": {**mem["periods"], **ng["periods"]}}
            if "rem" in mem:
                out["rem"] = {**mem["rem"], **ng.get("rem", {})}
            return out

        S = self.max_slots

        def gather_committed(stack, n_emit):
            """Per-slot pick of the verify stack at step n_emit[s]-1.

            Leaves are (K, n_periods, slots, ...) under "periods" and
            (K, slots, ...) under "rem"; the step axis is gathered at a
            different index per slot.
            """
            idx = n_emit - 1  # (S,) in [0, K)

            def pick(leaf, slot_axis):
                ym = jnp.moveaxis(leaf, slot_axis, 1)  # (K, S, ...)
                out = ym[idx, jnp.arange(S)]           # (S, ...)
                return jnp.moveaxis(out, 0, slot_axis - 1)

            out = {"periods": jax.tree.map(lambda a: pick(a, 2),
                                           stack["periods"])}
            if "rem" in stack:
                out["rem"] = jax.tree.map(lambda a: pick(a, 1),
                                          stack["rem"])
            return out

        def spec(params, mem, tables, toks, pos):
            snap = extract(mem)

            def dstep(carry, i):
                tok, mem = carry
                logits, mem = self.model.decode_step_paged(
                    params, mem, tok, pos + i, tables,
                    prefix_planes=draft_planes)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                return (nxt[:, None], mem), nxt

            (_, mem), drafts = jax.lax.scan(
                dstep, (toks, mem), jnp.arange(K, dtype=jnp.int32))

            mem = merge(mem, snap)  # rewind per-slot state for verify

            vin = jnp.concatenate([toks[:, 0][None], drafts[:-1]], axis=0)

            def vstep(mem, x):
                tok, i = x
                logits, mem = self.model.decode_step_paged(
                    params, mem, tok[:, None], pos + i, tables)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                bad = ~jnp.all(jnp.isfinite(logits), axis=(1, 2))
                return mem, (nxt, bad, extract(mem))

            mem, (verifs, bad, stack) = jax.lax.scan(
                vstep, mem, (vin, jnp.arange(K, dtype=jnp.int32)))

            match = jnp.cumprod((drafts == verifs).astype(jnp.int32), axis=0)
            accepted = jnp.sum(match, axis=0)           # (S,) m in [0, K]
            n_emit = jnp.minimum(accepted + 1, K)       # (S,) in [1, K]

            mem = merge(mem, gather_committed(stack, n_emit))
            return verifs, bad, accepted, n_emit, mem

        return jax.jit(spec, donate_argnums=(1,))

    def speculate(self, toks: np.ndarray, pos: np.ndarray, K: int,
                  draft_planes: Optional[int] = None):
        """One self-speculative round over every slot.

        Same calling convention as ``decode_burst``: every running slot
        must own blocks covering ``pos + K`` (``pos + K <= max_len``).
        Returns ``(verifs (K, max_slots), bad (K, max_slots),
        accepted (max_slots,), n_emit (max_slots,))`` — ``accepted`` is
        the per-slot count of drafts the verify pass confirmed (0..K);
        ``n_emit = min(accepted+1, K)`` counts the tokens actually
        decoded (the verifier's correction token always commits). The
        caller streams ``verifs[:n_emit[s], s]`` per slot; the rejected
        suffix was rolled back on device.
        """
        K = int(K)
        assert K >= 1, K
        if draft_planes is None:
            draft_planes = self.default_draft_planes()
        dp = self.validate_draft_planes(draft_planes)
        fn = self._specs.get((K, dp))
        if fn is None:
            fn = self._specs[(K, dp)] = self._make_spec(K, dp)
        t0 = time.perf_counter()
        tables = jnp.asarray(self.pool.tables)
        verifs, bad, accepted, n_emit, self.mem = fn(
            self.params, self.mem, tables,
            jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        self.decode_steps += 2 * K  # K draft + K verify model steps
        self.spec_rounds += 1
        res = (np.asarray(verifs), np.asarray(bad), np.asarray(accepted),
               np.asarray(n_emit))
        self._observe("serve_spec_seconds",
                      "speculative draft+verify round wall time",
                      time.perf_counter() - t0)
        return res
