"""Dense bit-plane containers: variable payload-width packing end to end.

Covers the sub-byte container stack: plane layout vs a pure-Python
oracle, codec registry resolution, backend parity, fused quantize+pack,
packed/paged flash-decode bit-exactness at sub-byte geometries, realized
footprint accounting, per-layer stash containers, pool byte accounting,
and the afloat policy plugin.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, configs, policies
from repro.configs.base import reduced
from repro.core import containers as C, footprint
from repro.kernels import bitplane_pack as bpk
from repro.kernels import ops, ref
from repro.kernels import packed_flash_decode as pfd
from repro.models.model import DecoderModel
from repro.serve import kvcache, pool


def _x(shape=(4, 256), dtype=jnp.bfloat16, seed=0, scale=3.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Pure-Python bit-plane oracle (independent of kernels/ref.py)
# ---------------------------------------------------------------------------


def py_plane_pack(words: np.ndarray, payload_bits: int) -> np.ndarray:
    """(R, 128) payload words -> (R, P*16) uint8, bit-by-bit in Python."""
    R = words.shape[0]
    out = np.zeros((R, payload_bits * 16), np.uint8)
    for r in range(R):
        for lane in range(128):
            w = int(words[r, lane])
            for p in range(payload_bits):
                if (w >> p) & 1:
                    out[r, p * 16 + lane // 8] |= 1 << (lane % 8)
    return out


def py_plane_unpack(planes: np.ndarray, payload_bits: int) -> np.ndarray:
    R = planes.shape[0]
    out = np.zeros((R, 128), np.int64)
    for r in range(R):
        for p in range(payload_bits):
            for i in range(16):
                byte = int(planes[r, p * 16 + i])
                for j in range(8):
                    if (byte >> j) & 1:
                        out[r, i * 8 + j] |= 1 << p
    return out


@pytest.mark.parametrize("payload_bits", [3, 7, 11, 16])
def test_plane_layout_matches_python_oracle(payload_bits):
    rng = np.random.RandomState(payload_bits)
    words = rng.randint(0, 1 << payload_bits, size=(3, 128)).astype(np.int64)
    got = np.asarray(ref.plane_pack_words(jnp.asarray(words, jnp.int32),
                                          payload_bits))
    want = py_plane_pack(words, payload_bits)
    np.testing.assert_array_equal(got, want)
    back = np.asarray(ref.plane_unpack_words(jnp.asarray(got), payload_bits))
    np.testing.assert_array_equal(back, words)
    np.testing.assert_array_equal(py_plane_unpack(want, payload_bits), words)


# ---------------------------------------------------------------------------
# Dense codec: registry, geometry, roundtrip, backend parity
# ---------------------------------------------------------------------------


def test_dense_name_resolution_and_geometry():
    f = codecs.get("sfp-m2e4").pack_fields(jnp.bfloat16)
    assert (f.man_keep, f.dexp_bits, f.payload_bits, f.dense) == (2, 4, 7,
                                                                  True)
    # lane-width budgets keep the fixed-lane fast path
    assert not codecs.get("sfp-m3e4").pack_fields(jnp.bfloat16).dense
    assert not codecs.get("sfp-m10e5").pack_fields(jnp.float32).dense
    # mantissa clamps to the source dtype (bf16 has 7)
    f2 = codecs.get("sfp-m9e3").pack_fields(jnp.bfloat16)
    assert f2.man_keep == 7 and f2.payload_bits == 11
    # payload caps at 16 bits total
    f3 = codecs.get("sfp-m12e7").pack_fields(jnp.float32)
    assert f3.payload_bits <= 16
    assert codecs.dense_name(1.2, 3.5) == "sfp-m2e4"


def test_dense_roundtrip_equals_same_geometry_fixed_lane():
    """The plane layout changes bytes, not values: a dense m2e4 roundtrip
    must be bit-identical to an 8-bit-lane container with the same
    (man, dexp) geometry."""
    x = _x((4, 256))
    dense = codecs.get("sfp-m2e4").roundtrip(x)
    f_fixed = ref.PackFields(man_keep=2, dexp_bits=4, payload_bits=8)
    pw, bw = ref.sfp_pack_nd(x, f_fixed)
    fixed = ref.sfp_unpack_nd(pw, bw, x.dtype, f_fixed)
    np.testing.assert_array_equal(np.asarray(dense, np.float32),
                                  np.asarray(fixed, np.float32))


def test_dense_backend_parity_and_fused_pack():
    x = _x((2, 3, 128), dtype=jnp.float32)
    codec = codecs.get("sfp-m4e5")  # 10-bit dense payload
    ref_pack = codec.pack(x, bits=3)
    ops.force_backend("interpret")
    try:
        interp_pack = codec.pack(x, bits=3)
        for k in ref_pack.data:
            np.testing.assert_array_equal(np.asarray(ref_pack.data[k]),
                                          np.asarray(interp_pack.data[k]))
        y = codec.unpack(interp_pack)
    finally:
        ops.force_backend(None)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(codec.unpack(ref_pack)))
    # fused quantize+pack == quantize then pack
    plain = codec.pack(C.truncate_mantissa(x, 3))
    for k in plain.data:
        np.testing.assert_array_equal(np.asarray(ref_pack.data[k]),
                                      np.asarray(plain.data[k]))


def test_dense_flat_layout_and_pallas_kernels():
    x = _x((37,), dtype=jnp.bfloat16)  # forces the padded flat layout
    codec = codecs.get("sfp-m2e4")
    packed = codec.pack(x)
    assert packed.data["payload"].shape == (1, 7 * 16)
    np.testing.assert_array_equal(
        np.asarray(codec.unpack(packed)),
        np.asarray(codec.roundtrip(x)))
    # kernel pair vs oracle on the flat rows
    f = codec.pack_fields(x.dtype)
    rows = _x((5, 128))
    kp, kb = bpk.bitplane_pack(rows, fields=f, interpret=True)
    rp, rb = ref.bitplane_pack(rows, f)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    back = bpk.bitplane_unpack(kp, kb, shape=(5, 128), dtype=rows.dtype,
                               fields=f, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(back, np.float32),
        np.asarray(ref.bitplane_unpack(rp, rb, (5, 128), rows.dtype, f),
                   np.float32))


def test_dense_packed_bits_below_fixed_lane():
    """The realized-footprint claim: dense m2e4 really stores fewer bytes
    than fixed-lane sfp8 (7.06 vs 8.06 bits/value), m1e2 lands at 4.06."""
    x = _x((64, 8192))
    m2e4 = codecs.get("sfp-m2e4").packed_bits(x) / x.size
    sfp8 = codecs.get("sfp8").packed_bits(x) / x.size
    assert m2e4 == 7.0625 and sfp8 == 8.0625
    assert m2e4 < sfp8
    assert codecs.get("sfp-m1e2").packed_bits(x) / x.size == 4.0625
    # encode_host writes exactly those bytes
    stream, _meta = codecs.get("sfp-m2e4").encode_host(np.asarray(x))
    assert stream.nbytes * 8 == int(codecs.get("sfp-m2e4").packed_bits(x))


def test_footprint_realized_report():
    x = _x((4, 256))
    for name in ("sfp-m2e4", "sfp8", "sfp16"):
        rep = footprint.container_realized_report(x, name)
        assert rep.total_bits == int(codecs.get(name).packed_bits(x)), name
    dense = footprint.container_realized_report(x, "sfp-m2e4")
    # dense payload wastes nothing on lane slack: metadata is bases only
    assert dense.metadata_bits == (x.size // 128) * 8
    assert dense.vs_bf16() < footprint.container_realized_report(
        x, "sfp8").vs_bf16()


# ---------------------------------------------------------------------------
# Flash decode over dense sub-byte caches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("container", ["sfp-m2e4", "sfp-m1e2"])
@pytest.mark.parametrize("window,pos,L", [(None, 31, 32), (16, 37, 16)])
def test_dense_packed_decode_bit_exact(container, window, pos, L):
    B, KH, hd, rep = 2, 2, 64, 2
    H = KH * rep
    dtype = jnp.bfloat16
    f = codecs.fields_for(container, dtype)
    assert f.dense
    k = _x((B, L, KH * hd), dtype, seed=1)
    v = _x((B, L, KH * hd), dtype, seed=2)
    kp, kb = ref.bitplane_pack_nd(k, f)
    vp, vb = ref.bitplane_pack_nd(v, f)
    q = _x((B, 1, H, hd), dtype, seed=3)
    posa = jnp.asarray(pos, jnp.int32)
    got = pfd.packed_flash_decode(q, kp, kb, vp, vb, posa, fields=f,
                                  window=window, block_l=16, interpret=True)
    oracle = jax.jit(functools.partial(ref.packed_flash_decode, fields=f,
                                       window=window, block_l=16))
    want = oracle(q, kp, kb, vp, vb, posa)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_dense_paged_decode_bit_exact_sub_byte():
    """Paged flash-decode over a dense sub-byte pool must be bit-exact vs
    the gather-unpack-attend oracle (interpret mode), including per-row
    positions and a trash-backed row."""
    B, KH, hd, rep, bl, nb = 2, 1, 128, 2, 16, 2
    H = KH * rep
    dtype = jnp.float32
    f = codecs.fields_for("sfp-m2e4", dtype)
    assert f.dense and f.payload_bits == 7
    D = KH * hd
    k = _x((nb * B, bl, D), dtype, seed=4)
    v = _x((nb * B, bl, D), dtype, seed=5)
    kp, kb = ref.bitplane_pack_nd(k, f)
    vp, vb = ref.bitplane_pack_nd(v, f)
    # physical pool with block 0 as trash
    zeros = lambda a: jnp.zeros((1,) + a.shape[1:], a.dtype)
    kp_p = jnp.concatenate([zeros(kp), kp]); kb_p = jnp.concatenate([zeros(kb), kb])
    vp_p = jnp.concatenate([zeros(vp), vp]); vb_p = jnp.concatenate([zeros(vb), vb])
    tables = jnp.asarray([[1, 2], [3, 0]], jnp.int32)  # row 1: trash tail
    posv = jnp.asarray([2 * bl - 1, bl - 6], jnp.int32)
    q = _x((B, 1, H, hd), dtype, seed=6)
    got = pfd.paged_flash_decode(q, kp_p, kb_p, vp_p, vb_p, tables, posv,
                                 fields=f, interpret=True)
    oracle = jax.jit(functools.partial(ref.paged_flash_decode, fields=f))
    want = oracle(q, kp_p, kb_p, vp_p, vb_p, tables, posv)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_kvcache_dense_fused_matches_unpack_fallback():
    from repro.models import common, attention
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    pf = common.ParamFactory(common.MODE_PARAMS, jax.random.PRNGKey(0),
                             jnp.float32)
    params = attention.attn_init(pf, cfg)
    B, L = 2, 12
    h_tok = 0.3 * _x((B, 1, cfg.d_model), jnp.float32, seed=7)
    outs, caches = {}, {}
    for backend in ("ref", "interpret"):
        ops.force_backend(backend)
        try:
            cache = kvcache.packed_cache_init(cfg, "global", B, 256,
                                              "sfp-m2e4")
            o, c = kvcache.attention_decode_packed(
                params, h_tok, cache, jnp.asarray(L, jnp.int32), cfg,
                kind="global", container="sfp-m2e4")
            outs[backend], caches[backend] = o, c
        finally:
            ops.force_backend(None)
    np.testing.assert_allclose(np.asarray(outs["ref"]),
                               np.asarray(outs["interpret"]),
                               rtol=1e-5, atol=1e-5)
    for part in ("payload", "bases"):
        np.testing.assert_array_equal(
            np.asarray(caches["ref"].k.data[part]),
            np.asarray(caches["interpret"].k.data[part]))


# ---------------------------------------------------------------------------
# Pool byte accounting + per-layer stash + afloat plugin
# ---------------------------------------------------------------------------


def test_pool_dense_byte_accounting():
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="bfloat16")
    bb_dense = kvcache.paged_block_bytes(cfg, 128, "sfp-m2e4")
    bb_fixed = kvcache.paged_block_bytes(cfg, 128, "sfp8")
    D = cfg.n_kv_heads * cfg.head_dim_
    assert bb_dense == 2 * 128 * ((D // 128) * 7 * 16 + D // 128)
    assert bb_dense < bb_fixed
    p = pool.BlockPool(4, 2, 2, 128, block_bytes=bb_dense)
    assert p.stats().capacity_bytes == 4 * bb_dense
    assert p.bytes_for(129) == 2 * bb_dense
    assert p.alloc_upto(0, 200)
    st = p.stats()
    assert st.used_bytes == 2 * bb_dense and st.peak_bytes == 2 * bb_dense
    p.free_slot(0)
    assert p.stats().free_bytes == 4 * bb_dense
    # the device pool really allocates the dense payload shape
    spec = kvcache.paged_block_spec(cfg, 2, 128, "sfp-m2e4")
    assert spec.k_payload.shape[-1] == (D // 128) * 7 * 16
    assert spec.k_payload.dtype == jnp.uint8


def test_per_layer_stash_plan_and_forward():
    cfg = reduced(configs.get("gemma2-2b"), n_layers=4, d_model=128)
    pol = policies.get("qm+qe", container="sfp-m2e4")
    base_model = DecoderModel(cfg, pol)
    st = pol.init_state(base_model.dims)
    st = st._replace(learn={
        **st.learn,
        "qm": {**st.learn["qm"], "act": jnp.asarray([2.0, 5.0])},
        "qe": {**st.learn["qe"], "act": jnp.asarray([4.0, 6.0])}})
    plan = base_model.stash_plan(st)
    assert plan == ("sfp-m2e4", "sfp-m5e6")  # per-layer, not network-wide
    model = DecoderModel(cfg, pol, stash_containers=plan)
    params = model.init(jax.random.PRNGKey(0))
    run = model.run_state(jax.random.PRNGKey(1), st)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, run)[0])(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(grads))
    # wrong plan length fails fast
    with pytest.raises(ValueError, match="one codec per period"):
        DecoderModel(cfg, pol, stash_containers=("sfp8",))


def test_layer_decisions_composition():
    dims = policies.ScopeDims.for_dtype(jnp.bfloat16, n_periods=3, n_rem=1)
    pol = policies.get("qm+qe")
    st = pol.init_state(dims)
    ds = pol.layer_decisions(st, dims)
    assert len(ds) == 3 and ds[0] == (7.0, 8.0)  # full width at init
    # network-wide controllers repeat their summary
    bw = policies.get("bitwave")
    assert len(bw.layer_decisions(bw.init_state(dims), dims)) == 3


def test_afloat_policy_learns_bias():
    dims = policies.ScopeDims.for_dtype(jnp.float32, n_periods=2, n_rem=0)
    pol = policies.get("afloat", container="sfp-m3e4")
    st = pol.init_state(dims)
    assert set(st.learn) >= {"act", "w", "act_b", "w_b"}
    view = pol.forward_view(st.learn, pol.control_view(st.ctrl, dims), dims)
    sl = jax.tree.map(lambda a: a[0], pol.scan_slices(view, dims))
    key = jax.random.PRNGKey(0)
    # a tensor far above the e4 window: positive bias recovers range, so
    # the finite-difference bias gradient must push the bias up (negative
    # grad under gradient descent).
    w = jnp.full((4, 128), 1e4, jnp.float32)

    def loss(learn):
        v = pol.forward_view(learn, pol.control_view(st.ctrl, dims), dims)
        s = jax.tree.map(lambda a: a[0], pol.scan_slices(v, dims))
        wq = pol.quantize_weight(w, s, key, dims)
        return jnp.sum((wq - w) ** 2)

    # drive e low so the window clips: bias grads become informative
    learn = dict(st.learn, w=jnp.full((2,), 4.0, jnp.float32))
    g = jax.grad(loss)(learn)
    assert float(g["w_b"][0]) < 0  # descent increases the bias
    new = pol.update_learn(learn, g, dims)
    assert float(new["w_b"][0]) > float(learn["w_b"][0])
    # penalty ignores bias keys but still prices bitlengths
    lam = {k: jnp.ones_like(v) for k, v in st.learn.items()
           if not k.endswith("_b")}
    pen = pol.penalty(learn, lam, jnp.asarray(0), dims)
    assert np.isfinite(float(pen))


def test_afloat_trains_end_to_end():
    from repro.optim import adamw
    from repro.optim.schedule import Schedule
    from repro.train import step as step_mod
    cfg = reduced(configs.get("gemma2-2b"), n_layers=2, d_model=128)
    model = DecoderModel(cfg, policies.get("afloat", container="bit_exact"))
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=5e-3),
        schedule=Schedule(total_steps=10, warmup_steps=1, base_lr=5e-3))
    step = jax.jit(step_mod.make_train_step(model, tc))
    state = step_mod.init_state(model, jax.random.PRNGKey(0), tc)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32),
             "labels": jnp.ones((4, 16), jnp.int32)}
    for _ in range(2):
        state, met = step(state, batch)
    assert np.isfinite(float(met["loss"]))
    assert "af_act_bias_mean" in met
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(state.pstate.learn))
