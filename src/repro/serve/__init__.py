"""Serving substrate: prefill/decode engine, (compressed) KV cache, the
paged packed-KV block pool, the continuous-batching scheduler,
policy-aware precision resolution (learned bitlengths -> pool codec),
and the fault-tolerance layer (deadlines/cancellation, bounded-queue
load shedding, per-block checksum integrity with quarantine + recompute
recovery, a preemption-storm guard, precision-downshift graceful
degradation under memory pressure, and the deterministic FaultInjector
chaos harness)."""
