"""AdamW with decoupled weight decay, fp32 moments, global-norm clipping.

Pure-JAX (no optax in this environment). Moments are fp32 regardless of
param dtype; the update is computed in fp32 and cast back — standard mixed
precision for bf16 training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(grads, state: AdamWState, params, cfg: AdamWConfig,
           lr: jax.Array) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        pf = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = pf - lr * (step + decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(m=new_m, v=new_v, count=count), gnorm


def sgd_update(grads, params, lr: float, clip: Optional[float] = None):
    """Plain SGD (used for Quantum Mantissa bitlength parameters)."""
    if clip is not None:
        grads, _ = clip_by_global_norm(grads, clip)
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
