"""VMEM budget sweep: every Pallas kernel × arch config × geometry.

Each kernel module exposes a ``vmem_estimate`` — a static model of what
one grid step keeps resident (double-buffered BlockSpec windows + scratch
+ dominant body temporaries). This sweep prices those models for every
attention-bearing registered architecture and every requested container
geometry against the per-core VMEM budget (``roofline.hw.VMEM_PER_CORE``
scaled by ``VMEM_BUDGET_FRACTION``), so a geometry/block-size combination
that cannot fit surfaces in CI instead of as a Mosaic allocation failure
on the first TPU run.

The budget numbers are the v5e datasheet constants; TPU-measured limits
are a ROADMAP follow-up.
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp

from repro import codecs, configs
from repro.analysis.findings import Finding
from repro.kernels import bitplane_pack, packed_flash_decode, sfp_pack
from repro.roofline import hw

_VMEM_PATH = "src/repro/analysis/vmem.py"


def _budget() -> float:
    return hw.VMEM_PER_CORE * hw.VMEM_BUDGET_FRACTION


def _attention_archs():
    """(name, H, KH, hd) for every registered arch with 128-aligned KV."""
    out = []
    for cfg in configs.ASSIGNED:
        if cfg.n_kv_heads <= 0:
            continue
        hd = cfg.head_dim_
        if (cfg.n_kv_heads * hd) % 128:
            continue  # not paged-servable; the engine rejects these too
        out.append((cfg.name, cfg.n_heads, cfg.n_kv_heads, hd))
    return out


def check_vmem(geometries: Sequence[str]) -> List[Finding]:
    budget = _budget()
    out: List[Finding] = []

    def over(scope: str, got: int):
        if got > budget:
            out.append(Finding(
                rule="vmem-budget", path=_VMEM_PATH, line=0, scope=scope,
                message=f"{scope}: static VMEM estimate {got / 2**20:.2f} "
                        f"MiB exceeds the {budget / 2**20:.2f} MiB "
                        f"per-core budget"))

    for name in geometries:
        codec = codecs.get(name)
        fields = codec.pack_fields(jnp.bfloat16)
        if fields is None:
            continue
        pack_est = (bitplane_pack if fields.dense else sfp_pack
                    ).vmem_estimate(fields=fields)
        over(f"quantize_pack:{name}", pack_est)
        for arch, H, KH, hd in _attention_archs():
            over(f"flash_decode:{name}:{arch}",
                 packed_flash_decode.vmem_estimate(fields=fields, H=H,
                                                   KH=KH, hd=hd))
    return out
