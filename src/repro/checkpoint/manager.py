"""Checkpoint manager: atomic, versioned, async, elastically reshardable.

Layout:
  <dir>/step_000123.tmp-<nonce>/   (written, then atomically renamed)
  <dir>/step_000123/
      manifest.json                (tree structure, shapes, dtypes, step)
      arr_00000.npy ...            (one file per leaf, host-gathered)

Fault-tolerance contract:
  * writes are crash-safe (tmp dir + rename; readers never see partials);
  * ``keep`` old checkpoints are retained for rollback;
  * restore() accepts a different mesh/sharding than save() used — leaves
    are host-loaded and re-placed with the new shardings (elastic restart
    after losing nodes);
  * optional codec compression of checkpoint payloads for non-optimizer
    leaves: any registry container (repro.codecs). ``bit_exact`` (default
    when only ``compress_bits`` is given) truncates mantissas like the
    paper's quantizer; ``gecko8`` additionally materializes the Gecko
    exponent stream, so the bytes on disk really shrink (lossless for
    bf16 leaves).

The async writer snapshots to host (blocking only on device->host copy)
and serializes on a background thread.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import codecs

_COMPRESSIBLE_DTYPES = {"float32", "bfloat16", "float16"}

_NATIVE_DTYPES = {
    "float64", "float32", "float16", "int64", "int32", "int16", "int8",
    "uint64", "uint32", "uint16", "uint8", "bool", "complex64", "complex128",
}


def _flatten_with_paths(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 compress_bits: Optional[int] = None,
                 compress_codec: Optional[str] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.compress_bits = compress_bits
        # Registry container realizing the on-disk payload. Legacy
        # compress_bits-only callers get the historical behaviour exactly:
        # bit_exact mantissa truncation applied to float32 leaves only
        # (bf16/fp16 leaves stayed raw before the registry existed).
        if compress_codec is None and compress_bits is not None:
            compress_codec = codecs.BIT_EXACT
            self._compress_dtypes = {"float32"}
        else:
            self._compress_dtypes = _COMPRESSIBLE_DTYPES
        self.compress_codec = compress_codec
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def all_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and "tmp-" not in p.name:
                try:
                    steps.append(int(p.name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host, then write (async unless blocking).

        ``extra`` is JSON-able run metadata recorded verbatim in the
        manifest (e.g. the precision-policy name) and read back via
        :meth:`read_extra` — policy state itself round-trips generically
        as tree leaves."""
        self.wait()  # never two writers at once (gc races on tmp dirs)
        leaves, _ = _flatten_with_paths(tree)
        host = [(name, np.asarray(leaf)) for name, leaf in leaves]

        if blocking:
            self._write(step, host, tree, extra)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, tree, extra),
                daemon=True)
            self._thread.start()

    def _write_guarded(self, step, host, tree, extra):
        try:
            self._write(step, host, tree, extra)
        except BaseException as e:  # pragma: no cover
            self._error = e

    def _write(self, step: int, host, tree, extra=None) -> None:
        final = self._step_dir(step)
        tmp = self.dir / f"{final.name}.tmp-{uuid.uuid4().hex[:8]}"
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        if extra:
            manifest["extra"] = extra
        codec = (codecs.get(self.compress_codec)
                 if self.compress_codec is not None else None)
        for i, (name, arr) in enumerate(host):
            fname = f"arr_{i:05d}.npy"
            entry = {"name": name, "file": fname, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)}
            # A leaf is compressed only when the user asked for lossy
            # quantization explicitly (compress_bits) or the codec is
            # bit-exact for this dtype — never silently degrade data
            # (e.g. gecko8 keeps 7 mantissa bits: lossless bf16, lossy
            # fp32, so fp32 leaves stay raw unless bits are requested).
            if (codec is not None
                    and arr.dtype.name in self._compress_dtypes
                    and arr.ndim >= 2 and "opt" not in name
                    and (self.compress_bits is not None
                         or codec.lossless_for(arr.dtype))):
                stream, meta = codec.encode_host(arr, self.compress_bits)
                entry["codec"] = codec.name
                entry["codec_meta"] = meta
                arr = stream
            if arr.dtype.name not in _NATIVE_DTYPES:
                # ml_dtypes (bf16/fp8) need pickle under np.save; store the
                # raw bits in a same-width uint container instead.
                stored = np.dtype(f"uint{arr.dtype.itemsize * 8}")
                entry["stored_as"] = stored.name
                arr = arr.view(stored)
            np.save(tmp / fname, arr)
            manifest["leaves"].append(entry)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            # re-save of an existing step (e.g. final save == last periodic
            # save): swap the old dir out first — os.replace cannot
            # overwrite a non-empty directory.
            old = self.dir / f"{final.name}.old-{uuid.uuid4().hex[:8]}"
            os.rename(final, old)
            os.replace(tmp, final)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # only reap *stale* tmp dirs (crash leftovers) — a live writer may
        # own a fresh one.
        now = time.time()
        for p in self.dir.glob("step_*.tmp-*"):
            try:
                if now - p.stat().st_mtime > 300:
                    shutil.rmtree(p, ignore_errors=True)
            except OSError:
                pass

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------

    def read_extra(self, step: int) -> Dict[str, Any]:
        """Run metadata recorded at save time ({} for older checkpoints)."""
        manifest = json.loads(
            (self._step_dir(step) / "manifest.json").read_text())
        return manifest.get("extra", {})

    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of ``like``; optionally re-place with
        new shardings (elastic restart onto a different mesh)."""
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten_with_paths(like)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        missing = [name for name, _ in leaves if name not in by_name]
        if missing:
            extra = manifest.get("extra", {})
            hint = (f" (checkpoint was saved with {extra})" if extra else "")
            raise ValueError(
                f"checkpoint step {step} lacks leaves {missing[:4]}"
                f"{'...' if len(missing) > 4 else ''} for the requested "
                f"state tree — e.g. a different precision policy{hint}")
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(leaves))
        out = []
        for (name, leaf), sh in zip(leaves, sh_leaves):
            entry = by_name[name]
            arr = np.load(d / entry["file"])
            if "codec" in entry:
                arr = codecs.get(entry["codec"]).decode_host(
                    arr, entry["codec_meta"], tuple(entry["shape"]),
                    jax.numpy.dtype(entry["dtype"]))
            elif "stored_as" in entry:
                arr = arr.view(jax.numpy.dtype(entry["dtype"]))
            expect = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(
                    f"checkpoint leaf {name} shape {arr.shape} != {expect}")
            target = getattr(leaf, "dtype", arr.dtype)
            if arr.dtype != target:
                # numpy lacks direct casts for ml_dtypes (bf16 etc.)
                arr = np.asarray(jax.numpy.asarray(arr).astype(target))
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
