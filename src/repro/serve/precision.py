"""Policy-aware serving precision: learned bitlengths -> pool codec geometry.

The paper's deployment round-up (§IV-A4): bitlengths learned during
training (Quantum Mantissa / Quantum Exponent / BitWave) carry over to
inference. Training stamps its final per-run ``PrecisionDecision`` summary
into every checkpoint manifest (``CheckpointManager.save(extra=...)`` via
the train loop); this module reads it back with ``read_extra`` and derives
the serving KV pool's container from it — a parametric
``sfp{8|16}-m{K}e{E}`` geometry (codecs/sfp.py) whose payload word holds
exactly the learned mantissa bits and a delta-exponent field sized to the
learned exponent range.

No policy state is restored and no model leaves are touched: the decision
summary is tiny JSON metadata, so a serving host can size its pool before
it ever loads weights.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

from repro.checkpoint.manager import CheckpointManager


def container_for_decision(man_bits: float, exp_bits: float) -> str:
    """Map a (possibly fractional) learned decision to a container name.

    Learned bitlengths are deployed rounded up (a fractional bit cannot be
    stored); the delta-exponent field gets the learned exponent bitlength
    (clamped to [2, 7] — the shared 128-lane base absorbs the rest of the
    range, and deltas below 2 bits cannot distinguish zero from
    saturation). The payload word is the smallest of 8/16 that fits
    sign + dexp + mantissa.
    """
    man = max(1, int(math.ceil(man_bits - 1e-9)))
    dexp = max(2, min(7, int(math.ceil(exp_bits - 1e-9))))
    payload = 8 if 1 + dexp + man <= 8 else 16
    man = min(man, payload - 1 - dexp)
    return f"sfp{payload}-m{man}e{dexp}"


def decision_from_extra(extra: Dict[str, Any]) -> Optional[Dict[str, float]]:
    d = extra.get("decision")
    if not isinstance(d, dict):
        return None
    try:
        return {"man_bits": float(d["man_bits"]),
                "exp_bits": float(d["exp_bits"])}
    except (KeyError, TypeError, ValueError):
        return None


def container_from_checkpoint(ckpt_dir: str,
                              step: Optional[int] = None) -> str:
    """Serving container for a trained run's checkpoint directory.

    Prefers the stamped PrecisionDecision summary (policy-learned
    geometry); falls back to the container the run trained with, then to
    the registry default. Raises if the directory holds no checkpoints.
    """
    from repro import codecs

    mgr = CheckpointManager(ckpt_dir)
    if step is None:
        step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    extra = mgr.read_extra(step)
    decision = decision_from_extra(extra)
    if decision is not None:
        return container_for_decision(**decision)
    return extra.get("container") or codecs.DEFAULT_CONTAINER
