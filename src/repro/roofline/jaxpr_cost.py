"""Jaxpr-level FLOP / HBM-byte cost model with exact scan trip counts.

Why this exists: on the CPU backend, ``compiled.cost_analysis()`` counts
every while/scan body ONCE (validated in tests/test_roofline.py: a scan of
10 matmuls reports 1 matmul of flops). Since this framework is scan-based
end to end (layers, microbatches, attention chunks), we derive the roofline
compute/memory terms from the traced jaxpr instead, where ``scan`` carries
its exact ``length``.

FLOPs: 2*B*M*N*K per dot_general / conv; elementwise+reduce ops count one
flop per element (they are never the dominant term).

HBM bytes: a *materialization model* — bytes are counted where data
plausibly crosses HBM on TPU: program inputs/outputs, dot/conv operands and
results, scatter/gather payloads, and scan xs/ys (stacked, once) + carries
(twice per iteration). Fused elementwise chains count zero. This slightly
overestimates (VMEM-resident tiles are charged) but is consistent across
program variants, which is what hillclimbing needs.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore


def _aval_bytes(aval) -> float:
    try:
        return math.prod(aval.shape) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _aval_numel(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


class Cost:
    __slots__ = ("flops", "bytes")

    def __init__(self, flops=0.0, bytes_=0.0):
        self.flops = flops
        self.bytes = bytes_

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        return self

    def scaled(self, k):
        return Cost(self.flops * k, self.bytes * k)


def _dot_cost(eqn) -> Cost:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    k = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(s for i, s in enumerate(lhs.shape) if i not in lc + lb)
    n = math.prod(s for i, s in enumerate(rhs.shape) if i not in rc + rb)
    flops = 2.0 * batch * m * n * k
    bytes_ = (_aval_bytes(lhs) + _aval_bytes(rhs)
              + _aval_bytes(eqn.outvars[0].aval))
    return Cost(flops, bytes_)


def _conv_cost(eqn) -> Cost:
    out = eqn.outvars[0].aval
    kernel = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    # kernel (spatial..., cin/groups, cout) in HWIO-ish layouts; use numel
    per_out = 2.0 * math.prod(kernel.shape) / max(out.shape[-1], 1)
    flops = _aval_numel(out) * per_out * max(out.shape[-1], 1) / max(groups, 1)
    bytes_ = (_aval_bytes(eqn.invars[0].aval) + _aval_bytes(kernel)
              + _aval_bytes(out))
    return Cost(flops, bytes_)


def cost_of_jaxpr(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        total += _cost_of_eqn(eqn)
    return total


def _subjaxpr(params, *names):
    for n in names:
        if n in params and params[n] is not None:
            j = params[n]
            return j.jaxpr if hasattr(j, "jaxpr") else j
    return None


def subjaxprs(eqn):
    """Yield every jaxpr-valued param of ``eqn`` as an *open* jaxpr.

    Covers scan/while bodies, pjit/remat/custom-vjp calls, cond branch
    lists, and pallas_call kernel bodies — any param that is a ClosedJaxpr,
    a bare Jaxpr, or a list/tuple of either. Shared by the cost model below
    and the static contract analyzers in ``repro.analysis``.
    """
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for b in vs:
            if hasattr(b, "jaxpr") and hasattr(b.jaxpr, "eqns"):
                yield b.jaxpr          # ClosedJaxpr
            elif hasattr(b, "eqns"):
                yield b                # open Jaxpr (e.g. pallas_call body)


def iter_eqns(jaxpr):
    """Depth-first walk over every eqn of ``jaxpr`` including all nested
    sub-jaxprs. This is the one traversal the jaxpr contract checks
    (``repro.analysis.contracts``) build on."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub)


def _cost_of_eqn(eqn) -> Cost:
    prim = eqn.primitive.name
    if prim == "dot_general":
        return _dot_cost(eqn)
    if prim == "conv_general_dilated":
        return _conv_cost(eqn)
    if prim == "scan":
        inner = cost_of_jaxpr(eqn.params["jaxpr"].jaxpr)
        length = eqn.params["length"]
        n_carry = eqn.params["num_carry"]
        n_consts = eqn.params["num_consts"]
        c = inner.scaled(length)
        # xs read once (stacked), ys written once (stacked), carry moves 2x/it
        for v in eqn.invars[n_consts + n_carry:]:
            c += Cost(0.0, _aval_bytes(v.aval))
        for v in eqn.outvars[n_carry:]:
            c += Cost(0.0, _aval_bytes(v.aval))
        for v in eqn.invars[n_consts: n_consts + n_carry]:
            c += Cost(0.0, 2.0 * length * _aval_bytes(v.aval))
        return c
    if prim == "while":
        body = cost_of_jaxpr(eqn.params["body_jaxpr"].jaxpr)
        cond = cost_of_jaxpr(eqn.params["cond_jaxpr"].jaxpr)
        body += cond
        return body  # trip count unknown at trace level; hot paths use scan
    if prim == "cond":
        branches = eqn.params["branches"]
        costs = [cost_of_jaxpr(b.jaxpr) for b in branches]
        return max(costs, key=lambda c: c.flops)
    if prim in ("jit", "pjit", "closed_call", "core_call", "remat2",
                "checkpoint", "custom_vjp_call_jaxpr",
                "custom_jvp_call_jaxpr", "custom_vjp_call",
                "custom_jvp_call"):
        sub = _subjaxpr(eqn.params, "jaxpr", "call_jaxpr", "fun_jaxpr")
        return cost_of_jaxpr(sub) if sub is not None else _generic(eqn)
    if prim == "shard_map":
        sub = _subjaxpr(eqn.params, "jaxpr")
        if sub is None:
            return Cost()
        mesh = eqn.params.get("mesh")
        k = float(getattr(mesh, "size", 1) or 1)
        return cost_of_jaxpr(sub).scaled(k)
    if prim in ("scatter", "scatter-add", "scatter_add", "gather",
                "dynamic_update_slice", "dynamic_slice"):
        b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        b += _aval_bytes(eqn.invars[-1].aval) if eqn.invars else 0.0
        return Cost(sum(_aval_numel(v.aval) for v in eqn.outvars), b)
    if prim.startswith("reduce_") or prim in ("argmax", "argmin"):
        return Cost(sum(_aval_numel(v.aval) for v in eqn.invars), 0.0)
    return _generic(eqn)


def _generic(eqn) -> Cost:
    """Unknown containers: recurse into every jaxpr-valued param; pure
    elementwise ops: one flop per output element, fused (zero bytes)."""
    subs = []
    for v in eqn.params.values():
        if hasattr(v, "jaxpr"):
            subs.append(v.jaxpr if hasattr(v.jaxpr, "eqns") else v)
        elif isinstance(v, (list, tuple)):
            subs += [b.jaxpr for b in v if hasattr(b, "jaxpr")]
    if subs:
        total = Cost()
        for sj in subs:
            total += cost_of_jaxpr(sj)
        return total
    return Cost(sum(_aval_numel(v.aval) for v in eqn.outvars), 0.0)


def estimate(fn, *args, **kwargs) -> Dict[str, float]:
    """Trace ``fn`` with ShapeDtypeStruct args and return global flops/bytes."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    c = cost_of_jaxpr(closed.jaxpr)
    io_bytes = (sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
                + sum(_aval_bytes(v.aval) for v in closed.jaxpr.outvars))
    return {"flops": c.flops, "hbm_bytes": c.bytes + io_bytes,
            "io_bytes": io_bytes}
