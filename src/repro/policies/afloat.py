"""AdaptivFloat-style policy: learned per-tensor exponent *bias* offsets.

AdaptivFloat (PAPERS.md) showed that small-bit float formats work best
when each tensor gets its own exponent bias — the representable window
slides to where the tensor's magnitudes actually live, instead of being
anchored at the IEEE default. This plugin brings that idea into the
policy registry as an extension of Quantum Exponent: on top of QE's
learned per-scope exponent *bitlengths*, ``afloat`` learns a per-scope
*bias offset* (in binades) that shifts the e-bit window via
``containers.truncate_exponent(..., bias_offset=...)``.

The bias gradient is a two-sided finite-difference estimator inside a
custom VJP (the same realized-quantization-difference trick as the QM/QE
stash estimators): d loss / d bias ~= g . (q(b+1) - q(b-1)) / 2, which is
exactly the loss sensitivity to sliding the window one binade either way.
The value path is straight-through. Deployment maps through the same
dense ``sfp-m{K}e{E}`` containers as QE — the bias rides in the shared
per-128-lane base exponents, so no container change is needed; this
policy exists to exercise the dense container stack from outside the
paper (ROADMAP "Policy plugins from related work").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import containers
from repro.policies import base
from repro.policies.quantum import QEPolicy

AF_ACT_SALT = 9  # decorrelate from QM (7) / QE (8) act draws


@jax.custom_vjp
def af_bias_shift(x, e, b):
    """Re-clamp ``x`` to the e-bit window shifted by round(b) binades."""
    return containers.truncate_exponent(x, e,
                                        bias_offset=_round_bias(b))


def _round_bias(b):
    return jnp.round(jnp.asarray(b, jnp.float32)).astype(jnp.int32)


def _af_fwd(x, e, b):
    bi = _round_bias(b)
    return containers.truncate_exponent(x, e, bias_offset=bi), (x, e, bi)


def _af_bwd(res, g):
    x, e, bi = res
    gf = g.astype(jnp.float32)
    hi = containers.truncate_exponent(x, e, bias_offset=bi + 1)
    lo = containers.truncate_exponent(x, e, bias_offset=bi - 1)
    db = 0.5 * jnp.sum(gf * (hi - lo).astype(jnp.float32))
    return g, None, db  # straight-through in x; e learns via qe_quantize


af_bias_shift.defvjp(_af_fwd, _af_bwd)

_BIAS_KEYS = ("act_b", "w_b", "act_rem_b", "w_rem_b")


@dataclasses.dataclass(frozen=True)
class AFloatPolicy(QEPolicy):
    """QE bitlengths + AdaptivFloat learned per-scope bias offsets."""

    bias_lr: float = 0.05
    init_bias: float = 0.0
    max_bias: float = 64.0  # |offset| cap in binades (well past fp32 range)

    name = "afloat"

    # -- state: QE's bitlengths plus one bias per scope -------------------

    def init_state(self, dims):
        st = super().init_state(dims)
        bias = lambda n: jnp.full((n,), float(self.init_bias), jnp.float32)
        learn = dict(st.learn,
                     act_b=bias(dims.n_periods), w_b=bias(dims.n_periods),
                     act_rem_b=bias(dims.n_rem), w_rem_b=bias(dims.n_rem))
        return base.PolicyState(learn=learn, ctrl=st.ctrl)

    def scan_slices(self, view, dims):
        return {"act": view["act"], "w": view["w"],
                "act_b": view["act_b"], "w_b": view["w_b"]}

    def rem_slice(self, view, i, dims):
        return {"act": view["act_rem"][i], "w": view["w_rem"][i],
                "act_b": view["act_rem_b"][i], "w_b": view["w_rem_b"][i]}

    # -- quantizers: QE range reduction, then the learned window shift ----

    def quantize_act(self, x, pslice, key, dims):
        x = super().quantize_act(x, pslice, key, dims)
        e = containers.stochastic_bitlength(
            pslice["act"], jax.random.fold_in(key, AF_ACT_SALT),
            dims.exp_bits, min_bits=containers.MIN_EXP_BITS)
        return af_bias_shift(x, e, pslice["act_b"])

    def quantize_weight(self, w, pslice, key, dims):
        w = super().quantize_weight(w, pslice, key, dims)
        e = containers.stochastic_bitlength(
            pslice["w"], jax.random.fold_in(key, AF_ACT_SALT + 1),
            dims.exp_bits, min_bits=containers.MIN_EXP_BITS)
        return af_bias_shift(w, e, pslice["w_b"])

    def stash_grad(self, dh, h_q, pslice, dims):
        g = super().stash_grad(dh, h_q, pslice, dims)
        g.update({k: jnp.zeros((), jnp.float32)
                  for k in ("act_b", "w_b") if k in pslice})
        return g

    # -- loss & updates: biases are unpenalized and clip symmetrically ----

    def penalty(self, learn, lam, step, dims):
        core = {k: v for k, v in learn.items() if not k.endswith("_b")}
        return super().penalty(core, lam, step, dims)

    def update_learn(self, learn, grads, dims):
        lo = self._min_bits(dims)
        top = float(self._max_bits(dims))
        out = {}
        for k in learn:
            if k.endswith("_b"):
                out[k] = jnp.clip(learn[k] - self.bias_lr * grads[k],
                                  -self.max_bias, self.max_bias)
            else:
                out[k] = jnp.clip(learn[k] - self.lr * grads[k], lo, top)
        return out

    # -- reporting --------------------------------------------------------

    def metrics(self, state, dims):
        m = super().metrics(state, dims)
        return {"af_act_e_mean": m["qe_act_mean"],
                "af_w_e_mean": m["qe_w_mean"],
                "af_act_bias_mean": jnp.mean(state.learn["act_b"]),
                "af_w_bias_mean": jnp.mean(state.learn["w_b"])}

    def snapshot(self, state):
        return {"act_e": state.learn["act"], "w_e": state.learn["w"],
                "act_bias": state.learn["act_b"],
                "w_bias": state.learn["w_b"]}
