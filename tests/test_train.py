"""Training integration: loss decreases, QM bits fall, BitChop reacts,
grad compression preserves convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, policies
from repro.configs.base import reduced
from repro.data import synthetic
from repro.models.model import DecoderModel
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.train import step as step_mod


QM_KW = dict(gamma=0.02, init_bits=7.0, lr=0.1)


def _setup(policy, n_steps=30, arch="mistral-large-123b", **tc_kw):
    cfg = reduced(configs.get(arch))
    model = DecoderModel(cfg, policy)
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=5e-3),
        schedule=Schedule(total_steps=n_steps, warmup_steps=2, base_lr=5e-3),
        **tc_kw)
    step = jax.jit(step_mod.make_train_step(model, tc))
    state = step_mod.init_state(model, jax.random.PRNGKey(0), tc)
    dcfg = synthetic.SyntheticConfig(vocab=cfg.vocab, seq_len=64,
                                     global_batch=8, seed=0)
    corpus = synthetic.MarkovCorpus(dcfg)
    return cfg, step, state, corpus


def _run(step, state, corpus, n):
    hist = []
    for i in range(n):
        b = corpus.batch(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = step(state, batch)
        hist.append({k: float(np.asarray(v)) for k, v in m.items()})
    return state, hist


@pytest.mark.slow
def test_loss_decreases_baseline():
    _, step, state, corpus = _setup(policies.get("none"), 30)
    state, hist = _run(step, state, corpus, 30)
    first = np.mean([h["xent"] for h in hist[:5]])
    last = np.mean([h["xent"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


@pytest.mark.slow
def test_loss_decreases_with_qm_and_bits_fall():
    _, step, state, corpus = _setup(
        policies.get("qm", container="bit_exact", **QM_KW), 40)
    state, hist = _run(step, state, corpus, 40)
    first = np.mean([h["xent"] for h in hist[:5]])
    last = np.mean([h["xent"] for h in hist[-5:]])
    assert last < first - 0.1
    assert hist[-1]["qm_act_mean"] < 7.0  # penalty drives bits down
    assert hist[-1]["qm_w_mean"] < 7.0
    assert np.isfinite(hist[-1]["policy_penalty"])


@pytest.mark.slow
def test_bitchop_mode_runs_and_adjusts():
    _, step, state, corpus = _setup(
        policies.get("bitchop", container="sfp8", warmup_steps=4,
                     max_bits=7), 40)
    state, hist = _run(step, state, corpus, 40)
    bits = [h["bc_bits"] for h in hist]
    assert min(bits) < 7.0  # improving loss -> shrinks below full
    assert np.isfinite(hist[-1]["xent"])


@pytest.mark.slow
def test_grad_compression_convergence_parity():
    pol = policies.get("none")
    _, step_c, state_c, corpus = _setup(pol, 30, grad_compress_bits=5)
    _, step_n, state_n, _ = _setup(pol, 30)
    state_c, hist_c = _run(step_c, state_c, corpus, 30)
    state_n, hist_n = _run(step_n, state_n, corpus, 30)
    # error-feedback truncation must track the exact run closely
    assert abs(hist_c[-1]["xent"] - hist_n[-1]["xent"]) < 0.35


@pytest.mark.slow
def test_microbatching_equivalence():
    """Same data, 1 vs 4 microbatches: losses must match closely (grad
    accumulation is a mean; RNG per microbatch differs only for QM draws,
    so compare in policy-none mode)."""
    pol = policies.get("none")
    cfg, step1, state1, corpus = _setup(pol, 6, num_microbatches=1)
    _, step4, state4, _ = _setup(pol, 6, num_microbatches=4)
    state1, h1 = _run(step1, state1, corpus, 6)
    state4, h4 = _run(step4, state4, corpus, 6)
    np.testing.assert_allclose(h1[-1]["xent"], h4[-1]["xent"], atol=5e-2)


@pytest.mark.slow
def test_static_policy_matches_gist_style():
    _, step, state, corpus = _setup(
        policies.get("static", static_act_bits=3, container="sfp8"), 20)
    state, hist = _run(step, state, corpus, 20)
    assert hist[-1]["xent"] < hist[0]["xent"] + 0.1


@pytest.mark.slow
def test_moe_arch_trains():
    _, step, state, corpus = _setup(
        policies.get("qm", container="bit_exact", **QM_KW), 12,
        arch="olmoe-1b-7b")
    state, hist = _run(step, state, corpus, 12)
    assert np.isfinite(hist[-1]["xent"])
    assert hist[-1]["moe_drop_frac"] < 0.6


def test_schedule_boundaries_and_lr():
    s = Schedule(kind="step", base_lr=1.0, warmup_steps=0, total_steps=100,
                 boundaries=(10, 20))
    assert float(s(jnp.asarray(5))) == 1.0
    assert abs(float(s(jnp.asarray(15))) - 0.1) < 1e-6
    assert abs(float(s(jnp.asarray(25))) - 0.01) < 1e-7
    assert bool(s.lr_changed(jnp.asarray(10)))
    assert not bool(s.lr_changed(jnp.asarray(11)))


def test_adamw_step_moves_toward_minimum():
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.5, weight_decay=0.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, st, _ = adamw.update(grads, st, params, cfg,
                                     jnp.asarray(0.1, jnp.float32))
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5
