"""Seeded violations: precision-policy names that do not parse."""
from repro import policies

p = policies.get("qmm")  # LINT: policy-name
train_policy = "qm+qm"  # LINT: policy-name
composed = dict(policy="qm+qx")  # LINT: policy-name
good_policy = "qm+qe"
