"""Codec subsystem benchmark: fused quantize+pack vs the two-kernel
sequence, plus realized footprints of every registered container.

The paper's hardware compressor fuses the mantissa quantizer with the
container packer so a tensor crosses the memory boundary once. The TPU
realization is kernels/sfp_pack.py's ``sfp_quantize_pack``; this benchmark
measures the same fusion on the reference backend — two separately
compiled executables (the old ops.mantissa_quantize -> ops.sfp_compress_nd
sequence, which materializes the quantized intermediate) against the
single-pass fused pack.

Emitted as BENCH_codecs.json by benchmarks/run.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

SHAPE = (8192, 8192)   # 128 MB of bf16 activations: memory-bound regime
BITS = 3               # where Quantum Mantissa lands (paper Fig 4)
ITERS = 10


def _median_ms(fn, iters=ITERS) -> float:
    fn()  # compile + warm caches
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def run() -> dict:
    from repro import codecs
    from repro.kernels import ops, ref

    ops.force_backend("ref")
    try:
        x = (jax.random.normal(jax.random.PRNGKey(0), SHAPE, jnp.float32)
             ).astype(jnp.bfloat16)
        fields = codecs.fields_for(codecs.SFP8, x.dtype)
        n = jnp.int32(BITS)

        quant = jax.jit(lambda x, n: ref.mantissa_truncate(x, n))
        pack = jax.jit(lambda q: ref.sfp_pack_nd(q, fields))
        fused = jax.jit(lambda x, n: ref.sfp_pack_nd(x, fields, n=n))

        two_ms = _median_ms(
            lambda: jax.block_until_ready(pack(quant(x, n))))
        fused_ms = _median_ms(
            lambda: jax.block_until_ready(fused(x, n)))

        # Bit-exactness of the fusion (same payload, same bases).
        p2, b2 = pack(quant(x, n))
        p1, b1 = fused(x, n)
        exact = bool(jnp.all(p1 == p2)) and bool(jnp.all(b1 == b2))

        # Realized footprint of each registered container on a small probe.
        probe = x[:64]
        footprints = {
            name: float(codecs.get(name).packed_bits(probe)) / probe.size
            for name in codecs.names()
        }
    finally:
        ops.force_backend(None)

    return {
        "backend": "ref",
        "container": codecs.SFP8,
        "shape": list(SHAPE),
        "dtype": "bfloat16",
        "bits": BITS,
        "two_kernel_ms": two_ms,
        "fused_ms": fused_ms,
        "speedup": two_ms / fused_ms,
        "bit_exact_fusion": exact,
        "bits_per_value": footprints,
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
