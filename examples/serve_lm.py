"""Serving example: prefill + batched greedy decode with a compressed
KV cache (SFP8 containers) next to the exact bf16 cache.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import reduced
from repro.models import attention
from repro.models.model import DecoderModel
from repro.serve import engine, kvcache

cfg = reduced(configs.get("mistral-large-123b"))
model = DecoderModel(cfg)
params = model.init(jax.random.PRNGKey(0))

B, S, NEW = 4, 32, 16
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

t0 = time.time()
res = engine.generate(model, params, prompt, max_new=NEW)
print(f"greedy generate: {res.tokens.shape} in {time.time()-t0:.1f}s")
print("first sequence:", np.asarray(res.tokens[0]).tolist())

# End-to-end generation over the sfp8-packed KV cache (on TPU/interpret,
# decode attends the packed bytes directly via the fused flash-decode
# kernel; on the CPU ref backend it decompresses then attends).
pk_model = DecoderModel(cfg, kv_container="sfp8")
t0 = time.time()
res_pk = engine.generate(pk_model, params, prompt, max_new=NEW)
print(f"packed-cache generate: {res_pk.tokens.shape} in "
      f"{time.time()-t0:.1f}s")
print("first sequence:", np.asarray(res_pk.tokens[0]).tolist())

# compressed-KV decode for one layer: error stays bounded
p0 = jax.tree.map(lambda a: a[0], params["periods"])["slot0"]["attn"]
h = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model),
                            cfg.compute_dtype)
raw = attention.cache_init(cfg, "global", B, 64, cfg.compute_dtype)
packed = kvcache.packed_cache_init(cfg, "global", B, 64)
o_raw, _ = attention.attention_decode(p0, h, raw, jnp.asarray(0), cfg,
                                      kind="global")
o_pk, _ = kvcache.attention_decode_packed(p0, h, packed, jnp.asarray(0),
                                          cfg, kind="global")
rel = float(jnp.max(jnp.abs((o_pk - o_raw).astype(jnp.float32)))
            / (float(jnp.max(jnp.abs(o_raw.astype(jnp.float32)))) + 1e-9))
bytes_raw = raw.k.size * 2 * 2
bytes_pk = sum(a.size * a.dtype.itemsize
               for a in jax.tree.leaves(packed.k)) * 2
print(f"compressed KV: {bytes_raw} B -> {bytes_pk} B "
      f"({bytes_pk/bytes_raw:.2%}), relative decode error {rel:.3f}")
