"""Target hardware constants: TPU v5e (per assignment)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
HBM_PER_CHIP = 16 * 2 ** 30   # 16 GiB
