"""TrainState: everything a training step carries between steps."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from repro.optim import adamw
from repro.policies import PolicyState


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    # Precision-policy state (PolicyState(learn, ctrl)): learned bitlength
    # parameters + controller registers, opaque to the loop/checkpointing.
    pstate: PolicyState
    step: jax.Array
    rng: jax.Array
    # error-feedback residual for compressed cross-pod gradient all-reduce
    grad_residual: Any
