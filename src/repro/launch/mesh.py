"""Production mesh builders.

A function (not a module-level constant) so importing never touches jax
device state. The dry-run overrides the host platform device count to 512
*before* any jax import (see dryrun.py lines 1-2).

  single pod : (16, 16)        axes (data, model)      — 256 chips
  multi  pod : (2, 16, 16)     axes (pod, data, model) — 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess SPMD tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
