"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel allclose tests, the CPU execution
path, and the lowering path used by the multi-pod dry-run (Pallas TPU
kernels cannot lower on the CPU backend; the FLOP/byte structure of these
references matches the kernels').
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import containers

# ---------------------------------------------------------------------------
# Mantissa quantization (paper eq. 5) — oracle for kernels/mantissa_quant.py
# ---------------------------------------------------------------------------


def mantissa_truncate(x: jax.Array, n) -> jax.Array:
    """Q(M, n): keep the top ``n`` mantissa bits. ``n`` scalar (traced ok)."""
    return containers.truncate_mantissa(x, n)


# ---------------------------------------------------------------------------
# SFP8 / SFP16 containers — oracles for kernels/sfp_pack.py
#
# Layouts (DESIGN.md D3). One shared 8-bit base exponent per group of 128
# lanes (Gecko column-base in spirit; max-exponent base so deltas are >= 0):
#   SFP8  byte  = sign<<7 | dexp4<<3 | man3        (bf16 payload)
#   SFP16 word  = sign<<15 | dexp5<<10 | man10|man7<<3   (fp32|bf16 payload)
# dexp saturates; (dexp == max, man == 0) encodes exact zero.
# ---------------------------------------------------------------------------

GROUP = 128


def _sfp_fields(container: str, spec: containers.FloatSpec):
    if container == "sfp8":
        man_keep, dexp_bits = 3, 4
    elif container == "sfp16":
        man_keep, dexp_bits = (10, 5) if spec.man_bits == 23 else (7, 5)
    else:
        raise ValueError(container)
    return man_keep, dexp_bits


def _to_rows(x: jax.Array) -> jax.Array:
    """Flatten to (rows, 128) lane groups, zero-padding the tail."""
    flat = x.reshape(-1)
    pad = (-flat.size) % GROUP
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, GROUP)


def sfp_pack(x: jax.Array, container: str = "sfp8"):
    """Pack a float tensor into (payload (R, 128), bases (R, 1) uint8).

    Rows are consecutive 128-lane groups of the flattened tensor (Gecko
    columns); identical layout to kernels/sfp_pack.py.
    """
    spec = containers.spec_for(x)
    man_keep, dexp_bits = _sfp_fields(container, spec)
    dexp_max = (1 << dexp_bits) - 1

    xg = _to_rows(x)
    sign, e, man = containers.split_fields(xg)
    sign = sign.astype(jnp.int32)
    e = e.astype(jnp.int32)
    man = man.astype(jnp.int32)

    base = jnp.max(e, axis=-1, keepdims=True)  # max-exponent base: deltas >= 0
    dexp = base - e
    man_top = man >> (spec.man_bits - man_keep)

    flush = (e == 0) | (dexp > dexp_max)  # exact zeros + magnitudes below range
    dexp = jnp.where(flush, dexp_max, jnp.minimum(dexp, dexp_max))
    man_top = jnp.where(flush, 0, man_top)
    sign = jnp.where(e == 0, 0, sign)

    if container == "sfp8":
        payload = ((sign << 7) | (dexp << 3) | man_top).astype(jnp.uint8)
    else:
        payload = ((sign << 15) | (dexp << (15 - dexp_bits)) | (
            man_top << (15 - dexp_bits - man_keep))).astype(jnp.uint16)
    return payload, base.astype(jnp.uint8)


def sfp_pack_nd(x: jax.Array, container: str = "sfp8"):
    """Rank-preserving pack: groups along the last dim (must be %128 == 0).

    Keeps the leading dims (batch, seq, ...) intact so GSPMD shardings
    propagate through the packed stash unchanged. payload has x's shape
    (uint8/uint16); bases has shape (*x.shape[:-1], D//128).
    """
    D = x.shape[-1]
    assert D % GROUP == 0, (x.shape,)
    spec = containers.spec_for(x)
    man_keep, dexp_bits = _sfp_fields(container, spec)
    dexp_max = (1 << dexp_bits) - 1

    xg = x.reshape(*x.shape[:-1], D // GROUP, GROUP)
    sign, e, man = containers.split_fields(xg)
    sign = sign.astype(jnp.int32)
    e = e.astype(jnp.int32)
    man = man.astype(jnp.int32)
    base = jnp.max(e, axis=-1, keepdims=True)
    dexp = base - e
    man_top = man >> (spec.man_bits - man_keep)
    flush = (e == 0) | (dexp > dexp_max)
    dexp = jnp.where(flush, dexp_max, jnp.minimum(dexp, dexp_max))
    man_top = jnp.where(flush, 0, man_top)
    sign = jnp.where(e == 0, 0, sign)
    if container == "sfp8":
        payload = ((sign << 7) | (dexp << 3) | man_top).astype(jnp.uint8)
    else:
        payload = ((sign << 15) | (dexp << (15 - dexp_bits)) | (
            man_top << (15 - dexp_bits - man_keep))).astype(jnp.uint16)
    return payload.reshape(x.shape), base[..., 0].astype(jnp.uint8)


def sfp_unpack_nd(payload: jax.Array, bases: jax.Array, dtype,
                  container: str = "sfp8") -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    man_keep, dexp_bits = _sfp_fields(container, spec)
    dexp_max = (1 << dexp_bits) - 1

    D = payload.shape[-1]
    p = payload.reshape(*payload.shape[:-1], D // GROUP, GROUP).astype(jnp.int32)
    if container == "sfp8":
        sign = (p >> 7) & 1
        dexp = (p >> 3) & dexp_max
        man_top = p & ((1 << man_keep) - 1)
    else:
        sign = (p >> 15) & 1
        dexp = (p >> (15 - dexp_bits)) & dexp_max
        man_top = (p >> (15 - dexp_bits - man_keep)) & ((1 << man_keep) - 1)
    base = bases.astype(jnp.int32)[..., None]
    e = jnp.maximum(base - dexp, 0)
    man = man_top << (spec.man_bits - man_keep)
    flush = (dexp == dexp_max) & (man_top == 0)
    e = jnp.where(flush, 0, e)
    man = jnp.where(flush, 0, man)
    sign = jnp.where(flush, 0, sign)
    out = containers.combine_fields(
        sign.astype(spec.int_dtype), e.astype(spec.int_dtype),
        man.astype(spec.int_dtype), spec)
    return out.reshape(payload.shape)


def sfp_unpack(payload: jax.Array, bases: jax.Array, shape: tuple,
               dtype, container: str = "sfp8") -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    man_keep, dexp_bits = _sfp_fields(container, spec)
    dexp_max = (1 << dexp_bits) - 1

    p = payload.astype(jnp.int32)
    if container == "sfp8":
        sign = (p >> 7) & 1
        dexp = (p >> 3) & dexp_max
        man_top = p & ((1 << man_keep) - 1)
    else:
        sign = (p >> 15) & 1
        dexp = (p >> (15 - dexp_bits)) & dexp_max
        man_top = (p >> (15 - dexp_bits - man_keep)) & ((1 << man_keep) - 1)

    base = bases.astype(jnp.int32)
    e = jnp.maximum(base - dexp, 0)
    man = man_top << (spec.man_bits - man_keep)
    flush = (dexp == dexp_max) & (man_top == 0)
    e = jnp.where(flush, 0, e)
    man = jnp.where(flush, 0, man)
    sign = jnp.where(flush, 0, sign)
    out = containers.combine_fields(
        sign.astype(spec.int_dtype), e.astype(spec.int_dtype),
        man.astype(spec.int_dtype), spec)
    n = 1
    for s in shape:
        n *= s
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# Attention oracle — for kernels/flash_attention.py
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,           # (B, Sq, H, D)
    k: jax.Array,           # (B, Sk, KH, D)
    v: jax.Array,           # (B, Sk, KH, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,      # sliding window (local attention)
    softcap: Optional[float] = None,   # gemma2 attn-logit softcap
    prefix_len: int = 0,               # prefix-LM: first P kv fully visible
    q_offset: int = 0,                 # absolute position of q[0] (decode)
) -> jax.Array:
    """Reference multi-head GQA attention, O(Sq*Sk). fp32 accumulation."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    rep = H // KH
    kq = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vq = jnp.repeat(v, rep, axis=2) if rep > 1 else v

    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask = k_pos <= q_pos
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    if prefix_len > 0:
        mask = mask | (k_pos < prefix_len)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
