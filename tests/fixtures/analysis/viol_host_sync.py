"""Seeded violations: device->host syncs inside a traced scope."""
import jax
import jax.numpy as jnp
import numpy as np


def step(x):
    loss = jnp.mean(x)
    scalar = loss.item()  # LINT: host-sync-in-jit
    host = np.asarray(loss)  # LINT: host-sync-in-jit
    fetched = jax.device_get(loss)  # LINT: host-sync-in-jit
    lr = float(jnp.exp(loss))  # LINT: host-sync-in-jit
    return loss + scalar + host.sum() + fetched + lr


out = jax.jit(step)(jnp.zeros((4,)))


def host_side(x):
    # NOT traced: the same calls are fine outside a jitted function.
    return float(jnp.mean(x))
