"""Train-step builder: microbatched grad accumulation + precision policies.

One jitted function per (arch, shape, policy):

  * microbatch scan — grads accumulate across num_microbatches slices of the
    global batch; only the final accumulation feeds the optimizer, so FSDP
    reduce-scatters amortize across microbatches (collective overlap).
  * precision policy — the model's stash/weight quantization is driven by
    the policy's PrecisionDecisions; learned bitlength parameters
    (Quantum Mantissa / Quantum Exponent) receive their exact weight-side
    + stash-estimator gradients plus the eq. 7 footprint penalty, then the
    policy's own SGD step; controller policies (BitChop / BitWave) observe
    the (pre-penalty) loss once per step (eq. 8-9), holding full precision
    around LR-schedule boundaries. The step never dispatches on policy
    names — everything routes through the Policy interface.
  * optional gradient compression with error feedback for the cross-pod
    all-reduce (train/grad_compress.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import DecoderModel, RunState
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.policies import PolicyState
from repro.train import grad_compress
from repro.train.state import TrainState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule: Schedule = Schedule()
    num_microbatches: int = 1
    grad_compress_bits: Optional[int] = None  # e.g. 4 -> bf16/4-bit-man wire
    grad_codec: str = "bit_exact"  # registry codec realizing the wire format
    # Optional tree of NamedShardings for params: pins the gradient
    # accumulator to the parameter layout so XLA reduce-scatters gradients
    # into shards (ZeRO-2) instead of all-reducing them in full.
    param_shardings: Optional[Any] = None


def init_state(model: DecoderModel, key: jax.Array, tc: TrainConfig
               ) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt=adamw.init(params),
        pstate=model.policy.init_state(model.dims),
        step=jnp.zeros((), jnp.int32),
        rng=jax.random.fold_in(key, 999),
        grad_residual=(grad_compress.init_residual(params)
                       if tc.grad_compress_bits else None),
    )


def _scope_lambdas(model: DecoderModel, batch_shape: Tuple[int, int]
                   ) -> Dict[str, jnp.ndarray]:
    """Footprint weights (eq. 7): each group's share of total stash bits.

    Activation stash per period: B * S_total * d values; weight footprint
    per period: parameter count of that period. Shares are computed over
    the combined activation+weight footprint, exactly as the paper weighs
    its loss to minimize *total* memory. The same weights serve every
    learned-bitlength policy (mantissa and exponent bits of one tensor
    scope occupy the same share of the stash).
    """
    cfg = model.cfg
    B, S = batch_shape
    S_tot = S + cfg.prefix_tokens
    shapes = model.param_shapes()
    per_period = sum(
        math.prod(s.shape[1:]) for s in jax.tree.leaves(shapes["periods"]))
    act = float(B * S_tot * cfg.d_model)
    n_rem = len(cfg.remainder)
    rem_w = (sum(math.prod(s.shape)
                 for s in jax.tree.leaves(shapes.get("rem", {}))) / max(n_rem, 1)
             if n_rem else 0.0)
    total = (act + per_period) * cfg.n_periods + (act + rem_w) * n_rem
    lam = {
        "act": jnp.full((cfg.n_periods,), act / total, jnp.float32),
        "w": jnp.full((cfg.n_periods,), per_period / total, jnp.float32),
        "act_rem": jnp.full((n_rem,), act / total, jnp.float32),
        "w_rem": jnp.full((n_rem,), rem_w / total, jnp.float32),
    }
    return lam


def make_train_step(model: DecoderModel, tc: TrainConfig):
    policy = model.policy
    dims = model.dims

    def loss_fn(params, learn, batch_mb, key, cview, step, lam):
        run = RunState(key=key,
                       pol=policy.forward_view(learn, cview, dims))
        loss, metrics = model.loss(params, batch_mb, run)
        penalty = policy.penalty(learn, lam, step, dims)
        metrics = dict(metrics, policy_penalty=penalty)
        return loss + penalty, metrics

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        B, S = batch["tokens"].shape
        nm = tc.num_microbatches
        assert B % nm == 0, (B, nm)
        lam = _scope_lambdas(model, (B // nm, S))
        lr = tc.schedule(state.step)
        cview = policy.control_view(state.pstate.ctrl, dims)
        step_key = jax.random.fold_in(state.rng, state.step)

        mb_batch = jax.tree.map(
            lambda x: x.reshape((nm, B // nm) + x.shape[1:]), batch)

        def micro(carry, inp):
            g_acc, q_acc, loss_acc, xent_acc = carry
            mb, i = inp
            (loss, metrics), (gp, gl) = grad_fn(
                state.params, state.pstate.learn, mb,
                jax.random.fold_in(step_key, i), cview, state.step, lam)
            if tc.param_shardings is not None:
                g_acc = jax.tree.map(
                    lambda a, g, sh: jax.lax.with_sharding_constraint(
                        a + g.astype(jnp.float32) / nm, sh),
                    g_acc, gp, tc.param_shardings)
            else:
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / nm, g_acc, gp)
            q_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / nm, q_acc, gl)
            return (g_acc, q_acc, loss_acc + loss / nm,
                    xent_acc + metrics["xent"] / nm), metrics

        if tc.param_shardings is not None:
            g0 = jax.tree.map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), sh),
                state.params, tc.param_shardings)
        else:
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
        q0 = jax.tree.map(jnp.zeros_like, state.pstate.learn)
        z = jnp.zeros((), jnp.float32)
        (grads, lgrads, loss, xent), metrics_seq = jax.lax.scan(
            micro, (g0, q0, z, z), (mb_batch, jnp.arange(nm)))

        # Optional compressed cross-pod gradient exchange (error feedback).
        residual = state.grad_residual
        if tc.grad_compress_bits is not None:
            grads, residual = grad_compress.compress_grads(
                grads, residual, tc.grad_compress_bits, tc.grad_codec)

        new_params, new_opt, gnorm = adamw.update(
            grads, state.opt, state.params, tc.opt, lr)

        # Policy updates: learned bitlengths take their SGD step, the
        # controller observes the (pre-penalty) loss (eq. 8-9).
        new_learn = policy.update_learn(state.pstate.learn, lgrads, dims)
        new_ctrl = policy.observe(state.pstate.ctrl, xent,
                                  tc.schedule.lr_changed(state.step), dims)
        new_pstate = PolicyState(learn=new_learn, ctrl=new_ctrl)

        metrics = {
            "loss": loss, "xent": xent, "lr": lr, "grad_norm": gnorm,
            "moe_lb_loss": metrics_seq["moe_lb_loss"].mean(),
            "moe_drop_frac": metrics_seq["moe_drop_frac"].mean(),
            "policy_penalty": metrics_seq["policy_penalty"].mean(),
            **policy.metrics(new_pstate, dims),
        }
        new_state = TrainState(
            params=new_params, opt=new_opt, pstate=new_pstate,
            step=state.step + 1, rng=state.rng, grad_residual=residual)
        return new_state, metrics

    return train_step
