"""Composition: run several policies on the same tensors in one step.

``policies.get("qm+qe")`` builds one of these. Sub-policy state is
namespaced by sub-policy name inside one PolicyState; decisions combine
field-wise by ``min`` (each sub-policy constrains the field it adapts and
leaves the other at full width), quantizers apply in registration order
(mantissa truncation before exponent clamping for "qm+qe", so saturation
cannot reintroduce dropped mantissa bits), and every per-call PRNG key is
folded with the sub-policy index so stochastic draws decorrelate.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.policies import base


@dataclasses.dataclass(frozen=True)
class CompositePolicy(base.Policy):
    policies: Tuple[base.Policy, ...] = ()

    @property
    def name(self):  # type: ignore[override]
        return "+".join(p.name for p in self.policies)

    @property
    def enabled(self):  # type: ignore[override]
        return any(p.enabled for p in self.policies)

    @property
    def adapts_exponent(self):  # type: ignore[override]
        return any(p.adapts_exponent for p in self.policies)

    @property
    def has_stash_grad(self):  # type: ignore[override]
        return any(p.has_stash_grad for p in self.policies)

    @property
    def requires_act_bits(self):  # type: ignore[override]
        return any(p.requires_act_bits for p in self.policies)

    @property
    def quantizes_weights(self):  # type: ignore[override]
        return any(p.quantizes_weights for p in self.policies)

    def _sub(self, fn):
        return {p.name: fn(p) for p in self.policies}

    def init_state(self, dims):
        states = self._sub(lambda p: p.init_state(dims))
        return base.PolicyState(
            learn={k: s.learn for k, s in states.items()},
            ctrl={k: s.ctrl for k, s in states.items()})

    def control_view(self, ctrl, dims):
        return self._sub(lambda p: p.control_view(ctrl[p.name], dims))

    def forward_view(self, learn, cview, dims):
        return self._sub(
            lambda p: p.forward_view(learn[p.name], cview[p.name], dims))

    def scan_slices(self, view, dims):
        return self._sub(lambda p: p.scan_slices(view[p.name], dims))

    def rem_slice(self, view, i, dims):
        return self._sub(lambda p: p.rem_slice(view[p.name], i, dims))

    def act_decision(self, pslice, key, dims):
        man = jnp.asarray(dims.man_bits, jnp.int32)
        exp = jnp.asarray(dims.exp_bits, jnp.int32)
        for i, p in enumerate(self.policies):
            d = p.act_decision(pslice[p.name], jax.random.fold_in(key, i),
                               dims)
            man = jnp.minimum(man, d.man_bits)
            exp = jnp.minimum(exp, d.exp_bits)
        return base.PrecisionDecision(man_bits=man, exp_bits=exp)

    def quantize_act(self, x, pslice, key, dims):
        for i, p in enumerate(self.policies):
            x = p.quantize_act(x, pslice[p.name], jax.random.fold_in(key, i),
                               dims)
        return x

    def quantize_weight(self, w, pslice, key, dims):
        for i, p in enumerate(self.policies):
            if p.quantizes_weights:
                w = p.quantize_weight(w, pslice[p.name],
                                      jax.random.fold_in(key, i), dims)
        return w

    def stash_grad(self, dh, h_q, pslice, dims):
        return self._sub(lambda p: p.stash_grad(dh, h_q, pslice[p.name], dims)
                         if p.has_stash_grad
                         else jax.tree.map(lambda a: jnp.zeros_like(a),
                                           pslice[p.name]))

    def penalty(self, learn, lam, step, dims):
        acc = jnp.zeros((), jnp.float32)
        for p in self.policies:
            acc = acc + p.penalty(learn[p.name], lam, step, dims)
        return acc

    def update_learn(self, learn, grads, dims):
        return self._sub(
            lambda p: p.update_learn(learn[p.name], grads[p.name], dims))

    def observe(self, ctrl, loss, lr_changed, dims):
        return self._sub(lambda p: p.observe(ctrl[p.name], loss, lr_changed,
                                             dims))

    def metrics(self, state, dims):
        out = {}
        for p in self.policies:
            out.update(p.metrics(
                base.PolicyState(learn=state.learn[p.name],
                                 ctrl=state.ctrl[p.name]), dims))
        return out

    def snapshot(self, state):
        out = {}
        for p in self.policies:
            out.update(p.snapshot(
                base.PolicyState(learn=state.learn[p.name],
                                 ctrl=state.ctrl[p.name])))
        return out

    def decision_summary(self, state, dims):
        man, exp = float(dims.man_bits), float(dims.exp_bits)
        for p in self.policies:
            d = p.decision_summary(
                base.PolicyState(learn=state.learn[p.name],
                                 ctrl=state.ctrl[p.name]), dims)
            man = min(man, d["man_bits"])
            exp = min(exp, d["exp_bits"])
        return {"man_bits": man, "exp_bits": exp}

    def layer_decisions(self, state, dims):
        # Field-wise min per period, like act_decision: each sub-policy
        # constrains the field it adapts and leaves the other full-width.
        per_sub = [p.layer_decisions(
            base.PolicyState(learn=state.learn[p.name],
                             ctrl=state.ctrl[p.name]), dims)
            for p in self.policies]
        return [(min(d[0] for d in ds), min(d[1] for d in ds))
                for ds in zip(*per_sub)]
