"""Serving substrate: prefill/decode engine, (compressed) KV cache, the
paged packed-KV block pool, the continuous-batching scheduler, and
policy-aware precision resolution (learned bitlengths -> pool codec)."""
