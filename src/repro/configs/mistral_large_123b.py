"""mistral-large-123b [dense] — all-global GQA decoder.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified] 88L, d_model=12288,
96H (GQA kv=8), d_ff=28672, vocab=32768.
"""
from repro.configs.base import ArchConfig, GLOBAL, register

MISTRAL_LARGE_123B = register(ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    period=(GLOBAL,),
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Large-Instruct-2407; assignment spec",
))
