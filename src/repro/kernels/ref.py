"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel allclose tests, the CPU execution
path, and the lowering path used by the multi-pod dry-run (Pallas TPU
kernels cannot lower on the CPU backend; the FLOP/byte structure of these
references matches the kernels').

Kernels here are *format-agnostic bit machines*: SFP pack/unpack take a
``PackFields`` describing the payload word geometry, and the Gecko plane
codec works on raw uint8 exponent groups. The mapping from container
*names* (sfp8, sfp16, gecko8, ...) to bit geometries lives in one place —
the codec registry (``repro.codecs``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import containers

# ---------------------------------------------------------------------------
# Mantissa quantization (paper eq. 5) — oracle for kernels/mantissa_quant.py
# ---------------------------------------------------------------------------


def mantissa_truncate(x: jax.Array, n) -> jax.Array:
    """Q(M, n): keep the top ``n`` mantissa bits. ``n`` scalar (traced ok)."""
    return containers.truncate_mantissa(x, n)


def default_interpret(flag: Optional[bool] = None) -> bool:
    """Resolve a kernel ``interpret`` argument: an explicit flag wins;
    ``None`` auto-selects interpret mode exactly when not running on TPU.

    Every Pallas entry point in this package defaults ``interpret=None``
    and routes through here, so kernels compile for real on TPU without
    each call site threading the flag (``repro.analysis`` lints for
    hard-coded ``interpret=True`` defaults leaking outside tests)."""
    if flag is not None:
        return bool(flag)
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# SFP fixed-width containers — oracles for kernels/sfp_pack.py
#
# Layouts (DESIGN.md D3). One shared 8-bit base exponent per group of 128
# lanes (Gecko column-base in spirit; max-exponent base so deltas are >= 0):
#   payload word = sign<<(P-1) | dexp<<(P-1-E) | man_top<<(P-1-E-K)
# with P = payload bits, E = delta-exponent bits, K = kept mantissa bits.
# dexp saturates; (dexp == max, man == 0) encodes exact zero.
# ---------------------------------------------------------------------------

GROUP = 128
PLANE_BYTES = GROUP // 8  # one byte-aligned bit plane of a 128-lane group


class PackFields(NamedTuple):
    """Payload geometry of an SFP container.

    Kernels receive this instead of a container-name string; the registry
    in ``repro.codecs`` owns the name -> PackFields mapping.

    ``dense=False`` is the fixed-lane layout: one 8/16-bit payload word
    per value. ``dense=True`` is the bit-plane layout: the payload word is
    ``1 + dexp_bits + man_keep`` bits wide (any width 3..16) and each of
    its bits is stored as a contiguous byte-aligned plane over the
    128-lane group (16 bytes/plane, Gecko-style), so a value really
    occupies ``payload_bits`` bits — no rounding up to a lane width.
    """

    man_keep: int       # mantissa bits kept in the payload
    dexp_bits: int      # delta-exponent field width
    payload_bits: int   # total payload word width (3..16)
    dense: bool = False  # True -> byte-aligned bit-plane storage

    @property
    def word_dtype(self):
        """Narrowest uint holding one payload word (kernel-internal)."""
        return jnp.uint8 if self.payload_bits <= 8 else jnp.uint16

    @property
    def payload_dtype(self):
        """Element dtype of the stored payload array (planes are bytes)."""
        return jnp.uint8 if self.dense else self.word_dtype

    @property
    def group_payload_bytes(self) -> int:
        """Payload bytes one 128-lane group occupies (excl. the base)."""
        if self.dense:
            return self.payload_bits * PLANE_BYTES
        return GROUP * (1 if self.payload_bits <= 8 else 2)

    def nd_payload_cols(self, D: int) -> int:
        """Minor-dim width of the rank-preserving payload for a feature
        dim ``D`` (% 128 == 0): D payload words, or (D//128) groups of
        ``payload_bits`` 16-byte planes."""
        if self.dense:
            return (D // GROUP) * self.group_payload_bytes
        return D

    @property
    def sign_shift(self) -> int:
        return self.payload_bits - 1

    @property
    def dexp_shift(self) -> int:
        return self.payload_bits - 1 - self.dexp_bits

    @property
    def man_shift(self) -> int:
        return self.payload_bits - 1 - self.dexp_bits - self.man_keep

    @property
    def dexp_max(self) -> int:
        return (1 << self.dexp_bits) - 1


def _to_rows(x: jax.Array) -> jax.Array:
    """Flatten to (rows, 128) lane groups, zero-padding the tail."""
    flat = x.reshape(-1)
    pad = (-flat.size) % GROUP
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, GROUP)


def _pack_words(x: jax.Array, f: PackFields, spec: containers.FloatSpec,
                n=None) -> Tuple[jax.Array, jax.Array]:
    """Shared pack body over the last (128-lane) axis.

    ``n`` (optional, traced ok) fuses Q(M, n) mantissa truncation into the
    same pass — the quantize+pack fusion of the hardware compressor.
    """
    sign, e, man = containers.split_fields(x)
    sign = sign.astype(jnp.int32)
    e = e.astype(jnp.int32)
    man = man.astype(jnp.int32)
    if n is not None:
        keep = containers._mantissa_keep_mask(n, spec).astype(jnp.int32)
        man = man & keep

    base = jnp.max(e, axis=-1, keepdims=True)  # max-exponent base: deltas >= 0
    dexp = base - e
    man_top = man >> (spec.man_bits - f.man_keep)
    flush = (e == 0) | (dexp > f.dexp_max)  # exact zeros + below-range values
    dexp = jnp.where(flush, f.dexp_max, jnp.minimum(dexp, f.dexp_max))
    man_top = jnp.where(flush, 0, man_top)
    sign = jnp.where(e == 0, 0, sign)

    word = ((sign << f.sign_shift) | (dexp << f.dexp_shift)
            | (man_top << f.man_shift))
    return word.astype(f.word_dtype), base


def _unpack_words(p: jax.Array, base: jax.Array, f: PackFields,
                  spec: containers.FloatSpec) -> jax.Array:
    p = p.astype(jnp.int32)
    sign = (p >> f.sign_shift) & 1
    dexp = (p >> f.dexp_shift) & f.dexp_max
    man_top = (p >> f.man_shift) & ((1 << f.man_keep) - 1)
    e = jnp.maximum(base.astype(jnp.int32) - dexp, 0)
    man = man_top << (spec.man_bits - f.man_keep)
    flush = (dexp == f.dexp_max) & (man_top == 0)
    e = jnp.where(flush, 0, e)
    man = jnp.where(flush, 0, man)
    sign = jnp.where(flush, 0, sign)
    return containers.combine_fields(
        sign.astype(spec.int_dtype), e.astype(spec.int_dtype),
        man.astype(spec.int_dtype), spec)


def sfp_pack(x: jax.Array, fields: PackFields, n=None):
    """Pack a float tensor into (payload (R, 128), bases (R, 1) uint8).

    Rows are consecutive 128-lane groups of the flattened tensor (Gecko
    columns); identical layout to kernels/sfp_pack.py. ``n`` optionally
    fuses mantissa truncation Q(M, n) into the same pass.
    """
    spec = containers.spec_for(x)
    payload, base = _pack_words(_to_rows(x), fields, spec, n)
    return payload, base.astype(jnp.uint8)


def sfp_pack_nd(x: jax.Array, fields: PackFields, n=None):
    """Rank-preserving pack: groups along the last dim (must be %128 == 0).

    Keeps the leading dims (batch, seq, ...) intact so GSPMD shardings
    propagate through the packed stash unchanged. payload has x's shape
    (uint8/uint16); bases has shape (*x.shape[:-1], D//128).
    """
    D = x.shape[-1]
    assert D % GROUP == 0, (x.shape,)
    spec = containers.spec_for(x)
    xg = x.reshape(*x.shape[:-1], D // GROUP, GROUP)
    payload, base = _pack_words(xg, fields, spec, n)
    return payload.reshape(x.shape), base[..., 0].astype(jnp.uint8)


def sfp_unpack_nd(payload: jax.Array, bases: jax.Array, dtype,
                  fields: PackFields) -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    D = payload.shape[-1]
    p = payload.reshape(*payload.shape[:-1], D // GROUP, GROUP)
    out = _unpack_words(p, bases.astype(jnp.int32)[..., None], fields, spec)
    return out.reshape(payload.shape)


def sfp_unpack(payload: jax.Array, bases: jax.Array, shape: tuple,
               dtype, fields: PackFields) -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    out = _unpack_words(payload, bases, fields, spec)
    n = 1
    for s in shape:
        n *= s
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# Dense bit-plane containers — oracles for kernels/bitplane_pack.py
#
# The variable payload-width realization: a payload word of P = 1 + E + K
# bits (any width 3..16) is stored as P byte-aligned bit planes per
# 128-lane group. Plane p is 16 contiguous bytes; byte i of plane p holds
# bit p of the payload words of lanes 8i..8i+7 (bit j <-> lane 8i+j). A
# value therefore occupies exactly P bits + the shared 8-bit group base —
# the learned bitlengths become real bytes instead of rounding up to an
# 8/16-bit lane.
# ---------------------------------------------------------------------------


def _reg_transpose8(rows):
    """SWAR 8x8 bit-matrix transpose (Hacker's Delight delta-swaps) with
    the 8 matrix rows in separate uint32 arrays.

    Each uint32 element carries 4 *independent* byte-matrices side by side
    (byte c of ``rows[p]`` is row p of matrix c); the odd/even bit masks
    keep every delta-swap byte-local, so one pass transposes 4 matrices at
    once. This is the whole plane <-> word conversion: 12 masked swaps per
    32 payload bytes instead of one gather-shift-accumulate per *bit*, so
    the work scales with plane bytes, not bits x lanes.
    """
    x = list(rows)
    M1 = jnp.uint32(0xAAAAAAAA)
    M2 = jnp.uint32(0xCCCCCCCC)
    M4 = jnp.uint32(0xF0F0F0F0)
    for i in (0, 2, 4, 6):
        a, b = x[i], x[i + 1]
        t = (a ^ (b << 1)) & M1
        x[i], x[i + 1] = a ^ t, b ^ (t >> 1)
    for i in (0, 1, 4, 5):
        a, b = x[i], x[i + 2]
        t = (a ^ (b << 2)) & M2
        x[i], x[i + 2] = a ^ t, b ^ (t >> 2)
    for i in (0, 1, 2, 3):
        a, b = x[i], x[i + 4]
        t = (a ^ (b << 4)) & M4
        x[i], x[i + 4] = a ^ t, b ^ (t >> 4)
    return x


def _u32_to_bytes(w: jax.Array) -> jax.Array:
    """(..., n) uint32 -> (..., 4n) uint8, little-endian."""
    out = jax.lax.bitcast_convert_type(w[..., None], jnp.uint8)
    return out.reshape(*w.shape[:-1], w.shape[-1] * 4)


def plane_pack_words(words: jax.Array, payload_bits: int) -> jax.Array:
    """Transpose payload words (..., 128) into bit planes (..., P*16) u8.

    Byte-granular: each block of <= 8 planes is one register-SWAR 8x8
    bit-matrix transpose over the group's 16 byte columns (bit j of plane
    byte i <-> bit i of word byte j for lanes 8i..8i+7).
    """
    P = payload_bits
    lead = words.shape[:-1]
    w = words.astype(jnp.int32) & ((1 << P) - 1)
    planes = []
    for lo in range(0, P, 8):
        byt = ((w >> lo) & 0xFF).astype(jnp.uint8)
        byt = byt.reshape(*lead, PLANE_BYTES, 8)
        rows = [jax.lax.bitcast_convert_type(
            byt[..., j].reshape(*lead, 4, 4), jnp.uint32)
            for j in range(8)]                     # row j = lane-j bytes
        x = _reg_transpose8(rows)                  # x[p] = plane lo+p bytes
        n = min(8, P - lo)
        pl = jnp.stack(x[:n], axis=-2)             # (..., n, 4) u32
        planes.append(_u32_to_bytes(pl).reshape(*lead, n * PLANE_BYTES))
    return (jnp.concatenate(planes, axis=-1) if len(planes) > 1
            else planes[0])


def plane_unpack_words(planes: jax.Array, payload_bits: int) -> jax.Array:
    """Invert plane_pack_words: (..., P*16) uint8 -> (..., 128) int32.

    Same SWAR transpose as the pack direction (the 8x8 bit transpose is an
    involution up to row/column naming): byte i of <= 8 stacked planes
    turns into the payload bytes of lanes 8i..8i+7 in 18 word ops — no
    per-bit gather, so expansion cost tracks the plane bytes actually read.
    """
    bs = _plane_unpack_bytes(planes, payload_bits)
    w = bs[0].astype(jnp.int32)
    if len(bs) > 1:
        w = w | (bs[1].astype(jnp.int32) << 8)
    return w


def _plane_unpack_bytes(planes: jax.Array, payload_bits: int):
    """SWAR plane expansion to payload *bytes*: (..., P*16) uint8 planes ->
    [low bytes] or [low, high bytes], each (..., 128) uint8 — the word is
    never widened here, so sub-byte consumers can stay in uint8. Missing
    planes of a partial block are zero registers, not padded memory."""
    P = payload_bits
    lead = planes.shape[:-1]
    u = jax.lax.bitcast_convert_type(
        planes.reshape(*lead, P, 4, 4), jnp.uint32)     # (..., P, 4)
    out_bytes = []
    for lo in range(0, P, 8):
        n = min(8, P - lo)
        zero = jnp.zeros((*lead, 4), jnp.uint32)
        rows = [u[..., lo + r, :] if r < n else zero for r in range(8)]
        y = _reg_transpose8(rows)                  # y[j] byte i = lane 8i+j
        out = jnp.stack([_u32_to_bytes(yj) for yj in y], axis=-1)
        out_bytes.append(out.reshape(*lead, GROUP))
    return out_bytes


def _unpack_bytes_u8(p: jax.Array, base: jax.Array, f: PackFields,
                     spec: containers.FloatSpec) -> jax.Array:
    """uint8-domain twin of ``_unpack_words`` for sub-byte payloads.

    When the payload fits one byte and the target float's exponent and
    mantissa each fit a byte (bf16: 8/7), every intermediate — fields,
    rebuilt exponent, shifted mantissa — stays uint8; nothing widens until
    ``combine_fields`` builds the 16-bit output word. On the single-core
    ref backend this shaves the int32 widen pass, the largest single cost
    of the dense decode path after the SWAR transpose itself.
    """
    sign = (p >> jnp.uint8(f.sign_shift)) & jnp.uint8(1)
    dexp = (p >> jnp.uint8(f.dexp_shift)) & jnp.uint8(f.dexp_max)
    man_top = p & jnp.uint8((1 << f.man_keep) - 1)
    if f.man_shift:
        man_top = (p >> jnp.uint8(f.man_shift)) & jnp.uint8(
            (1 << f.man_keep) - 1)
    # max-then-subtract clamps base - dexp at zero without a select; the
    # flush-to-zero test (dexp == max AND man == 0) is one masked compare
    # on the raw payload byte.
    e = jnp.maximum(base.astype(jnp.uint8), dexp) - dexp
    fl_mask = jnp.uint8((f.dexp_max << f.dexp_shift)
                        | (((1 << f.man_keep) - 1) << f.man_shift))
    keep = (p & fl_mask) != jnp.uint8(f.dexp_max << f.dexp_shift)
    w = ((sign.astype(spec.int_dtype) << spec.sign_shift)
         | (e.astype(spec.int_dtype) << spec.exp_shift)
         | (man_top.astype(spec.int_dtype)
            << (spec.man_bits - f.man_keep)))
    w = jnp.where(keep, w, jnp.zeros_like(w))
    return containers.bitcast_to_float(w, spec)


def unpack_planes(planes: jax.Array, bases: jax.Array, fields: PackFields,
                  spec: containers.FloatSpec) -> jax.Array:
    """Dense plane decode: (..., P*16) planes + broadcastable bases ->
    (..., 128) floats. One definition for the ref oracles, the Pallas
    unpack kernel and the flash-decode tiles; picks the uint8 fast path
    whenever the geometry allows (sub-byte payload, byte-sized float
    fields), falling back to the int32 word machine otherwise."""
    if (fields.payload_bits <= 8 and spec.exp_bits <= 8
            and spec.man_bits <= 8):
        (p,) = _plane_unpack_bytes(planes, fields.payload_bits)
        return _unpack_bytes_u8(p, bases, fields, spec)
    words = plane_unpack_words(planes, fields.payload_bits)
    return _unpack_words(words, bases.astype(jnp.int32), fields, spec)


def prefix_fields(fields: PackFields, prefix_planes: int) -> PackFields:
    """Geometry of the leading ``prefix_planes`` bits of a payload word.

    The payload word layout is most-significant-first (sign, delta-exp,
    mantissa top), so truncating a P-bit word to its top P' bits yields a
    valid narrower container with the same sign/dexp fields and
    ``man_keep - (P - P')`` mantissa bits: ``wide_word >> (P - P')`` *is*
    the narrow pack of the same values (flush encodings included — a wide
    flush word truncates to the narrow flush word). In the dense plane
    layout that truncation is free: planes are stored bit-index-ascending,
    so the leading P' bits live in the *last* P' planes of each group and
    a draft read touches a strict byte subset of the packed block.

    ``prefix_planes`` must keep at least one mantissa bit
    (``dexp_bits + 2 <= prefix_planes <= payload_bits``).
    """
    P = int(prefix_planes)
    if not fields.dexp_bits + 2 <= P <= fields.payload_bits:
        raise ValueError(
            f"prefix_planes={P} outside [{fields.dexp_bits + 2}, "
            f"{fields.payload_bits}] for {fields}")
    drop = fields.payload_bits - P
    return PackFields(man_keep=fields.man_keep - drop,
                      dexp_bits=fields.dexp_bits, payload_bits=P,
                      dense=fields.dense)


def prefix_plane_view(payload: jax.Array, fields: PackFields,
                      prefix_planes: int) -> jax.Array:
    """Slice a dense group payload (..., P*16) to its leading-plane prefix
    (..., P'*16): the last P' planes in storage order (planes are stored
    LSB-first, and the prefix keeps the *high* bits of the word)."""
    P, Pp = fields.payload_bits, int(prefix_planes)
    lead = payload.shape[:-1]
    pl = payload.reshape(*lead, P, PLANE_BYTES)
    return pl[..., P - Pp:, :].reshape(*lead, Pp * PLANE_BYTES)


def unpack_tile(payload: jax.Array, bases: jax.Array, fields: PackFields,
                spec: containers.FloatSpec, *, rows: int, KH: int,
                hd: int, prefix_planes: Optional[int] = None) -> jax.Array:
    """Shared per-tile decompressor for the packed decode kernels.

    ``payload`` (rows, nd_payload_cols(KH*hd)) — fixed-lane words or dense
    bit planes — and ``bases`` (rows, G) expand to (rows, KH, hd) float32.
    This is the body both flash-decode kernels run on each KV tile inside
    the online-softmax loop: only the ``rows`` (= block_l) slots being
    consumed are ever expanded, in VMEM, immediately before the dot —
    dense geometries go through the SWAR plane transpose first.

    ``prefix_planes`` selects the speculative *draft* read mode: only the
    leading P' bits of each payload word are expanded, decoded as the
    truncated geometry (``prefix_fields``). Dense geometries slice the
    plane bytes before the SWAR transpose, so the expansion work (and, on
    a DMA'd backend, the bytes moved) shrinks with P'; fixed-lane words
    shift in place (same bytes, same truncated semantics).
    """
    G = (KH * hd) // GROUP
    if prefix_planes is not None and prefix_planes != fields.payload_bits:
        nf = prefix_fields(fields, prefix_planes)
        if fields.dense:
            planes = prefix_plane_view(
                payload.reshape(rows, G, fields.group_payload_bytes),
                fields, prefix_planes)
            x = unpack_planes(planes, bases.reshape(rows, G, 1), nf, spec)
        else:
            drop = fields.payload_bits - nf.payload_bits
            p = payload.astype(jnp.int32).reshape(rows, G, GROUP) >> drop
            x = _unpack_words(p,
                              bases.astype(jnp.int32).reshape(rows, G, 1),
                              nf, spec)
        return x.reshape(rows, KH, hd).astype(jnp.float32)
    if fields.dense:
        x = unpack_planes(
            payload.reshape(rows, G, fields.group_payload_bytes),
            bases.reshape(rows, G, 1), fields, spec)
    else:
        p = payload.astype(jnp.int32).reshape(rows, G, GROUP)
        x = _unpack_words(p, bases.astype(jnp.int32).reshape(rows, G, 1),
                          fields, spec)
    return x.reshape(rows, KH, hd).astype(jnp.float32)


def bitplane_pack(x: jax.Array, fields: PackFields, n=None):
    """Dense pack: (planes (R, P*16) uint8, bases (R, 1) uint8).

    Same payload-word bit machine as ``sfp_pack`` (``n`` fuses Q(M, n)),
    then the words are transposed into byte-aligned bit planes. Rows are
    128-lane groups of the flattened tensor, zero-padded at the tail.
    """
    spec = containers.spec_for(x)
    words, base = _pack_words(_to_rows(x), fields, spec, n)
    return plane_pack_words(words, fields.payload_bits), base.astype(jnp.uint8)


def bitplane_unpack(planes: jax.Array, bases: jax.Array, shape: tuple,
                    dtype, fields: PackFields) -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    out = unpack_planes(planes, bases, fields, spec)
    n = 1
    for s in shape:
        n *= s
    return out.reshape(-1)[:n].reshape(shape)


def bitplane_pack_nd(x: jax.Array, fields: PackFields, n=None):
    """Rank-preserving dense pack (last dim % 128 == 0).

    payload has shape (*x.shape[:-1], (D//128) * P * 16) uint8 — each
    position's payload bytes are laid out (group, plane, 16), so one
    sequence row owns its own bytes and splices without read-modify-write;
    bases has shape (*x.shape[:-1], D//128) as in ``sfp_pack_nd``.
    """
    D = x.shape[-1]
    assert D % GROUP == 0, (x.shape,)
    spec = containers.spec_for(x)
    xg = x.reshape(*x.shape[:-1], D // GROUP, GROUP)
    words, base = _pack_words(xg, fields, spec, n)
    planes = plane_pack_words(words, fields.payload_bits)
    return (planes.reshape(*x.shape[:-1], fields.nd_payload_cols(D)),
            base[..., 0].astype(jnp.uint8))


def bitplane_unpack_nd(planes: jax.Array, bases: jax.Array, dtype,
                       fields: PackFields) -> jax.Array:
    spec = containers.spec_for(jnp.dtype(dtype))
    G = bases.shape[-1]
    p = planes.reshape(*planes.shape[:-1], G, fields.group_payload_bytes)
    out = unpack_planes(p, bases[..., None], fields, spec)
    return out.reshape(*planes.shape[:-1], G * GROUP)


# ---------------------------------------------------------------------------
# Gecko delta-mode exponent compression — oracle for kernels/gecko_pack.py
#
# Byte-aligned bit-plane realization of core/gecko.py's 8x8 delta scheme:
# each 64-exponent group is an 8x8 matrix; row 0 holds the 8 column bases;
# rows 1..7 store sign+magnitude deltas against the bases as *bit planes* —
# one byte per plane holds that bit for all 8 columns, so a row whose max
# |delta| needs w bits occupies exactly (w + 1) bytes (sign plane + w
# magnitude planes). The dense (G, 63)-byte form below is the jit-friendly
# device representation; repro.codecs.gecko compacts it into the actual
# variable-length byte stream (and proves bit-exactness vs core/gecko.py).
# ---------------------------------------------------------------------------

GECKO_GROUP = 64   # exponents per group (8 rows x 8 cols)
GECKO_ROWS = 7     # delta rows (row 0 is the bases)
GECKO_PLANES = 9   # sign plane + 8 magnitude bit planes
GECKO_PLANE_BYTES = GECKO_ROWS * GECKO_PLANES  # 63 dense bytes per group


def gecko_encode_block(g: jax.Array):
    """Shared encode body: (B, 64) int32 groups -> int32 (bases (B, 8),
    widths (B, 7), planes (B, 63)). Called by both the jnp oracle below
    and the Pallas kernel in kernels/gecko_pack.py, so the plane layout
    has exactly one definition."""
    g = g.reshape(-1, 8, 8)
    bases = g[:, 0, :]
    d = g[:, 1:, :] - bases[:, None, :]          # (B, 7, 8)
    sign = (d < 0).astype(jnp.int32)
    mag = jnp.abs(d)

    width = jnp.zeros(mag.shape[:2], jnp.int32)  # (B, 7)
    row_max = jnp.max(mag, axis=2)
    for b in range(8, -1, -1):                   # 255 needs 8 bits
        width = jnp.where((row_max >> b) > 0, jnp.maximum(width, b + 1),
                          width)

    col = jnp.arange(8, dtype=jnp.int32)
    plane_list = [jnp.sum(sign << col, axis=2)]  # sign plane
    for b in range(8):
        plane_list.append(jnp.sum(((mag >> b) & 1) << col, axis=2))
    planes = jnp.stack(plane_list, axis=2)       # (B, 7, 9)
    return bases, width, planes.reshape(-1, GECKO_PLANE_BYTES)


def gecko_decode_block(bases: jax.Array, planes: jax.Array) -> jax.Array:
    """Shared decode body (int32 in/out): invert gecko_encode_block."""
    pl = planes.reshape(-1, GECKO_ROWS, GECKO_PLANES)
    col = jnp.arange(8, dtype=jnp.int32)
    sign = (pl[:, :, 0:1] >> col[None, None, :]) & 1        # (B, 7, 8)
    mag = jnp.zeros_like(sign)
    for b in range(8):
        mag = mag | (((pl[:, :, b + 1: b + 2] >> col[None, None, :]) & 1)
                     << b)
    d = jnp.where(sign == 1, -mag, mag)
    b0 = bases[:, None, :]
    full = jnp.concatenate([b0, b0 + d], axis=1)            # (B, 8, 8)
    return full.reshape(-1, GECKO_GROUP)


def gecko_plane_encode(groups: jax.Array):
    """Encode (G, 64) uint8 exponent groups into dense plane form.

    Returns (bases (G, 8) uint8, widths (G, 7) uint8, planes (G, 63) uint8).
    ``widths[g, r]`` is the magnitude bitwidth of delta row r+1 — identical
    to core/gecko.py's ``row_widths``; planes above a row's width are zero.
    """
    bases, width, planes = gecko_encode_block(groups.astype(jnp.int32))
    return (bases.astype(jnp.uint8), width.astype(jnp.uint8),
            planes.astype(jnp.uint8))


def gecko_plane_decode(bases: jax.Array, planes: jax.Array) -> jax.Array:
    """Invert gecko_plane_encode: (G, 8), (G, 63) -> (G, 64) uint8."""
    out = gecko_decode_block(bases.astype(jnp.int32),
                             planes.astype(jnp.int32))
    return out.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Decode over the packed KV cache — oracle for kernels/packed_flash_decode.py
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def decode_kv_mask(pos, L: int, window: Optional[int] = None, slots=None):
    """Validity of each KV-cache slot for a decode query at absolute
    position ``pos``.

    Global caches (``window=None``) store position p at slot p. Local
    caches are L-slot ring buffers (L <= window): slot s holds the latest
    position p <= pos with p === s (mod L), valid while inside the window.
    ``slots`` defaults to arange(L); kernels pass their block-relative
    slot indices (padded slots >= L are masked off). ``pos`` may carry
    leading batch dims (broadcast against ``slots``).
    """
    if slots is None:
        slots = jnp.arange(L)
    if window is None:
        return (slots <= pos) & (slots < L)
    k_pos = pos - jnp.mod(pos - slots, L)
    return ((k_pos >= 0) & (k_pos <= pos) & (k_pos > pos - window)
            & (slots < L))


def packed_flash_decode(q: jax.Array, k_payload: jax.Array,
                        k_bases: jax.Array, v_payload: jax.Array,
                        v_bases: jax.Array, pos, fields: PackFields, *,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_l: Optional[int] = None,
                        prefix_planes: Optional[int] = None) -> jax.Array:
    """Unpack-then-attend decode oracle for kernels/packed_flash_decode.py.

    Decompresses the whole packed cache (same bit logic as the kernel:
    ``_unpack_words``) and attends the single query token with the same
    online-softmax block recurrence over ``block_l``-slot KV blocks, so
    the Pallas kernel validates bit-for-bit in interpret mode.

    q: (B, 1, H, hd); payload (B, L, fields.nd_payload_cols(KH*hd)) and
    bases (B, L, KH*hd // 128) — the rank-preserving layout of
    ``sfp_pack_nd`` (fixed-lane words) or ``bitplane_pack_nd`` (dense bit
    planes; the kernel expands the planes inline). GQA is grouped: q head
    h reads kv head h // (H // KH). ``pos`` is scalar (whole batch at one
    position) or (B,) — one decode position per batch row (the serving
    engine's continuous-batching slots). ``prefix_planes`` is the
    speculative draft read mode: expand only the leading P' payload bits
    (see ``prefix_fields``) of the same packed cache.
    """
    B, _, H, hd = q.shape
    L, G = k_bases.shape[1], k_bases.shape[2]
    D = G * GROUP
    KH = D // hd
    rep = H // KH
    spec = containers.spec_for(jnp.dtype(q.dtype))
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    # Kernel-identical blocking: shrink to a divisor of L (the kernel never
    # pads the cache — that would copy the packed arrays every step).
    bl = L if block_l is None else min(block_l, L)
    while L % bl:
        bl -= 1

    def unp(payload, bases):
        # Same tile decompressor the kernels run (rows = every slot here:
        # the oracle expands the whole cache up front).
        x = unpack_tile(payload.reshape(B * L, -1), bases.reshape(B * L, G),
                        fields, spec, rows=B * L, KH=KH, hd=hd,
                        prefix_planes=prefix_planes)
        return x.reshape(B, L, KH, hd)

    k = unp(k_payload, k_bases)
    v = unp(v_payload, v_bases)
    qf = q.reshape(B, KH, rep, hd).astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)

    # Per-batch block loop mirroring the kernel grid exactly (one grid row
    # per batch element) so accumulation order — and thus every float bit —
    # matches the Pallas kernel in interpret mode.
    outs = []
    for b in range(B):
        m = jnp.full((KH, rep, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((KH, rep, 1), jnp.float32)
        acc = jnp.zeros((KH, rep, hd), jnp.float32)
        for ki in range(L // bl):
            k_c = k[b, ki * bl:(ki + 1) * bl]
            v_c = v[b, ki * bl:(ki + 1) * bl]
            s = jnp.einsum("hgd,lhd->hgl", qf[b], k_c) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            valid = decode_kv_mask(pos[b], L, window,
                                   slots=ki * bl + jnp.arange(bl))
            s = jnp.where(valid[None, None, :], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("hgl,lhd->hgd", p, v_c)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30))
    o = jnp.stack(outs, axis=0)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def paged_gather(part: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather pool blocks into per-row contiguous sequences.

    ``part`` is one packed pool part (P_blocks, block_l, ...) — payload or
    bases; ``tables`` (B, nb) holds physical block ids per logical block
    (invalid logical blocks point at the reserved trash block and are
    masked by position downstream). Returns (B, nb * block_l, ...).
    """
    g = part[tables]                      # (B, nb, block_l, ...)
    return g.reshape(g.shape[0], -1, *g.shape[3:])


def paged_flash_decode(q: jax.Array, k_payload: jax.Array,
                       k_bases: jax.Array, v_payload: jax.Array,
                       v_bases: jax.Array, tables: jax.Array, pos,
                       fields: PackFields, *,
                       softcap: Optional[float] = None,
                       prefix_planes: Optional[int] = None) -> jax.Array:
    """Gather-unpack-attend oracle for the paged flash-decode kernel.

    Pool parts are (P_blocks, block_l, D) / (P_blocks, block_l, D // 128)
    in the ``sfp_pack_nd`` layout; ``tables`` (B, nb) maps each row's
    logical KV blocks to physical pool blocks; ``pos`` is (B,) or scalar.
    Gathers each row's blocks into a contiguous packed cache, then runs
    the exact block recurrence of ``packed_flash_decode`` (block_l = the
    pool block), so the Pallas paged kernel validates bit-for-bit in
    interpret mode. Paged caches are global-attention only (local ring
    buffers are window-bounded and stay per-slot contiguous).
    """
    block_l = k_payload.shape[1]
    return packed_flash_decode(
        q, paged_gather(k_payload, tables), paged_gather(k_bases, tables),
        paged_gather(v_payload, tables), paged_gather(v_bases, tables),
        pos, fields, window=None, softcap=softcap, block_l=block_l,
        prefix_planes=prefix_planes)


# ---------------------------------------------------------------------------
# Attention oracle — for kernels/flash_attention.py
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,           # (B, Sq, H, D)
    k: jax.Array,           # (B, Sk, KH, D)
    v: jax.Array,           # (B, Sk, KH, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,      # sliding window (local attention)
    softcap: Optional[float] = None,   # gemma2 attn-logit softcap
    prefix_len: int = 0,               # prefix-LM: first P kv fully visible
    q_offset: int = 0,                 # absolute position of q[0] (decode)
) -> jax.Array:
    """Reference multi-head GQA attention, O(Sq*Sk). fp32 accumulation."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    rep = H // KH
    kq = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vq = jnp.repeat(v, rep, axis=2) if rep > 1 else v

    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask = k_pos <= q_pos
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    if prefix_len > 0:
        mask = mask | (k_pos < prefix_len)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
