"""Deterministic synthetic corpora.

No datasets ship in this environment (DESIGN.md D1), so training runs use
structured synthetic streams with real learnable signal — a mixture of
n-gram processes — rather than uniform noise, so loss curves actually fall
and QM/BitChop see a realistic (noisy, improving) loss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2          # markov order of the generating process
    n_modes: int = 8        # distinct "documents" styles
    temperature: float = 0.7


class MarkovCorpus:
    """Fixed random Markov chain over the vocab; same seed -> same stream."""

    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v = min(cfg.vocab, 512)  # transition table stays small
        self.v = v
        # per-mode transition logits, sparse-ish rows
        self.trans = rng.gumbel(size=(cfg.n_modes, v, 16)).astype(np.float32)
        self.nxt = rng.randint(0, v, size=(cfg.n_modes, v, 16))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(cfg.seed * 100003 + step)
        B, S = cfg.global_batch, cfg.seq_len
        modes = rng.randint(0, cfg.n_modes, size=B)
        toks = np.zeros((B, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.v, size=B)
        g = rng.gumbel(size=(B, S, 16)).astype(np.float32)
        for t in range(S):
            logits = self.trans[modes, toks[:, t]] / cfg.temperature
            choice = np.argmax(logits + g[:, t], axis=-1)
            toks[:, t + 1] = self.nxt[modes, toks[:, t], choice]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batches(cfg: SyntheticConfig, start_step: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    corpus = MarkovCorpus(cfg)
    step = start_step
    while True:
        yield corpus.batch(step)
        step += 1
