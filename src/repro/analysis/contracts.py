"""Layer 2: jaxpr/HLO contract analyzers over the real entry points.

These checks trace the actual programs — the fused quantize+pack, the
train step, and both paged decode entry points — and verify properties
the AST layer cannot see:

  precision-leak   no float-widening ``convert_element_type`` and no
                   float64 aval anywhere between quantize and pack (the
                   fused path must stay in the integer bit machine), for
                   both the ref and interpret backends.
  buffer-geometry  a codec's materialized packed bytes equal its declared
                   ``packed_bits`` footprint, and the paged pool's block
                   spec equals the admission accounting's
                   ``paged_block_bytes`` — stash/KV buffers never exceed
                   the declared payload geometry.
  donation-audit   every ``donate_argnums`` buffer of every serving/train
                   entry point is actually aliased to an output
                   (``tf.aliasing_output`` in the lowering) — a dropped
                   donation silently doubles the cache/optimizer HBM.
  recompile-guard  compile caches stay at one entry across runtime-varying
                   but shape-stable inputs (decode steps at different
                   positions, repeated bursts at the same K, repeated
                   generate() calls at the same budget, repeated
                   self-speculative rounds at the same (K, draft depth)).

The jaxpr walks reuse ``roofline.jaxpr_cost.iter_eqns`` — one traversal
definition for the cost model and the contracts.

Everything runs on a reduced config on CPU; the geometry set is
``QUICK_GEOMETRIES`` for the fast tier and ``full_geometries()`` for the
nightly sweep.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs, configs, policies
from repro.analysis.findings import Finding
from repro.configs.base import reduced
from repro.kernels import ops
from repro.roofline.jaxpr_cost import iter_eqns

QUICK_GEOMETRIES = ("sfp8", "sfp-m2e4", "sfp-m1e2")

_CONTRACT_PATH = "src/repro/analysis/contracts.py"


def full_geometries() -> Tuple[str, ...]:
    """Every registered dense geometry (payload width <= 16) plus the
    fixed-lane containers — the nightly sweep set."""
    names = ["sfp8", "sfp16"]
    for m in (1, 2, 3, 4, 5, 7):
        for e in (2, 3, 4, 5):
            if 1 + e + m <= 16:
                names.append(codecs.dense_name(m, e))
    return tuple(n for n in names
                 if _resolves(n))


def _resolves(name: str) -> bool:
    try:
        codecs.get(name)
        return True
    except KeyError:
        return False


def _finding(rule: str, scope: str, message: str) -> Finding:
    return Finding(rule=rule, path=_CONTRACT_PATH, line=0, scope=scope,
                   message=message)


# ---------------------------------------------------------------------------
# precision-leak
# ---------------------------------------------------------------------------


def _float_widenings(jaxpr) -> List[str]:
    """Names of float->wider-float converts + any float64 aval."""
    bad = []
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "dtype", None) is not None:
                if aval.dtype == jnp.float64:
                    bad.append("float64 aval")
        if eqn.primitive.name != "convert_element_type":
            continue
        src = eqn.invars[0].aval.dtype
        dst = eqn.outvars[0].aval.dtype
        if (jnp.issubdtype(src, jnp.floating)
                and jnp.issubdtype(dst, jnp.floating)
                and jnp.dtype(dst).itemsize > jnp.dtype(src).itemsize):
            bad.append(f"{src}->{dst}")
    return bad


def check_precision_leak(geometries: Sequence[str]) -> List[Finding]:
    """The fused quantize+pack must not widen floats on its way to the
    payload: any up-conversion doubles the stash HBM write the container
    exists to shrink."""
    out: List[Finding] = []
    x = jax.ShapeDtypeStruct((8, 256), jnp.bfloat16)
    n = jax.ShapeDtypeStruct((), jnp.int32)
    for name in geometries:
        codec = codecs.get(name)
        for backend in ("ref", "interpret"):
            ops.force_backend(backend)
            try:
                closed = jax.make_jaxpr(
                    lambda t, b: codec.pack(t, bits=b))(x, n)
            finally:
                ops.force_backend(None)
            bad = _float_widenings(closed.jaxpr)
            if bad:
                out.append(_finding(
                    "precision-leak", f"pack:{name}:{backend}",
                    f"quantize+pack of {name!r} ({backend} backend) widens "
                    f"floats: {sorted(set(bad))}"))
    return out


# ---------------------------------------------------------------------------
# buffer-geometry
# ---------------------------------------------------------------------------


def _spec_bits(tree) -> int:
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize * 8
               for l in jax.tree_util.tree_leaves(tree))


def check_buffer_geometry(geometries: Sequence[str],
                          cfg=None) -> List[Finding]:
    """Materialized packed buffers must equal the declared footprint —
    ``packed_bits`` is what the paper's results are priced in, so a spec
    that allocates more would silently misreport compression."""
    out: List[Finding] = []
    shape = (4, 256)
    for name in geometries:
        codec = codecs.get(name)
        spec = codec.packed_spec(shape, jnp.float32)
        got = _spec_bits(spec.data)
        want = float(codec.packed_bits(jnp.zeros(shape, jnp.float32)))
        if got != want:
            out.append(_finding(
                "buffer-geometry", f"packed_spec:{name}",
                f"{name!r}: packed_spec materializes {got} bits but "
                f"packed_bits declares {want}"))
    if cfg is not None:
        from repro.serve import kvcache
        for name in geometries:
            spec = kvcache.paged_block_spec(cfg, 1, ops.DECODE_BLOCK_L, name)
            got = _spec_bits(spec) // 8
            want = kvcache.paged_block_bytes(cfg, ops.DECODE_BLOCK_L, name)
            if got != want:
                out.append(_finding(
                    "buffer-geometry", f"paged_block:{name}",
                    f"{name!r}: pool block spec is {got} B but admission "
                    f"accounting prices {want} B"))
    return out


# ---------------------------------------------------------------------------
# donation-audit
# ---------------------------------------------------------------------------


def _count_aliased(lowered) -> int:
    return lowered.as_text().count("tf.aliasing_output")


def _audit(scope: str, lowered, donated_tree) -> List[Finding]:
    want = len(jax.tree_util.tree_leaves(donated_tree))
    got = _count_aliased(lowered)
    if got < want:
        return [_finding(
            "donation-audit", scope,
            f"{scope}: {want} donated buffers but only {got} aliased to "
            "outputs — the un-aliased ones are silently copied "
            "(double HBM)")]
    return []


def _tiny_serving(container: str):
    """One reduced all-global model + engine, shared by the donation and
    recompile checks."""
    from repro.models.model import DecoderModel
    from repro.serve.engine import PagedEngine

    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    model = DecoderModel(cfg, kv_container=container)
    params = model.init(jax.random.PRNGKey(0))
    engine = PagedEngine(model, params, max_slots=2, max_len=128)
    return cfg, model, params, engine


def check_donation(container: str = "sfp8",
                   include_train: bool = True) -> List[Finding]:
    from repro.serve.engine import make_decode_loop

    out: List[Finding] = []
    cfg, model, params, engine = _tiny_serving(container)
    S = engine.max_slots

    tables = jnp.zeros((S, engine.nmax), jnp.int32)
    toks = jnp.zeros((S, 1), jnp.int32)
    pos = jnp.zeros((S,), jnp.int32)

    low = engine._step.lower(params, engine.mem, tables, toks, pos)
    out += _audit(f"PagedEngine._step[{container}]", low, engine.mem)

    burst = engine._make_burst(2)
    low = burst.lower(params, engine.mem, tables, toks, pos)
    out += _audit(f"PagedEngine.decode_burst[K=2,{container}]", low,
                  engine.mem)

    # Self-speculative round: the draft+verify executable snapshots and
    # rewinds per-slot state internally, so the *pool* donation is what
    # keeps the round at zero extra HBM.
    spec = engine._make_spec(2, engine.default_draft_planes())
    low = spec.lower(params, engine.mem, tables, toks, pos)
    out += _audit(f"PagedEngine.speculate[K=2,{container}]", low,
                  engine.mem)

    # Contiguous decode loop: cache donated across the scan.
    cache = jax.eval_shape(lambda: model.init_cache(1, engine.max_len))
    loop = make_decode_loop(model, 4)
    low = loop.lower(params, cache, jnp.zeros((1, 1), jnp.int32),
                     jax.ShapeDtypeStruct((), jnp.int32))
    out += _audit(f"decode_loop[{container}]", low, cache)

    if include_train:
        out += _check_train_donation()
    return out


def _check_train_donation() -> List[Finding]:
    from repro.models.model import DecoderModel
    from repro.optim import adamw
    from repro.optim.schedule import Schedule
    from repro.train import step as step_mod

    cfg = reduced(configs.get("mistral-large-123b"))
    model = DecoderModel(cfg, policies.get("qm"))
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=1e-3),
        schedule=Schedule(total_steps=8, warmup_steps=2, base_lr=1e-3))
    step = jax.jit(step_mod.make_train_step(model, tc), donate_argnums=(0,))
    state = jax.eval_shape(
        lambda: step_mod.init_state(model, jax.random.PRNGKey(0), tc))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    low = step.lower(state, batch)
    return _audit("train_step[qm]", low, state)


# ---------------------------------------------------------------------------
# recompile-guard
# ---------------------------------------------------------------------------


def _cache_size(jitted) -> Optional[int]:
    fn = getattr(jitted, "_cache_size", None)
    return fn() if callable(fn) else None


def check_recompile(container: str = "sfp8") -> List[Finding]:
    """Shape-stable inputs must never re-trace: the paged step, the
    K-burst, and generate()'s memoized executables each get exercised
    twice with different runtime values and must hold one cache entry."""
    from repro.serve.engine import _CACHE_ATTR, generate

    out: List[Finding] = []
    cfg, model, params, engine = _tiny_serving(container)
    S = engine.max_slots

    toks = np.zeros(S, np.int32)
    engine.decode(toks, np.zeros(S, np.int32))
    engine.decode(toks + 3, np.ones(S, np.int32))
    n = _cache_size(engine._step)
    if n is not None and n != 1:
        out.append(_finding(
            "recompile-guard", f"PagedEngine._step[{container}]",
            f"decode step recompiled across shape-stable calls "
            f"(cache size {n})"))

    engine.decode_burst(toks, np.zeros(S, np.int32), 2)
    engine.decode_burst(toks + 1, np.full(S, 2, np.int32), 2)
    if set(engine._bursts) != {2}:
        out.append(_finding(
            "recompile-guard", "PagedEngine.decode_burst",
            f"burst memo holds {sorted(engine._bursts)} after two K=2 "
            "bursts (want exactly [2])"))
    else:
        n = _cache_size(engine._bursts[2])
        if n is not None and n != 1:
            out.append(_finding(
                "recompile-guard", "PagedEngine.decode_burst",
                f"K=2 burst recompiled across calls (cache size {n})"))

    dp = engine.default_draft_planes()
    engine.speculate(toks, np.full(S, 4, np.int32), 2)
    engine.speculate(toks + 1, np.full(S, 6, np.int32), 2)
    if set(engine._specs) != {(2, dp)}:
        out.append(_finding(
            "recompile-guard", "PagedEngine.speculate",
            f"spec memo holds {sorted(engine._specs)} after two K=2 "
            f"rounds at the default draft depth (want exactly "
            f"[(2, {dp})])"))
    else:
        n = _cache_size(engine._specs[(2, dp)])
        if n is not None and n != 1:
            out.append(_finding(
                "recompile-guard", "PagedEngine.speculate",
                f"K=2 draft+verify round recompiled across shape-stable "
                f"calls (cache size {n})"))

    prompt = np.zeros((1, 8), np.int32)
    generate(model, params, jnp.asarray(prompt), 4, max_len=engine.max_len)
    generate(model, params, jnp.asarray(prompt) + 1, 4,
             max_len=engine.max_len)
    memo = model.__dict__.get(_CACHE_ATTR, {})
    keys = {k[0] for k in memo}
    if keys != {"prefill", "decode_loop"}:
        out.append(_finding(
            "recompile-guard", "generate",
            f"generate() memo holds {sorted(memo)} after two same-shape "
            "calls (want one prefill + one decode_loop)"))
    for key, fn in memo.items():
        n = _cache_size(fn)
        if n is not None and n != 1:
            out.append(_finding(
                "recompile-guard", f"generate:{key[0]}",
                f"{key} executable re-traced across same-shape calls "
                f"(cache size {n})"))

    # Instrumentation must be trace-invisible: a fully observed scheduler
    # run (metrics + span tracer + precision timeline live) over a warm
    # engine must add zero executables beyond what the bare run compiled.
    from repro import obs as obs_mod
    from repro.serve.scheduler import Request, Scheduler

    rng = np.random.RandomState(0)
    reqs = [Request(uid=100 + i,
                    prompt=rng.randint(0, cfg.vocab, size=6).astype(np.int32),
                    max_new=4) for i in range(3)]
    before = _cache_size(engine._step)
    full_obs = obs_mod.Obs(trace=True, timeline=True)
    Scheduler(engine, obs=full_obs).run(reqs, burst=2)
    n = _cache_size(engine._step)
    if n is not None and n != before:
        out.append(_finding(
            "recompile-guard", "Scheduler[obs]",
            f"instrumented scheduler re-traced the decode step "
            f"(cache size {before} -> {n}); obs calls must stay on the "
            "host side of the step boundary"))
    for k, fn in engine._bursts.items():
        n = _cache_size(fn)
        if n is not None and n != 1:
            out.append(_finding(
                "recompile-guard", "Scheduler[obs]",
                f"instrumented scheduler re-traced the K={k} burst "
                f"(cache size {n})"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_contracts(full: bool = False) -> List[Finding]:
    geoms = full_geometries() if full else QUICK_GEOMETRIES
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    out: List[Finding] = []
    out += check_precision_leak(geoms)
    out += check_buffer_geometry(geoms, cfg)
    out += check_donation(include_train=True)
    out += check_recompile()
    return out
