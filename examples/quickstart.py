"""Quickstart: Schrödinger's FP containers on any tensor, in 40 lines.

  PYTHONPATH=src python examples/quickstart.py

Shows the core mechanisms on real tensors: Quantum Mantissa / Quantum
Exponent quantization (learnable bitlengths via the precision-policy
registry), Gecko lossless exponent compression, and the realized SFP8
container pack/unpack.
"""
import jax
import jax.numpy as jnp

from repro import codecs, policies
from repro.core import (containers, footprint, gecko,
                        quantum_exponent as qe, quantum_mantissa as qm)

key = jax.random.PRNGKey(0)
x = (jax.random.normal(key, (4, 1024)) * 2.0).astype(jnp.bfloat16)

# 1) Quantum Mantissa: stochastic fractional-bitlength quantization (eq 5+6)
n = jnp.asarray(2.5, jnp.float32)          # learnable parameter
q = qm.qm_quantize(x, n, jax.random.PRNGKey(1))
err = jnp.max(jnp.abs((q - x).astype(jnp.float32)))
print(f"QM @ n={float(n)} bits: max abs err {float(err):.4f}")

# ...and its learning signal: d(loss)/dn pushes n where the data needs it
dn = jax.grad(lambda n: jnp.sum(
    qm.qm_quantize(x, n, jax.random.PRNGKey(1)) ** 2).astype(jnp.float32))(n)
print(f"dL/dn = {float(dn):+.3f}  (gradient descent finds the bitlength)")

# 1b) Quantum Exponent: the same trick on the exponent field — values
# outside the e-bit range flush to zero / saturate, and dL/de is exact
e = jnp.asarray(3.5, jnp.float32)
qx = qe.qe_quantize(x.astype(jnp.float32), e, jax.random.PRNGKey(2))
de = jax.grad(lambda e: jnp.sum(
    qe.qe_quantize(x.astype(jnp.float32), e, jax.random.PRNGKey(2)) ** 2))(e)
kept = float(jnp.mean((qx != 0) | (x.astype(jnp.float32) == 0)))
print(f"QE @ e={float(e)} bits: {kept:.1%} of values in range, "
      f"dL/de = {float(de):+.3f}")

# 1c) ...both at once, through the precision-policy registry (how the
# trainer consumes them: one PrecisionDecision{man_bits, exp_bits})
pol = policies.get("qm+qe", container="bit_exact")
dims = policies.ScopeDims.for_dtype(jnp.bfloat16, n_periods=1)
st = pol.init_state(dims)
sl = jax.tree.map(lambda a: a[0], pol.scan_slices(
    pol.forward_view(st.learn, pol.control_view(st.ctrl, dims), dims), dims))
d = pol.act_decision(sl, jax.random.PRNGKey(3), dims)
print(f"policy {pol.name!r} decides man={int(d.man_bits)}b "
      f"exp={int(d.exp_bits)}b (registered: {'/'.join(policies.names())})")

# 2) Gecko: lossless exponent compression
exp = containers.exponent_field(x)
ratio = float(gecko.compression_ratio(exp.reshape(-1), "delta"))
print(f"Gecko exponent ratio: {ratio:.3f} (1.0 = uncompressed 8b)")

# 3) Realized SFP8 container (sign + 4b delta-exp + 3b mantissa + shared
#    base), via the codec registry — fused quantize+pack in one pass
sfp8 = codecs.get("sfp8")
packed = sfp8.pack(x, bits=3)
back = sfp8.unpack(packed)
exact = jnp.all(back == containers.truncate_mantissa(x, 3))
bytes_packed = int(sfp8.packed_bits(x)) // 8
print(f"SFP8: {x.size * 2} B -> {bytes_packed} B "
      f"({bytes_packed / (x.size * 2):.2%}), bit-exact={bool(exact)}")

# 3b) gecko8: the paper's delta-mode exponent stream, actually materialized
g8 = codecs.get("gecko8")
lossless = jnp.all(g8.unpack(g8.pack(x)) == x)
print(f"gecko8: {g8.packed_bits(x) / x.size:.2f} bits/value, "
      f"bf16-lossless={bool(lossless)}")

# 4) Bit-exact footprint accounting (what the paper's Table I counts)
rep = footprint.sfp_footprint(x, mantissa_bits=2, signless=False)
print(f"SFP entitlement @2b mantissa: {rep.vs_fp32():.1%} of FP32, "
      f"{rep.vs_bf16():.1%} of BF16")
