"""Pallas TPU kernels: Gecko delta-mode exponent pack/unpack (paper §IV-C).

Materializes the compressed exponent stream that core/gecko.py only
*accounts* for: each 64-exponent group (an 8x8 matrix) becomes

  bases  (8 bytes)  — row 0, the per-column base exponents;
  widths (7 values) — magnitude bitwidth of each delta row (== the
                      reference encoder's ``row_widths``);
  planes (63 bytes) — rows 1..7 as sign+magnitude *bit planes*: byte
                      ``[row, p]`` holds bit p of all 8 columns (p = 0 is
                      the sign plane, p = 1..8 the magnitude planes), so a
                      row of width w occupies exactly (w + 1) meaningful
                      bytes and planes above w are zero.

The kernels produce the dense fixed-shape form (static shapes keep them
jit/scan-compatible); ``repro.codecs.gecko`` compacts it into the actual
variable-length byte-aligned stream and proves bit-exactness against the
core/gecko.py encoder. Validated against kernels/ref.py's
gecko_plane_encode/decode oracles in interpret mode; on TPU the same
kernels lower natively (the (Bg, 64) -> (Bg, 8, 8) view is a minor-dim
relayout Mosaic handles).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as kref

DEFAULT_BLOCK_GROUPS = 128


def _gecko_pack_kernel(e_ref, base_ref, width_ref, plane_ref):
    # One shared body with the jnp oracle (ref.gecko_encode_block): the
    # kernel owns only the VMEM load/store plumbing.
    bases, width, planes = kref.gecko_encode_block(
        e_ref[...].astype(jnp.int32))
    base_ref[...] = bases.astype(jnp.uint8)
    width_ref[...] = width.astype(jnp.uint8)
    plane_ref[...] = planes.astype(jnp.uint8)


def _gecko_unpack_kernel(base_ref, plane_ref, o_ref):
    out = kref.gecko_decode_block(base_ref[...].astype(jnp.int32),
                                  plane_ref[...].astype(jnp.int32))
    o_ref[...] = out.astype(jnp.uint8)


def _group_grid(x: jax.Array, block_groups: int):
    n = x.shape[0]
    block_groups = min(block_groups, n)
    pad = (-n) % block_groups
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)), mode="edge")
    return x, n, pad, block_groups


@functools.partial(jax.jit, static_argnames=("block_groups", "interpret"))
def gecko_pack(groups: jax.Array, *,
               block_groups: int = DEFAULT_BLOCK_GROUPS,
               interpret: Optional[bool] = None):
    """Encode (G, 64) uint8 exponent groups -> (bases, widths, planes)."""
    interpret = kref.default_interpret(interpret)
    groups, n, pad, block_groups = _group_grid(groups, block_groups)
    grid = (groups.shape[0] // block_groups,)

    bases, widths, planes = pl.pallas_call(
        _gecko_pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_groups, kref.GECKO_GROUP),
                               lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_groups, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_groups, 7), lambda i: (i, 0)),
            pl.BlockSpec((block_groups, kref.GECKO_PLANE_BYTES),
                         lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((groups.shape[0], 8), jnp.uint8),
            jax.ShapeDtypeStruct((groups.shape[0], 7), jnp.uint8),
            jax.ShapeDtypeStruct((groups.shape[0], kref.GECKO_PLANE_BYTES),
                                 jnp.uint8),
        ],
        interpret=interpret,
    )(groups)
    if pad:
        bases, widths, planes = bases[:n], widths[:n], planes[:n]
    return bases, widths, planes


@functools.partial(jax.jit, static_argnames=("block_groups", "interpret"))
def gecko_unpack(bases: jax.Array, planes: jax.Array, *,
                 block_groups: int = DEFAULT_BLOCK_GROUPS,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Decode (bases (G, 8), planes (G, 63)) -> (G, 64) uint8 exponents."""
    interpret = kref.default_interpret(interpret)
    n = bases.shape[0]
    block_groups = min(block_groups, n)
    pad = (-n) % block_groups
    if pad:
        bases = jnp.pad(bases, ((0, pad), (0, 0)))
        planes = jnp.pad(planes, ((0, pad), (0, 0)))
    grid = (bases.shape[0] // block_groups,)

    out = pl.pallas_call(
        _gecko_unpack_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_groups, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_groups, kref.GECKO_PLANE_BYTES),
                         lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_groups, kref.GECKO_GROUP),
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bases.shape[0], kref.GECKO_GROUP),
                                       jnp.uint8),
        interpret=interpret,
    )(bases, planes)
    return out[:n] if pad else out
