"""Train-step builder: microbatched grad accumulation + SFP integration.

One jitted function per (arch, shape, policy):

  * microbatch scan — grads accumulate across num_microbatches slices of the
    global batch; only the final accumulation feeds the optimizer, so FSDP
    reduce-scatters amortize across microbatches (collective overlap).
  * Quantum Mantissa — bitlength params get their (exact weight-side +
    stash-estimator activation-side) gradients plus the eq. 7 footprint
    penalty, then an SGD step clipped to [0, man_bits].
  * BitChop — the controller observes the (pre-penalty) loss each step and
    adjusts the network-wide activation bitlength (eq. 8-9), holding full
    precision around LR-schedule boundaries.
  * optional gradient compression with error feedback for the cross-pod
    all-reduce (train/grad_compress.py).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitchop, quantum_mantissa as qmod, sfp
from repro.models.model import DecoderModel, RunState
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.train import grad_compress
from repro.train.state import QMState, TrainState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule: Schedule = Schedule()
    qm: qmod.QMConfig = qmod.QMConfig()
    bc: bitchop.BitChopConfig = bitchop.BitChopConfig()
    num_microbatches: int = 1
    grad_compress_bits: Optional[int] = None  # e.g. 4 -> bf16/4-bit-man wire
    grad_codec: str = "bit_exact"  # registry codec realizing the wire format
    # Optional tree of NamedShardings for params: pins the gradient
    # accumulator to the parameter layout so XLA reduce-scatters gradients
    # into shards (ZeRO-2) instead of all-reducing them in full.
    param_shardings: Optional[Any] = None


def init_state(model: DecoderModel, key: jax.Array, tc: TrainConfig
               ) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt=adamw.init(params),
        qm=_qm_init(model, tc),
        bc=bitchop.init(tc.bc),
        step=jnp.zeros((), jnp.int32),
        rng=jax.random.fold_in(key, 999),
        grad_residual=(grad_compress.init_residual(params)
                       if tc.grad_compress_bits else None),
    )


def _qm_init(model: DecoderModel, tc: TrainConfig) -> QMState:
    cfg = model.cfg
    bits = tc.qm.init_bits if model.policy.mode == sfp.MODE_QM else float(
        model.man_bits)
    n_rem = len(cfg.remainder)
    full = lambda n: jnp.full((n,), bits, jnp.float32)
    return QMState(act=full(cfg.n_periods), w=full(cfg.n_periods),
                   act_rem=full(n_rem), w_rem=full(n_rem))


def _qm_lambdas(model: DecoderModel, batch_shape: Tuple[int, int]
                ) -> Dict[str, jnp.ndarray]:
    """Footprint weights (eq. 7): each group's share of total stash bits.

    Activation stash per period: B * S_total * d values; weight footprint
    per period: parameter count of that period. Shares are computed over
    the combined activation+weight footprint, exactly as the paper weighs
    its loss to minimize *total* memory.
    """
    cfg = model.cfg
    B, S = batch_shape
    S_tot = S + cfg.prefix_tokens
    shapes = model.param_shapes()
    per_period = sum(
        math.prod(s.shape[1:]) for s in jax.tree.leaves(shapes["periods"]))
    act = float(B * S_tot * cfg.d_model)
    n_rem = len(cfg.remainder)
    rem_w = (sum(math.prod(s.shape)
                 for s in jax.tree.leaves(shapes.get("rem", {}))) / max(n_rem, 1)
             if n_rem else 0.0)
    total = (act + per_period) * cfg.n_periods + (act + rem_w) * n_rem
    lam = {
        "act": jnp.full((cfg.n_periods,), act / total, jnp.float32),
        "w": jnp.full((cfg.n_periods,), per_period / total, jnp.float32),
        "act_rem": jnp.full((n_rem,), act / total, jnp.float32),
        "w_rem": jnp.full((n_rem,), rem_w / total, jnp.float32),
    }
    return lam


def make_train_step(model: DecoderModel, tc: TrainConfig):
    cfg = model.cfg
    policy = model.policy
    man = float(model.man_bits)

    def loss_fn(params, qm: QMState, batch_mb, key, bc_bits, gamma, lam):
        run = RunState(key=key, qm_act=qm.act, qm_w=qm.w,
                       qm_act_rem=qm.act_rem, qm_w_rem=qm.w_rem,
                       bc_bits=bc_bits)
        loss, metrics = model.loss(params, batch_mb, run)
        if policy.mode == sfp.MODE_QM:
            penalty = gamma * (
                jnp.sum(lam["act"] * jnp.clip(qm.act, 0, man))
                + jnp.sum(lam["w"] * jnp.clip(qm.w, 0, man))
                + jnp.sum(lam["act_rem"] * jnp.clip(qm.act_rem, 0, man))
                + jnp.sum(lam["w_rem"] * jnp.clip(qm.w_rem, 0, man)))
        else:
            penalty = jnp.zeros((), jnp.float32)
        metrics = dict(metrics, qm_penalty=penalty)
        return loss + penalty, metrics

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        B, S = batch["tokens"].shape
        nm = tc.num_microbatches
        assert B % nm == 0, (B, nm)
        lam = _qm_lambdas(model, (B // nm, S))
        lr = tc.schedule(state.step)
        gamma = qmod.gamma_at(tc.qm, state.step)
        bc_bits = bitchop.effective_bits(state.bc, tc.bc)
        step_key = jax.random.fold_in(state.rng, state.step)

        mb_batch = jax.tree.map(
            lambda x: x.reshape((nm, B // nm) + x.shape[1:]), batch)

        def micro(carry, inp):
            g_acc, q_acc, loss_acc, xent_acc = carry
            mb, i = inp
            (loss, metrics), (gp, gq) = grad_fn(
                state.params, state.qm, mb, jax.random.fold_in(step_key, i),
                bc_bits, gamma, lam)
            if tc.param_shardings is not None:
                g_acc = jax.tree.map(
                    lambda a, g, sh: jax.lax.with_sharding_constraint(
                        a + g.astype(jnp.float32) / nm, sh),
                    g_acc, gp, tc.param_shardings)
            else:
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / nm, g_acc, gp)
            q_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / nm, q_acc, gq)
            return (g_acc, q_acc, loss_acc + loss / nm,
                    xent_acc + metrics["xent"] / nm), metrics

        if tc.param_shardings is not None:
            g0 = jax.tree.map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), sh),
                state.params, tc.param_shardings)
        else:
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
        q0 = jax.tree.map(jnp.zeros_like, state.qm)
        z = jnp.zeros((), jnp.float32)
        (grads, qgrads, loss, xent), metrics_seq = jax.lax.scan(
            micro, (g0, q0, z, z), (mb_batch, jnp.arange(nm)))

        # Optional compressed cross-pod gradient exchange (error feedback).
        residual = state.grad_residual
        if tc.grad_compress_bits is not None:
            grads, residual = grad_compress.compress_grads(
                grads, residual, tc.grad_compress_bits, tc.grad_codec)

        new_params, new_opt, gnorm = adamw.update(
            grads, state.opt, state.params, tc.opt, lr)

        # Quantum Mantissa bitlength SGD (+ clip to [0, man]).
        if policy.mode == sfp.MODE_QM:
            new_qm = QMState(*[
                jnp.clip(p - tc.qm.lr * g, tc.qm.min_bits, man)
                for p, g in zip(state.qm, qgrads)])
        else:
            new_qm = state.qm

        # BitChop observes the (pre-penalty) loss once per step (eq. 8-9).
        new_bc = bitchop.update(state.bc, xent, tc.bc,
                                lr_changed=tc.schedule.lr_changed(state.step))

        metrics = {
            "loss": loss, "xent": xent, "lr": lr, "grad_norm": gnorm,
            "gamma": gamma,
            "qm_act_mean": jnp.mean(jnp.clip(new_qm.act, 0, man)),
            "qm_w_mean": jnp.mean(jnp.clip(new_qm.w, 0, man)),
            "bc_bits": bc_bits.astype(jnp.float32),
            "moe_lb_loss": metrics_seq["moe_lb_loss"].mean(),
            "moe_drop_frac": metrics_seq["moe_drop_frac"].mean(),
            "qm_penalty": metrics_seq["qm_penalty"].mean(),
        }
        new_state = TrainState(
            params=new_params, opt=new_opt, qm=new_qm, bc=new_bc,
            step=state.step + 1, rng=state.rng, grad_residual=residual)
        return new_state, metrics

    return train_step
