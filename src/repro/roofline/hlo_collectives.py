"""Trip-count-aware collective accounting from post-SPMD HLO text.

The flat parse (launch/dryrun.parse_collectives) counts each collective
once, but collectives inside scanned layer bodies execute once per
iteration. XLA annotates its while loops with
``backend_config={..."known_trip_count":{"n":"13"}...}`` — this module
builds the computation call graph (while bodies/conditions, fusions,
calls, conditionals) and multiplies each computation's collective bytes by
the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALL_SINGLE_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALL_LIST_RE = re.compile(
    r"(?:calls|branch_computations)=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)\\?"')


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse(hlo_text: str) -> Dict[str, Dict]:
    """Returns {kind: {count, bytes}} with trip-count weighting, plus
    {'total_bytes': ...}. Counts are trip-weighted executions."""
    # --- split into computations ---
    comps: Dict[str, List[str]] = {}
    current = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line.strip())
        if m and (line.endswith("{") or "->" in line):
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            comps[current].append(line.strip())
    entry = None
    for raw in hlo_text.splitlines():
        if raw.strip().startswith("ENTRY"):
            m = _COMP_RE.match(raw.strip()[len("ENTRY"):].strip())
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = next(iter(comps))

    # --- per-computation: local collectives + weighted calls ---
    local: Dict[str, Dict[str, Tuple[int, int]]] = {}
    calls: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        stats = {k: [0, 0] for k in COLLECTIVES}
        for line in lines:
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(",
                         line)
            if m:
                type_str, op = m.groups()
                base = op
                for suffix in ("-start", "-done"):
                    if base.endswith(suffix):
                        base = base[: -len(suffix)]
                if base in COLLECTIVES and not op.endswith("-done"):
                    stats[base][0] += 1
                    stats[base][1] += _shape_bytes(type_str)
            callees = [m.group(1) for m in _CALL_SINGLE_RE.finditer(line)]
            for m in _CALL_LIST_RE.finditer(line):
                callees += [c.lstrip("%") for c in
                            re.split(r",\s*", m.group(1)) if c]
            if callees:
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                for callee in callees:
                    calls[name].append((callee.lstrip("%"), trip))
        local[name] = {k: tuple(v) for k, v in stats.items()}

    # --- weighted DFS from entry ---
    memo: Dict[str, Dict[str, Tuple[float, float]]] = {}

    def total(name: str, depth=0) -> Dict[str, Tuple[float, float]]:
        if name in memo:
            return memo[name]
        if depth > 64 or name not in local:
            return {k: (0.0, 0.0) for k in COLLECTIVES}
        acc = {k: [float(local[name][k][0]), float(local[name][k][1])]
               for k in COLLECTIVES}
        for callee, trip in calls.get(name, ()):  # noqa: B020
            sub = total(callee, depth + 1)
            for k in COLLECTIVES:
                acc[k][0] += trip * sub[k][0]
                acc[k][1] += trip * sub[k][1]
        memo[name] = {k: tuple(v) for k, v in acc.items()}
        return memo[name]

    agg = total(entry) if entry else {k: (0.0, 0.0) for k in COLLECTIVES}
    out = {k: {"count": agg[k][0], "bytes": agg[k][1]} for k in COLLECTIVES}
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out
