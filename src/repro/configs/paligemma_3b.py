"""paligemma-3b [vlm] — SigLIP vision frontend + gemma decoder.

[arXiv:2407.07726; hf] 18L, d_model=2048, 8H (GQA kv=1), d_ff=16384,
vocab=257216. Backbone only: the SigLIP tower is a stub — input_specs()
provides precomputed patch embeddings consumed as a fully-visible prefix.
"""
from repro.configs.base import ArchConfig, GLOBAL, register

PALIGEMMA_3B = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257_216,
    period=(GLOBAL,),
    act="gelu",
    emb_scale=True,
    prefix_tokens=256,
    source="arXiv:2407.07726 (PaliGemma); assignment spec",
))
