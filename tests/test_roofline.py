"""Roofline tooling: the scan-trip-count defect in cost_analysis (why the
jaxpr model exists), jaxpr cost accuracy, HLO collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_collectives, jaxpr_cost


def test_cost_analysis_misses_scan_trips():
    """Documents the backend defect the jaxpr model corrects."""
    def f(c, xs):
        def body(c, x):
            return jnp.tanh(c @ x), None
        out, _ = jax.lax.scan(body, c, xs)
        return out

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    xs = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    compiled = jax.jit(f).lower(a, xs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns one dict per device
        cost = cost[0]
    reported = cost["flops"]
    one_matmul = 2 * 256 ** 3
    assert reported < 2.5 * one_matmul  # counts the body once, not x10


def test_jaxpr_cost_counts_scan_trips_exactly():
    def f(c, xs):
        def body(c, x):
            return jnp.tanh(c @ x), None
        out, _ = jax.lax.scan(body, c, xs)
        return out

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    xs = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    est = jaxpr_cost.estimate(f, a, xs)
    expect = 10 * 2 * 256 ** 3
    assert expect <= est["flops"] < expect * 1.05


def test_jaxpr_cost_counts_grad_and_remat():
    def loss(w, x):
        h = x
        for _ in range(2):
            h = jnp.tanh(h @ w)
        return jnp.sum(h ** 2)

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    fwd = jaxpr_cost.estimate(loss, w, x)["flops"]
    g = jaxpr_cost.estimate(jax.grad(loss), w, x)["flops"]
    assert g > 2.0 * fwd  # backward ~2x forward matmul cost


def test_jaxpr_cost_handles_jit_and_custom_vjp():
    @jax.custom_vjp
    def f(x, w):
        return x @ w

    def fwd(x, w):
        return x @ w, (x, w)

    def bwd(res, g):
        x, w = res
        return g @ w.T, x.T @ g

    f.defvjp(fwd, bwd)

    def loss(x, w):
        return jnp.sum(jax.jit(f)(x, w))

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    est = jaxpr_cost.estimate(jax.grad(loss, argnums=(0, 1)), x, x)
    assert est["flops"] >= 3 * 2 * 64 ** 3  # fwd + two bwd matmuls


def test_hlo_collective_parse_trip_counts():
    hlo = """
%body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}
}

%cond.1 (p: (s32[], f32[128])) -> pred[] {
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %w = (s32[], f32[128]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[512]{0} all-gather(%y), dimensions={0}
}
"""
    stats = hlo_collectives.parse(hlo)
    assert stats["all-reduce"]["count"] == 7
    assert stats["all-reduce"]["bytes"] == 7 * 128 * 4
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 512 * 4


def test_model_flops_formulas():
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.roofline.analysis import model_flops
    cfg = configs.get("mistral-large-123b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    # 6 * ~123e9 params * 1.05M tokens ~ 7.7e17, attention adds a few %
    assert 7e17 < f_train < 1.4e18
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec < f_train / 1000
