"""Compressed gradient synchronization (beyond-paper application).

The paper compresses tensors crossing the DRAM boundary; at multi-pod scale
the analogous expensive boundary is the cross-pod (DCN) gradient
all-reduce. We apply the same recipe: quantize gradients through a registry
codec's pack->unpack round trip before the reduction and keep the
quantization error in a local *error-feedback* residual that is re-injected
next step — the standard convergence-preserving trick for biased
compressors.

The wire format is whichever container the codec realizes (default
``bit_exact``: mantissa truncation, the historical behaviour, with the
Gecko exponent packing accounted in core.footprint; ``sfp8``/``sfp16``
model the byte-aligned wire).

Two entry points:
  * compress_grads / error feedback — used inside the big pjit train step
    (XLA owns the actual collective; the entitlement is the quantized
    payload).
  * psum_compressed — explicit shard_map collective for the tested
    multi-device harness (tests/spmd/).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import codecs


def compress_grads(grads: Any, residual: Any, bits: int,
                   codec: str = codecs.BIT_EXACT) -> Tuple[Any, Any]:
    """Error-feedback codec round trip: returns (compressed, new_residual)."""
    cd = codecs.get(codec)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q = cd.roundtrip(gf, bits=bits)
        return q, gf - q

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_residual(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def psum_compressed(grads: Any, residual: Any, bits: int, axis_name: str,
                    codec: str = codecs.BIT_EXACT) -> Tuple[Any, Any]:
    """shard_map building block: codec round trip -> bf16 -> psum -> mean.

    Payload on the wire: bf16 containers with ``bits``-bit mantissas (the
    Gecko exponent packing applies on top in the hardware realization; the
    bit-exact accounting lives in core.footprint).
    """
    q, new_res = compress_grads(grads, residual, bits, codec)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        .astype(jnp.float32) / n, q)
    return summed, new_res
