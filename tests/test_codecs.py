"""The container-codec registry: uniform interface, backend parity, and
bit-exactness of the realized gecko8 stream against core/gecko.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.core import containers as C, gecko
from repro.kernels import ops


def _x(shape=(4, 256), dtype=jnp.bfloat16, seed=0, scale=3.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_registry_contents():
    assert {"bit_exact", "sfp8", "sfp16", "gecko8"} <= set(codecs.names())


def test_unknown_codec_raises_with_names():
    with pytest.raises(KeyError, match="sfp8"):
        codecs.get("definitely-not-a-codec")


def test_register_new_codec_visible_everywhere():
    class Doubler(codecs.Codec):
        name = "test_doubler"

        def pack(self, x, bits=None):
            return codecs.PackedTensor(self.name, x.shape, x.dtype,
                                       {"payload": x * 2})

        def unpack(self, packed):
            return packed.data["payload"] / 2

        def packed_bits(self, x, bits=None):
            return float(x.size * 16)

    codecs.register(Doubler())
    try:
        x = _x()
        np.testing.assert_array_equal(
            np.asarray(codecs.get("test_doubler").roundtrip(x)),
            np.asarray(x))
    finally:
        codecs.base._REGISTRY.pop("test_doubler")


@pytest.mark.parametrize("name", ["bit_exact", "sfp8", "sfp16", "gecko8"])
def test_unpack_dispatches_on_metadata(name):
    x = _x()
    packed = codecs.get(name).pack(x)
    y = codecs.unpack(packed)  # no codec argument: rides in the metadata
    assert y.shape == x.shape and y.dtype == x.dtype


@pytest.mark.parametrize("name", ["bit_exact", "sfp8", "sfp16", "gecko8"])
def test_packed_spec_matches_pack(name):
    x = _x((2, 3, 128))
    spec = codecs.get(name).packed_spec(x.shape, x.dtype)
    packed = codecs.get(name).pack(x)
    for k, s in spec.data.items():
        assert tuple(s.shape) == tuple(packed.data[k].shape), (name, k)
        assert s.dtype == packed.data[k].dtype, (name, k)


@pytest.mark.parametrize("name", ["bit_exact", "sfp8", "sfp16", "gecko8"])
def test_packed_tensor_rides_through_scan(name):
    codec = codecs.get(name)
    x = _x((4, 128))

    def body(carry, _):
        packed = codec.pack(carry, bits=3)
        return codec.unpack(packed), packed

    out, stacked = jax.lax.scan(body, x, None, length=3)
    assert out.shape == x.shape
    assert stacked.shape == x.shape  # metadata (incl. shape) preserved
    leaves = jax.tree.leaves(stacked)
    assert all(l.shape[0] == 3 for l in leaves)


# ---------------------------------------------------------------------------
# Numerics per codec
# ---------------------------------------------------------------------------


def test_bit_exact_pack_is_mantissa_truncation():
    x = _x(dtype=jnp.float32)
    q = codecs.get("bit_exact").roundtrip(x, bits=4)
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(C.truncate_mantissa(x, 4)))


def test_sfp_pack_with_bits_fuses_quantization():
    """codec.pack(x, bits=n) == pack(truncate(x, n)) bit-exactly."""
    for name in ("sfp8", "sfp16"):
        codec = codecs.get(name)
        x = _x()
        a = codec.pack(x, bits=2)
        b = codec.pack(C.truncate_mantissa(x, 2))
        for k in a.data:
            np.testing.assert_array_equal(np.asarray(a.data[k]),
                                          np.asarray(b.data[k]), err_msg=name)


def test_sfp8_bounded_relative_error():
    codec = codecs.get("sfp8")
    x = _x((8, 512))
    back = codec.roundtrip(x)
    err = np.abs(np.asarray(back, np.float32) - np.asarray(x, np.float32))
    gmax = np.abs(np.asarray(x, np.float32)).reshape(8, 4, 128).max(-1)
    assert (err.reshape(8, 4, 128) / gmax[..., None]).max() < 0.13


def test_sfp_flat_layout_for_unaligned_shapes():
    codec = codecs.get("sfp8")
    x = _x((5, 33))  # last dim not a multiple of 128 -> flat row layout
    packed = codec.pack(x, bits=3)
    y = codec.unpack(packed)
    assert y.shape == x.shape
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(codec.unpack(codec.pack(
            C.truncate_mantissa(x, 3)))))


def test_gecko8_lossless_on_bf16():
    x = _x((7, 129))  # deliberately unaligned
    back = codecs.get("gecko8").roundtrip(x)
    np.testing.assert_array_equal(np.asarray(back).view(np.uint16),
                                  np.asarray(x).view(np.uint16))


def test_gecko8_fp32_keeps_top7_mantissa():
    x = _x((64,), dtype=jnp.float32)
    back = codecs.get("gecko8").roundtrip(x)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.asarray(C.truncate_mantissa(x, 7)))


# ---------------------------------------------------------------------------
# gecko8 vs the core/gecko.py reference encoder (bit-exact equivalence)
# ---------------------------------------------------------------------------


def _exponents(n, seed=0, spread=4):
    rng = np.random.RandomState(seed)
    return jnp.asarray(np.clip(rng.normal(127, spread, n).round(), 0, 255)
                       .astype(np.uint8))


@pytest.mark.parametrize("n", [1, 63, 64, 65, 1000, 1 << 14])
def test_gecko8_fields_match_reference_encoder(n):
    e = _exponents(n, seed=n % 7)
    enc = gecko.encode_delta(e)
    bases, widths, planes = ops.gecko_encode(
        codecs.gecko._exponent_groups(e))
    np.testing.assert_array_equal(np.asarray(bases), np.asarray(enc.bases))
    np.testing.assert_array_equal(np.asarray(widths),
                                  np.asarray(enc.row_widths).astype(np.uint8))
    # plane payload reproduces the reference deltas exactly
    back = ops.gecko_decode(bases, planes)
    np.testing.assert_array_equal(np.asarray(back).reshape(-1)[:n],
                                  np.asarray(gecko.decode_delta(enc)))


@pytest.mark.parametrize("n", [1, 64, 257, 4096])
def test_gecko8_stream_roundtrip_bit_exact(n):
    e = _exponents(n, seed=n % 5)
    stream, nv = codecs.gecko.pack_exponent_stream(e)
    back = codecs.gecko.unpack_exponent_stream(stream, nv)
    np.testing.assert_array_equal(back, np.asarray(e))


def test_gecko8_stream_cost_matches_reference_accounting():
    """Stream bytes == core/gecko.py delta_bits + exactly 11 bits/group
    (4-bit width nibbles byte-aligned vs the idealized 3-bit fields)."""
    e = _exponents(1 << 14, seed=3)
    enc = gecko.encode_delta(e)
    stream, _ = codecs.gecko.pack_exponent_stream(e)
    n_groups = enc.bases.shape[0]
    assert stream.size * 8 == int(gecko.delta_bits(enc)) + 11 * n_groups


def test_gecko8_stream_compresses_trained_exponents():
    e = _exponents(1 << 14, seed=4)
    stream, _ = codecs.gecko.pack_exponent_stream(e)
    assert stream.size < e.size * 0.75  # paper-range ratio on tight streams


def test_gecko8_interpret_kernel_matches_ref_backend():
    e = _exponents(2048, seed=9)
    groups = codecs.gecko._exponent_groups(e)
    ops.force_backend("interpret")
    try:
        bk, wk, pk = ops.gecko_encode(groups)
        dk = ops.gecko_decode(bk, pk)
    finally:
        ops.force_backend(None)
    br, wr, pr = ops.gecko_encode(groups)
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(groups))


# ---------------------------------------------------------------------------
# Footprint accounting + host serialization
# ---------------------------------------------------------------------------


def test_sfp_packed_bits_counts_payload_plus_bases():
    x = _x((2, 256))
    assert codecs.get("sfp8").packed_bits(x) == x.size * 8 + (x.size // 128) * 8
    assert codecs.get("sfp16").packed_bits(x) == x.size * 16 + (x.size // 128) * 8


@pytest.mark.parametrize("name", ["sfp8", "sfp16", "gecko8"])
def test_packed_bits_matches_encode_host_stream(name):
    """The accounting contract for *realized* codecs: packed_bits == the
    bytes encode_host actually writes (including flat-layout tail
    padding). bit_exact is exempt — its packed_bits is deliberately the
    paper's idealized entitlement, not the materialized payload."""
    codec = codecs.get(name)
    for shape in [(2, 256), (5, 33)]:  # aligned and unaligned
        x = _x(shape)
        stream, _meta = codec.encode_host(np.asarray(x))
        assert codec.packed_bits(x) == stream.size * 8, (name, shape)


def test_gecko8_packed_bits_matches_stream():
    x = _x((512,))
    g = codecs.get("gecko8")
    stream, meta = g.encode_host(np.asarray(x))
    assert g.packed_bits(x) == stream.size * 8


@pytest.mark.parametrize("name", ["bit_exact", "sfp8", "sfp16", "gecko8"])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_encode_decode_host_roundtrip(name, dtype):
    codec = codecs.get(name)
    arr = np.asarray(_x((16, 128), dtype=dtype))
    stream, meta = codec.encode_host(arr, bits=3)
    back = codec.decode_host(stream, meta, arr.shape, arr.dtype)
    assert back.shape == arr.shape and back.dtype == arr.dtype
    want = np.asarray(codec.roundtrip(jnp.asarray(arr), bits=3))
    np.testing.assert_array_equal(back.view(np.uint8).reshape(-1),
                                  want.view(np.uint8).reshape(-1))
