"""Seeded violations: Python control flow on traced values."""
import jax
import jax.numpy as jnp


def body(x):
    if jnp.any(x > 0):  # LINT: traced-truthiness
        x = x + 1
    while jnp.max(x) < 4:  # LINT: traced-truthiness
        x = x * 2
    assert jnp.isfinite(x).all()  # LINT: traced-truthiness
    if x.ndim == 2:
        # Shape-level branch: static under trace, not a violation.
        x = x[0]
    return x


out = jax.jit(body)(jnp.ones((3,)))
